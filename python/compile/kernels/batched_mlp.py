"""Layer-1 Pallas kernel: tiled batched dense layer (the serving hot-spot).

Each microservice in the Fifer workload is an ML inference model whose
compute is dominated by dense layers over a *request batch* (Fifer's whole
point is batching requests into one container execution). This kernel
computes ``activation(x @ w + b)`` for a batch of requests, tiled so that on
a real TPU each (block_m, block_n) output tile is produced by the MXU from
VMEM-resident operand tiles.

TPU adaptation notes (docs/DESIGN.md §3):
  * Tiles are (block_m=128, block_n=128) by default — the MXU systolic array
    shape — with the full K dimension resident per tile (the Fifer models
    are small: K ≤ 4096 keeps the per-tile VMEM footprint
    (bm*K + K*bn + bm*bn)*4B ≤ ~4.2 MiB, well under the ~16 MiB VMEM).
  * Bias add + activation are fused into the same kernel so the output tile
    makes a single HBM round-trip.
  * ``interpret=True`` everywhere on this image: CPU PJRT cannot execute
    Mosaic custom-calls; kernel *structure* is still the TPU schedule.

The pure-jnp oracle is ref.dense_ref; pytest sweeps shapes with hypothesis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (block_m, block_n) output tile: activation(x_tile @ w_tile + b)."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    o_ref[...] = ref.apply_activation(acc, activation).astype(o_ref.dtype)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "interpret")
)
def dense(
    x,
    w,
    b,
    activation: str = "relu",
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
):
    """Batched dense layer via Pallas: activation(x @ w + b).

    x: (M, K) request batch, w: (K, N), b: (N,). Returns (M, N) f32.

    M and N are padded up to the block grid; K is kept whole per tile
    (see module docstring for the VMEM budget argument).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    # Shrink blocks for small problems so we don't pad tiny models to 128.
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)

    x_p = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, np_ - n)))
    b_p = jnp.pad(b.astype(jnp.float32), ((0, np_ - n),)).reshape(1, np_)

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(x_p, w_p, b_p)
    return out[:m, :n]


def mlp(x, params, activation: str = "relu", interpret: bool = True):
    """MLP forward over a request batch; every layer is the Pallas kernel.

    params: list of (w, b); the final layer is linear (no activation),
    matching ref.mlp_ref.
    """
    n = len(params)
    for i, (w, b) in enumerate(params):
        act = activation if i + 1 < n else "none"
        x = dense(x, w, b, activation=act, interpret=interpret)
    return x


def vmem_bytes(block_m: int, block_n: int, k: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (used by the perf model)."""
    return dtype_bytes * (block_m * k + k * block_n + block_m * block_n + block_n)


def mxu_utilization(m: int, n: int, k: int, block_m: int = 128, block_n: int = 128) -> float:
    """Fraction of MXU work that is useful (non-padding) for a (m,k)x(k,n)
    matmul under this kernel's padding scheme. Used for the §Perf roofline
    estimate in docs/EXPERIMENTS.md (interpret mode gives no TPU wall-clock)."""
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    return (m * n * k) / float(mp * np_ * k)
