"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every Pallas kernel in this package has an exact (up to float tolerance)
reference implementation here. pytest (python/tests/test_kernels.py) sweeps
shapes/dtypes with hypothesis and asserts allclose between kernel and oracle;
this is the core correctness signal for Layer 1.
"""
from __future__ import annotations

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "relu"):
    """Reference batched dense layer: activation(x @ w + b).

    x: (M, K) activations, w: (K, N) weights, b: (N,) bias.
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return apply_activation(y, activation)


def apply_activation(y, activation: str):
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "gelu":
        # tanh-approximated gelu, matching the kernel
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def mlp_ref(x, params, activation: str = "relu"):
    """Reference MLP forward: chain of dense layers, last layer linear."""
    n = len(params)
    for i, (w, b) in enumerate(params):
        act = activation if i + 1 < n else "none"
        x = dense_ref(x, w, b, act)
    return x


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Reference fused LSTM cell.

    x: (B, I), h: (B, H), c: (B, H)
    wx: (I, 4H), wh: (H, 4H), b: (4H,)
    Gate order along the 4H axis: input, forget, cell(g), output.
    Returns (h', c').
    """
    z = jnp.dot(x, wx, preferred_element_type=jnp.float32) + jnp.dot(
        h, wh, preferred_element_type=jnp.float32
    ) + b
    hidden = h.shape[-1]
    i, f, g, o = (
        z[:, 0 * hidden : 1 * hidden],
        z[:, 1 * hidden : 2 * hidden],
        z[:, 2 * hidden : 3 * hidden],
        z[:, 3 * hidden : 4 * hidden],
    )
    i = jnp.reciprocal(1.0 + jnp.exp(-i))
    f = jnp.reciprocal(1.0 + jnp.exp(-f))
    o = jnp.reciprocal(1.0 + jnp.exp(-o))
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
