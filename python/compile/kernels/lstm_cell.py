"""Layer-1 Pallas kernel: fused LSTM cell for Fifer's load predictor.

Fifer's proactive scaler forecasts the arrival rate with a 2-layer, 32-unit
LSTM (paper §4.5.1). The per-step cell — two matmuls, four gate
nonlinearities, and the state update — is fused into a single Pallas kernel
so the whole step is one VMEM-resident block (the predictor is tiny: the
entire cell state fits in a fraction of one tile).

Gate order along the 4H axis: input, forget, cell(g), output — matching
ref.lstm_cell_ref, which is the pytest oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, ho_ref, co_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    z = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    hidden = h.shape[-1]
    i = z[:, 0 * hidden : 1 * hidden]
    f = z[:, 1 * hidden : 2 * hidden]
    g = z[:, 2 * hidden : 3 * hidden]
    o = z[:, 3 * hidden : 4 * hidden]
    i = jnp.reciprocal(1.0 + jnp.exp(-i))
    f = jnp.reciprocal(1.0 + jnp.exp(-f))
    o = jnp.reciprocal(1.0 + jnp.exp(-o))
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    ho_ref[...] = h_new.astype(ho_ref.dtype)
    co_ref[...] = c_new.astype(co_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_cell(x, h, c, wx, wh, b, interpret: bool = True):
    """Fused LSTM cell step.

    x: (B, I), h/c: (B, H), wx: (I, 4H), wh: (H, 4H), b: (4H,).
    Returns (h', c'), both (B, H) f32.
    """
    batch, _ = x.shape
    hidden = h.shape[-1]
    assert wx.shape[-1] == 4 * hidden and wh.shape == (hidden, 4 * hidden)
    assert b.shape == (4 * hidden,)
    b2 = b.reshape(1, 4 * hidden)
    out_shape = (
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
    )
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=out_shape,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        h.astype(jnp.float32),
        c.astype(jnp.float32),
        wx.astype(jnp.float32),
        wh.astype(jnp.float32),
        b2.astype(jnp.float32),
    )
