"""AOT pipeline: lower every Layer-2 graph to HLO-text artifacts for Rust.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (under artifacts/):
  ms_<NAME>_b<B>.hlo.txt     batched inference graph per (microservice,
                             batch size); weights are runtime *parameters*
                             (w1,b1,...,x) so the HLO stays small
  weights/<NAME>.bin         f32-LE concatenated layer weights for the above
  lstm_predict.hlo.txt       LSTM load predictor (trained weights baked in,
                             input = (1, WINDOW) normalized history)
  ff_predict.hlo.txt         feed-forward predictor baseline
  predictor_weights.json     trained weights + normalization scale (shared
                             with the Rust-native predictor implementation)
  traces/{wits,wiki}.json    the synthetic arrival traces (per-second rates)
                             so Rust scores predictors on the same series
  manifest.json              index of all of the above

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import lstm_train, model, traces

BATCH_SIZES = [1, 2, 4, 8, 16, 32]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_microservice(name: str, batch: int, params) -> str:
    """Lower one (microservice, batch) inference graph with weight params."""
    in_dim, _, _ = model.layer_dims(name)

    flat_specs = []
    for (w, b) in params:
        flat_specs.append(jax.ShapeDtypeStruct(w.shape, jnp.float32))
        flat_specs.append(jax.ShapeDtypeStruct(b.shape, jnp.float32))
    x_spec = jax.ShapeDtypeStruct((batch, in_dim), jnp.float32)

    def fn(*args):
        *flat, x = args
        ps = [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]
        return (model.microservice_forward(name, ps, x),)

    lowered = jax.jit(fn).lower(*flat_specs, x_spec)
    return to_hlo_text(lowered)


def lower_lstm(weights) -> str:
    """Lower the LSTM predictor with weights as runtime *parameters*.

    Parameter order (must match rust runtime::Runtime::predict):
    wx1, wh1, b1, wx2, wh2, b2, w_out, b_out, x. (Baked-constant weights
    trip a miscompile in the image's xla_extension 0.5.1 when combined
    with interpret-mode Pallas while-loops; the parameter path is the same
    one the microservice artifacts use and verifies bit-exact.)
    """
    specs = []
    for l in weights["layers"]:
        specs.append(jax.ShapeDtypeStruct(l["wx"].shape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct(l["wh"].shape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct(l["b"].shape, jnp.float32))
    specs.append(jax.ShapeDtypeStruct(weights["w_out"].shape, jnp.float32))
    specs.append(jax.ShapeDtypeStruct(weights["b_out"].shape, jnp.float32))
    x_spec = jax.ShapeDtypeStruct((1, model.WINDOW), jnp.float32)

    def fn(*args):
        *flat, x = args
        layers = []
        for i in range(0, len(flat) - 2, 3):
            layers.append({"wx": flat[i], "wh": flat[i + 1], "b": flat[i + 2]})
        params = {"layers": layers, "w_out": flat[-2], "b_out": flat[-1]}
        return (model.lstm_forward(params, x),)

    return to_hlo_text(jax.jit(fn).lower(*specs, x_spec))


def lower_ff(weights) -> str:
    """Lower the FF predictor with weights as runtime parameters
    (w1, b1, w2, b2, x) — see lower_lstm for why."""
    specs = []
    for (w, b) in weights:
        specs.append(jax.ShapeDtypeStruct(w.shape, jnp.float32))
        specs.append(jax.ShapeDtypeStruct(b.shape, jnp.float32))
    x_spec = jax.ShapeDtypeStruct((1, model.WINDOW), jnp.float32)

    def fn(*args):
        *flat, x = args
        ps = [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]
        return (model.ff_forward(ps, x),)

    return to_hlo_text(jax.jit(fn).lower(*specs, x_spec))


def write_weights_bin(path: str, params) -> list:
    """Concatenate all layer tensors as f32-LE; return layer shape index."""
    layers = []
    buf = []
    for (w, b) in params:
        layers.append({"w": list(w.shape), "b": list(b.shape)})
        buf.append(np.asarray(w, np.float32).ravel())
        buf.append(np.asarray(b, np.float32).ravel())
    flat = np.concatenate(buf).astype("<f4")
    with open(path, "wb") as f:
        f.write(flat.tobytes())
    return layers


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default=",".join(map(str, BATCH_SIZES)))
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse predictor_weights.json if present")
    args = ap.parse_args()

    out = args.out_dir
    batches = [int(b) for b in args.batches.split(",")]
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)
    os.makedirs(os.path.join(out, "traces"), exist_ok=True)

    manifest = {
        "version": 1,
        "slo_ms": model.SLO_MS,
        "batch_sizes": batches,
        "microservices": {},
        "chains": {
            name: {"stages": stages, "slack_ms": slack}
            for name, (stages, slack) in model.CHAINS.items()
        },
        "predictors": {},
        "traces": {},
    }

    # --- microservice inference graphs -----------------------------------
    for name in model.MICROSERVICES:
        in_dim, hidden, out_dim, exec_ms = (
            model.MICROSERVICES[name][0],
            model.MICROSERVICES[name][1],
            model.MICROSERVICES[name][2],
            model.MICROSERVICES[name][3],
        )
        params = model.init_mlp_params(name)
        wpath = f"weights/{name}.bin"
        layer_index = write_weights_bin(os.path.join(out, wpath), params)
        entry = {
            "paper_exec_ms": exec_ms,
            "input_dim": in_dim,
            "hidden": hidden,
            "output_dim": out_dim,
            "weights": {"path": wpath, "layers": layer_index},
            "batches": {},
        }
        for b in batches:
            hlo = lower_microservice(name, b, params)
            fname = f"ms_{name}_b{b}.hlo.txt"
            with open(os.path.join(out, fname), "w") as f:
                f.write(hlo)
            entry["batches"][str(b)] = fname
            print(f"[aot] {name} b={b}: {len(hlo)} chars")
        manifest["microservices"][name] = entry

    # --- predictors -------------------------------------------------------
    wjson = os.path.join(out, "predictor_weights.json")
    if not (args.skip_train and os.path.exists(wjson)):
        lstm_train.train_all(wjson)
    lstm_w, ff_w, scale = lstm_train.load_weights(wjson)

    lstm_hlo = lower_lstm(lstm_w)
    with open(os.path.join(out, "lstm_predict.hlo.txt"), "w") as f:
        f.write(lstm_hlo)
    ff_hlo = lower_ff(ff_w)
    with open(os.path.join(out, "ff_predict.hlo.txt"), "w") as f:
        f.write(ff_hlo)
    manifest["predictors"] = {
        "lstm": {
            "path": "lstm_predict.hlo.txt",
            "window": model.WINDOW,
            "hidden": model.LSTM_HIDDEN,
            "scale": scale,
            "weights": "predictor_weights.json",
        },
        "ff": {
            "path": "ff_predict.hlo.txt",
            "window": model.WINDOW,
            "scale": scale,
            "weights": "predictor_weights.json",
        },
    }
    print(f"[aot] lstm: {len(lstm_hlo)} chars, ff: {len(ff_hlo)} chars")

    # --- traces -----------------------------------------------------------
    for tname, gen in (("wits", traces.wits_trace), ("wiki", traces.wiki_trace)):
        rate = gen()
        tpath = f"traces/{tname}.json"
        with open(os.path.join(out, tpath), "w") as f:
            json.dump({"name": tname, "rate_per_s": rate.tolist()}, f)
        manifest["traces"][tname] = {
            "path": tpath,
            "avg": float(rate.mean()),
            "peak": float(rate.max()),
            "duration_s": len(rate),
        }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {os.path.join(out, 'manifest.json')}")


if __name__ == "__main__":
    main()
