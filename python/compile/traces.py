"""Synthetic arrival-trace generators (Python half, build-time only).

The paper drives its large-scale simulations with two real traces:

  * **WITS** (Waikato Internet Traffic Storage): avg ~240-300 req/s with
    unpredictable spikes up to 1200 req/s (peak ~5x median).
  * **Wiki** (Wikipedia workload): avg ~1500 req/s with a recurring
    diurnal pattern.

We do not have the raw traces, so we synthesize generators that match the
statistics the paper publishes and exploits (docs/DESIGN.md §2). This module is
the *training-side* generator: lstm_train.py fits the LSTM on 60% of the
WITS trace exactly as the paper does, and aot.py exports the generated
traces to artifacts/ so the Rust evaluation (Fig. 6) scores predictors on
the very same series. The Rust trace module re-implements the same formulas
for arbitrary-duration simulation runs (Figs. 14-16).
"""
from __future__ import annotations

import numpy as np

WITS_SEED = 1316
WIKI_SEED = 2025
DEFAULT_DURATION_S = 4000


def wits_trace(duration_s: int = DEFAULT_DURATION_S, seed: int = WITS_SEED) -> np.ndarray:
    """Per-second arrival rates, WITS-like: avg ~240-300, peak ~1200.

    Composition: a slowly-drifting base around 240 req/s, lognormal noise,
    and Poisson-arriving spikes (mean gap 300 s, 30-120 s wide, peaking
    near 1200 req/s) — the "black-Friday" bursts the paper highlights.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    base = 230.0 * (1.0 + 0.20 * np.sin(2 * np.pi * t / 1800.0))
    noise = rng.lognormal(mean=0.0, sigma=0.12, size=duration_s)
    rate = base * noise
    # spikes: rare, sharp, tall (black-Friday style)
    pos = 0.0
    while True:
        pos += rng.exponential(500.0)
        if pos >= duration_s:
            break
        width = rng.uniform(20.0, 60.0)
        amp = rng.uniform(650.0, 950.0)  # on top of base -> peak ~1200
        span = np.exp(-0.5 * ((t - pos) / (width / 2.355)) ** 2)  # gaussian bump
        rate = rate + amp * span
    return np.clip(rate, 1.0, 1250.0)


def wiki_trace(duration_s: int = DEFAULT_DURATION_S, seed: int = WIKI_SEED) -> np.ndarray:
    """Per-second arrival rates, Wiki-like: avg ~1500 with diurnal pattern.

    A day is compressed to 3600 s (the simulations run hours, not days),
    with an hour-of-day fundamental, a shorter harmonic, and mild noise —
    "recurring patterns" rather than surprise spikes.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(duration_s, dtype=np.float64)
    rate = 1500.0 * (
        1.0
        + 0.35 * np.sin(2 * np.pi * t / 3600.0)
        + 0.12 * np.sin(2 * np.pi * t / 600.0 + 1.0)
    )
    rate = rate * rng.lognormal(mean=0.0, sigma=0.08, size=duration_s)
    return np.clip(rate, 1.0, None)


def window_maxima(rate: np.ndarray, window_s: int = 5) -> np.ndarray:
    """Max arrival rate per adjacent window (paper §4.5: W_s = 5 s).

    A trailing partial window contributes its own maximum (mirrors
    rust trace::Trace::window_maxima — the predictor input must not
    silently lose the end of the series).
    """
    return np.asarray(
        [rate[i : i + window_s].max() for i in range(0, len(rate), window_s)],
        dtype=np.float64,
    )


def make_dataset(rate: np.ndarray, history: int = 20, horizon: int = 2,
                 window_s: int = 5):
    """Sliding-window dataset for the predictors.

    X[i] = `history` consecutive 5 s window maxima (the past 100 s),
    y[i] = max over the next `horizon` windows (the next 10 s monitoring
    interval) — what Fifer's proactive scaler needs.
    Returns (X, y) un-normalized.
    """
    w = window_maxima(rate, window_s)
    xs, ys = [], []
    for i in range(len(w) - history - horizon):
        xs.append(w[i : i + history])
        ys.append(w[i + history : i + history + horizon].max())
    return np.asarray(xs, np.float32), np.asarray(ys, np.float32)
