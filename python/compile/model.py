"""Layer-2: JAX compute graphs for the Fifer workload (build-time only).

Two families of graphs, both of which lower into HLO-text artifacts that the
Rust runtime executes via PJRT (Python is never on the request path):

1. **Microservice inference models** — one small MLP per Djinn&Tonic-style
   microservice (paper Table 3). The Rust "containers" run these for real in
   live-serving mode, batched at Fifer's per-stage batch size. Layer sizes
   scale roughly with the paper's mean execution times (relative, not
   absolute — see docs/DESIGN.md §2 substitutions). Every dense layer is the
   Pallas kernel from kernels/batched_mlp.py.

2. **Load-predictor networks** — the 2-layer/32-unit LSTM (paper §4.5.1) and
   the simple feed-forward baseline from Fig. 6, built on the fused Pallas
   LSTM cell. Trained by lstm_train.py; the trained weights are baked into
   the exported artifact as constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import batched_mlp, lstm_cell

# ---------------------------------------------------------------------------
# Microservice catalog (paper Table 3).
#
# name -> (input_dim, hidden_dims, output_dim, paper_exec_ms)
# Hidden sizes are chosen so relative FLOPs roughly track paper exec times.
# "NLP" is the composite POS+NER stage used by the IMG / IPA chains (Table 4).
# ---------------------------------------------------------------------------
MICROSERVICES = {
    # Image services
    "IMC":   (1024, [1024, 1024, 512], 10, 43.5),   # Image Classification (Alexnet)
    "AP":    (1024, [768, 768, 256], 16, 30.3),     # Human Activity Pose (DeepPose)
    "HS":    (2048, [2048, 2048, 1024, 512], 64, 151.2),  # Human Segmentation (VGG16)
    "FACER": (256, [256, 128], 32, 5.5),            # Facial Recognition (VGGNET)
    "FACED": (256, [256, 160], 4, 6.1),             # Face Detection (Xception)
    # Speech services
    "ASR":   (1280, [1024, 1024, 512], 48, 46.1),   # Auto Speech Recognition (NNet3)
    # NLP services
    "POS":   (64, [32], 12, 0.100),                 # Parts-of-Speech (SENNA)
    "NER":   (64, [32], 8, 0.090),                  # Named Entity Recognition (SENNA)
    "NLP":   (64, [48], 16, 0.190),                 # composite POS+NER chain stage
    "QA":    (1024, [1024, 768, 512], 32, 56.1),    # Question Answering
}

# Application chains (paper Table 4) and their measured average slack (ms).
CHAINS = {
    "FaceSecurity":  (["FACED", "FACER"], 788.0),
    "IMG":           (["IMC", "NLP", "QA"], 700.0),
    "IPA":           (["ASR", "NLP", "QA"], 697.0),
    "DetectFatigue": (["HS", "AP", "FACED", "FACER"], 572.0),
}

SLO_MS = 1000.0  # paper §4.1: fixed end-to-end response latency


def layer_dims(name: str):
    """(in_dim, [hidden...], out_dim) for a microservice."""
    in_dim, hidden, out_dim, _ = MICROSERVICES[name]
    return in_dim, hidden, out_dim


def init_mlp_params(name: str, seed: int = 0):
    """Deterministic He-init weights for a microservice model."""
    in_dim, hidden, out_dim = layer_dims(name)
    dims = [in_dim] + hidden + [out_dim]
    key = jax.random.PRNGKey(hash(name) % (2**31) + seed)
    params = []
    for i in range(len(dims) - 1):
        key, kw = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        w = jax.random.normal(kw, (dims[i], dims[i + 1]), jnp.float32) * scale
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def microservice_forward(name: str, params, x, interpret: bool = True):
    """Batched inference for one microservice: (B, in_dim) -> (B, out_dim)."""
    return batched_mlp.mlp(x, params, activation="relu", interpret=interpret)


def microservice_forward_ref(name: str, params, x):
    """Pure-jnp oracle for the same forward (tests & HLO diffing)."""
    from .kernels import ref

    return ref.mlp_ref(x, params, activation="relu")


# ---------------------------------------------------------------------------
# Load predictor networks (paper §4.5.1 / Fig. 6).
#
# Input: WINDOW normalized arrival-rate samples (5 s windows over the last
# 100 s -> 20 samples). Output: predicted (normalized) max arrival rate for
# the next monitoring window.
# ---------------------------------------------------------------------------
WINDOW = 20          # 100 s history / 5 s sampling windows (paper W_s)
LSTM_HIDDEN = 32     # paper: 2 layers x 32 neurons
LSTM_LAYERS = 2


def init_lstm_params(seed: int = 7):
    key = jax.random.PRNGKey(seed)
    params = {"layers": [], "w_out": None, "b_out": None}
    in_dim = 1
    for _ in range(LSTM_LAYERS):
        key, k1, k2 = jax.random.split(key, 3)
        sx = jnp.sqrt(1.0 / max(in_dim, 1))
        sh = jnp.sqrt(1.0 / LSTM_HIDDEN)
        wx = jax.random.normal(k1, (in_dim, 4 * LSTM_HIDDEN), jnp.float32) * sx
        wh = jax.random.normal(k2, (LSTM_HIDDEN, 4 * LSTM_HIDDEN), jnp.float32) * sh
        b = jnp.zeros((4 * LSTM_HIDDEN,), jnp.float32)
        # forget-gate bias init to 1.0 for training stability
        b = b.at[LSTM_HIDDEN : 2 * LSTM_HIDDEN].set(1.0)
        params["layers"].append({"wx": wx, "wh": wh, "b": b})
        in_dim = LSTM_HIDDEN
    key, k3 = jax.random.split(key)
    params["w_out"] = jax.random.normal(k3, (LSTM_HIDDEN, 1), jnp.float32) * 0.1
    params["b_out"] = jnp.zeros((1,), jnp.float32)
    return params


def lstm_forward(params, x, interpret: bool = True):
    """LSTM predictor forward: x (B, WINDOW) -> (B,) forecast.

    Unrolls WINDOW steps of the fused Pallas LSTM cell per layer.
    """
    batch = x.shape[0]
    seq = x.reshape(batch, -1, 1)  # (B, T, 1)
    for layer in params["layers"]:
        h = jnp.zeros((batch, LSTM_HIDDEN), jnp.float32)
        c = jnp.zeros((batch, LSTM_HIDDEN), jnp.float32)
        outs = []
        for t in range(seq.shape[1]):
            h, c = lstm_cell.lstm_cell(
                seq[:, t, :], h, c, layer["wx"], layer["wh"], layer["b"],
                interpret=interpret,
            )
            outs.append(h)
        seq = jnp.stack(outs, axis=1)  # (B, T, H)
    last = seq[:, -1, :]
    out = batched_mlp.dense(
        last, params["w_out"], params["b_out"], activation="none",
        interpret=interpret,
    )
    return out[:, 0]


def lstm_forward_ref(params, x):
    """Pure-jnp oracle for lstm_forward."""
    from .kernels import ref

    batch = x.shape[0]
    seq = x.reshape(batch, -1, 1)
    for layer in params["layers"]:
        h = jnp.zeros((batch, LSTM_HIDDEN), jnp.float32)
        c = jnp.zeros((batch, LSTM_HIDDEN), jnp.float32)
        outs = []
        for t in range(seq.shape[1]):
            h, c = ref.lstm_cell_ref(
                seq[:, t, :], h, c, layer["wx"], layer["wh"], layer["b"]
            )
            outs.append(h)
        seq = jnp.stack(outs, axis=1)
    last = seq[:, -1, :]
    return (jnp.dot(last, params["w_out"]) + params["b_out"])[:, 0]


def init_ff_params(seed: int = 11):
    """Simple feed-forward predictor baseline (Fig. 6): WINDOW -> 32 -> 1."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (WINDOW, 32), jnp.float32) * jnp.sqrt(2.0 / WINDOW)
    b1 = jnp.zeros((32,), jnp.float32)
    w2 = jax.random.normal(k2, (32, 1), jnp.float32) * jnp.sqrt(2.0 / 32)
    b2 = jnp.zeros((1,), jnp.float32)
    return [(w1, b1), (w2, b2)]


def ff_forward(params, x, interpret: bool = True):
    """Feed-forward predictor: (B, WINDOW) -> (B,)."""
    return batched_mlp.mlp(x, params, activation="relu", interpret=interpret)[:, 0]


def ff_forward_ref(params, x):
    from .kernels import ref

    return ref.mlp_ref(x, params, activation="relu")[:, 0]
