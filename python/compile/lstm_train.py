"""Train Fifer's load predictors (build-time only).

Reproduces paper §4.5.1: the LSTM (2 layers x 32 units) and the simple
feed-forward baseline are pre-trained on 60% of the WITS arrival trace; the
remaining 40% is the test set scored in Fig. 6. Training uses the pure-jnp
reference forward (bit-identical math to the Pallas kernels, which are
validated against it by pytest) so the unrolled-gradient loop stays fast;
aot.py bakes the trained weights into the exported Pallas-kerneled HLO.

Outputs (under artifacts/):
  predictor_weights.json  — trained LSTM + FF weights and the normalization
                            scale, consumed by aot.py and by the Rust-native
                            predictor (cross-checked against the artifact).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model, traces


def _tree_map2(f, a, b):
    return jax.tree_util.tree_map(f, a, b)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = _tree_map2(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = _tree_map2(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = _tree_map2(
        lambda p, u: p - lr * u,
        params,
        _tree_map2(lambda mh, vh: mh / (jnp.sqrt(vh) + eps), mh, vh),
    )
    return params, {"m": m, "v": v, "t": t}


def train_lstm(x_train, y_train, epochs: int = 30, batch: int = 64, lr: float = 1e-2,
               seed: int = 7, verbose: bool = True):
    params = model.init_lstm_params(seed)

    def loss_fn(p, xb, yb):
        pred = model.lstm_forward_ref(p, xb)
        return jnp.mean((pred - yb) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = adam_init(params)
    n = len(x_train)
    rng = np.random.default_rng(seed)
    for epoch in range(epochs):
        order = rng.permutation(n)
        total = 0.0
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            loss, grads = grad_fn(params, x_train[idx], y_train[idx])
            params, opt = adam_step(params, grads, opt, lr=lr)
            total += float(loss)
        if verbose and (epoch % 5 == 0 or epoch == epochs - 1):
            print(f"[lstm] epoch {epoch:3d} loss {total / max(1, n // batch):.5f}")
    return params


def train_ff(x_train, y_train, epochs: int = 60, batch: int = 64, lr: float = 5e-3,
             seed: int = 11, verbose: bool = True):
    params = model.init_ff_params(seed)

    def loss_fn(p, xb, yb):
        pred = model.ff_forward_ref(p, xb)
        return jnp.mean((pred - yb) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = adam_init(params)
    n = len(x_train)
    rng = np.random.default_rng(seed)
    for epoch in range(epochs):
        order = rng.permutation(n)
        total = 0.0
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            loss, grads = grad_fn(params, x_train[idx], y_train[idx])
            params, opt = adam_step(params, grads, opt, lr=lr)
            total += float(loss)
        if verbose and (epoch % 10 == 0 or epoch == epochs - 1):
            print(f"[ff]   epoch {epoch:3d} loss {total / max(1, n // batch):.5f}")
    return params


def rmse(pred, y):
    return float(np.sqrt(np.mean((np.asarray(pred) - np.asarray(y)) ** 2)))


def relative_normalize(x: "np.ndarray", y: "np.ndarray"):
    """Scale-invariant normalization: divide each sample's history and
    target by that history's mean. Lets a model trained on WITS
    (~300 req/s) transfer to any absolute rate (e.g. Poisson λ=50 or
    Wiki ~1500 req/s) — the network only ever sees relative load shapes.
    Returns (xn, yn, m) with m the per-sample means."""
    m = np.clip(x.mean(axis=1, keepdims=True), 1.0, None)
    return x / m, y / m[:, 0], m[:, 0]


def train_all(out_path: str, epochs_lstm: int = 30, epochs_ff: int = 60,
              verbose: bool = True) -> dict:
    """Train both predictors on 60% of the WITS trace; return summary."""
    rate = traces.wits_trace()
    x, y = traces.make_dataset(rate, history=model.WINDOW, horizon=2)
    split = int(0.6 * len(x))  # paper: pre-trained with 60% of the trace
    scale = 1.0  # kept for artifact compat; relative norm makes it moot
    xn, yn, m = relative_normalize(x, y)
    x_tr, y_tr = xn[:split], yn[:split]
    x_te, y_te = xn[split:], yn[split:]

    lstm = train_lstm(x_tr, y_tr, epochs=epochs_lstm, verbose=verbose)
    ff = train_ff(x_tr, y_tr, epochs=epochs_ff, verbose=verbose)

    m_te = m[split:]
    lstm_pred = np.asarray(model.lstm_forward_ref(lstm, x_te)) * m_te
    ff_pred = np.asarray(model.ff_forward_ref(ff, x_te)) * m_te
    actual_te = y[split:]
    summary = {
        "scale": scale,
        "train_samples": int(split),
        "test_samples": int(len(x) - split),
        "lstm_rmse": rmse(lstm_pred, actual_te),
        "ff_rmse": rmse(ff_pred, actual_te),
    }
    if verbose:
        print(f"[eval] LSTM test RMSE {summary['lstm_rmse']:.1f} req/s, "
              f"FF test RMSE {summary['ff_rmse']:.1f} req/s "
              f"(trace avg {rate.mean():.0f}, peak {rate.max():.0f})")

    blob = {
        "scale": scale,
        "norm": "relative",
        "window": model.WINDOW,
        "hidden": model.LSTM_HIDDEN,
        "layers": [
            {
                "wx": np.asarray(l["wx"]).tolist(),
                "wh": np.asarray(l["wh"]).tolist(),
                "b": np.asarray(l["b"]).tolist(),
            }
            for l in lstm["layers"]
        ],
        "w_out": np.asarray(lstm["w_out"]).tolist(),
        "b_out": np.asarray(lstm["b_out"]).tolist(),
        "ff": [
            {"w": np.asarray(w).tolist(), "b": np.asarray(b).tolist()}
            for (w, b) in ff
        ],
        "summary": summary,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(blob, f)
    if verbose:
        print(f"[out] wrote {out_path}")
    return summary


def load_weights(path: str):
    """Load trained weights back into model params pytrees."""
    with open(path) as f:
        blob = json.load(f)
    lstm = {
        "layers": [
            {
                "wx": jnp.asarray(l["wx"], jnp.float32),
                "wh": jnp.asarray(l["wh"], jnp.float32),
                "b": jnp.asarray(l["b"], jnp.float32),
            }
            for l in blob["layers"]
        ],
        "w_out": jnp.asarray(blob["w_out"], jnp.float32),
        "b_out": jnp.asarray(blob["b_out"], jnp.float32),
    }
    ff = [
        (jnp.asarray(l["w"], jnp.float32), jnp.asarray(l["b"], jnp.float32))
        for l in blob["ff"]
    ]
    return lstm, ff, float(blob["scale"])


if __name__ == "__main__":
    train_all("../artifacts/predictor_weights.json")
