"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes and batch sizes; assert_allclose against ref is the
core correctness signal for everything the Rust runtime will execute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import batched_mlp, lstm_cell, ref

jax.config.update("jax_platform_name", "cpu")

ACTIVATIONS = ["relu", "tanh", "gelu", "none"]


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# dense kernel
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(ACTIVATIONS),
)
def test_dense_matches_ref(m, k, n, act):
    x = _rand(m * 7 + 1, (m, k))
    w = _rand(k * 13 + 2, (k, n))
    b = _rand(n * 17 + 3, (n,))
    got = batched_mlp.dense(x, w, b, activation=act)
    want = ref.dense_ref(x, w, b, activation=act)
    assert got.shape == (m, n)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (130, 200, 257), (1, 2048, 64)])
def test_dense_block_boundaries(m, k, n):
    """Exercise exact-tile, ragged-tile, and single-row shapes."""
    x = _rand(1, (m, k))
    w = _rand(2, (k, n))
    b = _rand(3, (n,))
    got = batched_mlp.dense(x, w, b, activation="relu")
    want = ref.dense_ref(x, w, b, activation="relu")
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 64), (128, 128), (256, 128)])
def test_dense_block_shape_sweep(bm, bn):
    """Any legal block shape must give identical numerics (perf knob only)."""
    x, w, b = _rand(4, (33, 70)), _rand(5, (70, 41)), _rand(6, (41,))
    got = batched_mlp.dense(x, w, b, activation="gelu", block_m=bm, block_n=bn)
    want = ref.dense_ref(x, w, b, activation="gelu")
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_rejects_bad_shapes():
    x, w, b = _rand(1, (4, 8)), _rand(2, (9, 3)), _rand(3, (3,))
    with pytest.raises(AssertionError):
        batched_mlp.dense(x, w, b)


def test_dense_bias_broadcast_and_zero_input():
    x = jnp.zeros((5, 12), jnp.float32)
    w = _rand(8, (12, 7))
    b = jnp.arange(7, dtype=jnp.float32)
    got = batched_mlp.dense(x, w, b, activation="none")
    assert_allclose(np.asarray(got), np.tile(np.arange(7, dtype=np.float32), (5, 1)))


# ---------------------------------------------------------------------------
# mlp composition
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    batch=st.integers(1, 16),
    dims=st.lists(st.integers(1, 48), min_size=2, max_size=5),
)
def test_mlp_matches_ref(batch, dims):
    params = []
    for i in range(len(dims) - 1):
        params.append((_rand(i * 3 + 1, (dims[i], dims[i + 1])), _rand(i * 3 + 2, (dims[i + 1],))))
    x = _rand(99, (batch, dims[0]))
    got = batched_mlp.mlp(x, params)
    want = ref.mlp_ref(x, params)
    assert got.shape == (batch, dims[-1])
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mlp_final_layer_is_linear():
    """The last layer must have no activation (can go negative)."""
    params = [(-jnp.ones((4, 4), jnp.float32), jnp.zeros((4,), jnp.float32))]
    x = jnp.ones((2, 4), jnp.float32)
    out = np.asarray(batched_mlp.mlp(x, params))
    assert (out < 0).all()


# ---------------------------------------------------------------------------
# lstm cell kernel
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 8),
    in_dim=st.integers(1, 16),
    hidden=st.integers(1, 48),
)
def test_lstm_cell_matches_ref(batch, in_dim, hidden):
    x = _rand(1, (batch, in_dim))
    h = _rand(2, (batch, hidden))
    c = _rand(3, (batch, hidden))
    wx = _rand(4, (in_dim, 4 * hidden)) * 0.3
    wh = _rand(5, (hidden, 4 * hidden)) * 0.3
    b = _rand(6, (4 * hidden,)) * 0.1
    h2, c2 = lstm_cell.lstm_cell(x, h, c, wx, wh, b)
    h2r, c2r = ref.lstm_cell_ref(x, h, c, wx, wh, b)
    assert_allclose(np.asarray(h2), np.asarray(h2r), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(c2), np.asarray(c2r), rtol=1e-5, atol=1e-5)


def test_lstm_cell_state_bounds():
    """h' = o * tanh(c') must stay in (-1, 1)."""
    x = _rand(1, (4, 2)) * 10
    h = _rand(2, (4, 8)) * 10
    c = _rand(3, (4, 8)) * 10
    wx = _rand(4, (2, 32))
    wh = _rand(5, (8, 32))
    b = _rand(6, (32,))
    h2, _ = lstm_cell.lstm_cell(x, h, c, wx, wh, b)
    assert np.abs(np.asarray(h2)).max() <= 1.0


def test_lstm_cell_forget_gate_saturation():
    """With f ~= 1 and i ~= 0, the cell state must carry through."""
    batch, hidden = 2, 4
    x = jnp.zeros((batch, 1), jnp.float32)
    h = jnp.zeros((batch, hidden), jnp.float32)
    c = jnp.full((batch, hidden), 0.7, jnp.float32)
    wx = jnp.zeros((1, 4 * hidden), jnp.float32)
    wh = jnp.zeros((hidden, 4 * hidden), jnp.float32)
    b = jnp.concatenate([
        jnp.full((hidden,), -20.0),  # i -> 0
        jnp.full((hidden,), 20.0),   # f -> 1
        jnp.zeros((hidden,)),        # g
        jnp.full((hidden,), 20.0),   # o -> 1
    ]).astype(jnp.float32)
    h2, c2 = lstm_cell.lstm_cell(x, h, c, wx, wh, b)
    assert_allclose(np.asarray(c2), 0.7 * np.ones((batch, hidden)), rtol=1e-5)
    assert_allclose(np.asarray(h2), np.tanh(0.7) * np.ones((batch, hidden)), rtol=1e-4)


# ---------------------------------------------------------------------------
# perf model helpers
# ---------------------------------------------------------------------------
def test_vmem_budget_for_paper_models():
    """Every (block, K) combination used by the catalog fits VMEM (16 MiB)."""
    from compile import model as m

    for name, (in_dim, hidden, out_dim, _) in m.MICROSERVICES.items():
        dims = [in_dim] + hidden + [out_dim]
        for k in dims[:-1]:
            assert batched_mlp.vmem_bytes(128, 128, k) < 16 * 2**20, name


def test_mxu_utilization_bounds():
    assert batched_mlp.mxu_utilization(128, 128, 128) == 1.0
    u = batched_mlp.mxu_utilization(1, 10, 64)
    assert 0.0 < u <= 1.0
