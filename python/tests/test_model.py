"""Layer-2 correctness: microservice models and predictor nets vs oracles."""
import jax
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", sorted(model.MICROSERVICES))
def test_microservice_forward_shape_and_ref(name):
    in_dim, _, out_dim = model.layer_dims(name)
    params = model.init_mlp_params(name)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, in_dim))
    got = model.microservice_forward(name, params, x)
    want = model.microservice_forward_ref(name, params, x)
    assert got.shape == (2, out_dim)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_params_deterministic():
    a = model.init_mlp_params("IMC")
    b = model.init_mlp_params("IMC")
    for (w1, b1), (w2, b2) in zip(a, b):
        assert_allclose(np.asarray(w1), np.asarray(w2))
        assert_allclose(np.asarray(b1), np.asarray(b2))


def test_chain_catalog_consistent():
    """Every chain stage exists; Table 4 slack < SLO; exec sums sane."""
    for name, (stages, slack) in model.CHAINS.items():
        assert 0 < slack < model.SLO_MS, name
        total_exec = 0.0
        for s in stages:
            assert s in model.MICROSERVICES, f"{name} references unknown {s}"
            total_exec += model.MICROSERVICES[s][3]
        assert total_exec + 1e-9 < model.SLO_MS, name


def test_detect_fatigue_stage1_dominates():
    """Paper Fig. 3a: HS is ~81% of DetectFatigue's execution time."""
    stages, _ = model.CHAINS["DetectFatigue"]
    times = [model.MICROSERVICES[s][3] for s in stages]
    assert times[0] / sum(times) > 0.75


@pytest.mark.parametrize("batch", [1, 3])
def test_lstm_forward_matches_ref(batch):
    params = model.init_lstm_params()
    x = jax.random.uniform(jax.random.PRNGKey(3), (batch, model.WINDOW))
    got = model.lstm_forward(params, x)
    want = model.lstm_forward_ref(params, x)
    assert got.shape == (batch,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ff_forward_matches_ref():
    params = model.init_ff_params()
    x = jax.random.uniform(jax.random.PRNGKey(4), (5, model.WINDOW))
    got = model.ff_forward(params, x)
    want = model.ff_forward_ref(params, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_lstm_param_topology():
    """Paper §4.5.1: 2 layers x 32 neurons."""
    p = model.init_lstm_params()
    assert len(p["layers"]) == 2
    assert p["layers"][0]["wx"].shape == (1, 4 * 32)
    assert p["layers"][1]["wx"].shape == (32, 4 * 32)
    assert p["w_out"].shape == (32, 1)
