"""AOT pipeline: HLO-text round-trip, weight binaries, manifest integrity."""
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    def fn(x):
        return (jnp.tanh(x) * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text


def test_lower_microservice_has_params():
    """Weights must be runtime parameters, not baked constants."""
    params = model.init_mlp_params("POS")
    hlo = aot.lower_microservice("POS", 2, params)
    assert "HloModule" in hlo
    # 1 hidden layer -> w1,b1,w2,b2 + x = 5 parameters
    n_params = hlo.count("parameter(")
    assert n_params >= 5, f"expected >=5 HLO parameters, got {n_params}"


def test_write_weights_bin_roundtrip(tmp_path):
    params = model.init_mlp_params("NER")
    path = tmp_path / "ner.bin"
    layers = aot.write_weights_bin(str(path), params)
    raw = path.read_bytes()
    total = sum(
        int(np.prod(l["w"])) + int(np.prod(l["b"])) for l in layers
    )
    assert len(raw) == 4 * total
    # first float must equal params[0] w[0,0] in little-endian f32
    first = struct.unpack("<f", raw[:4])[0]
    assert abs(first - float(np.asarray(params[0][0])[0, 0])) < 1e-6


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_integrity():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m["slo_ms"] == 1000.0
    assert set(m["chains"]) == set(model.CHAINS)
    for name, entry in m["microservices"].items():
        for b, fname in entry["batches"].items():
            assert os.path.exists(os.path.join(ART, fname)), fname
        wpath = os.path.join(ART, entry["weights"]["path"])
        assert os.path.exists(wpath)
        total = sum(
            int(np.prod(l["w"])) + int(np.prod(l["b"]))
            for l in entry["weights"]["layers"]
        )
        assert os.path.getsize(wpath) == 4 * total, name
    for p in m["predictors"].values():
        assert os.path.exists(os.path.join(ART, p["path"]))
    for t in m["traces"].values():
        assert os.path.exists(os.path.join(ART, t["path"]))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "predictor_weights.json")),
    reason="artifacts not built",
)
def test_trained_lstm_beats_naive_baseline():
    """The trained LSTM must out-predict 'last value' persistence on WITS."""
    from compile import lstm_train, traces

    lstm_w, _, _scale = lstm_train.load_weights(
        os.path.join(ART, "predictor_weights.json")
    )
    rate = traces.wits_trace()
    x, y = traces.make_dataset(rate, history=model.WINDOW, horizon=2)
    split = int(0.6 * len(x))
    xn, _, m = lstm_train.relative_normalize(x, y)
    x_te, y_te, m_te = xn[split:], y[split:], m[split:]
    pred = np.asarray(model.lstm_forward_ref(lstm_w, x_te)) * m_te
    lstm_rmse = np.sqrt(np.mean((pred - y_te) ** 2))
    naive_rmse = np.sqrt(np.mean((x[split:, -1] - y_te) ** 2))
    assert lstm_rmse < naive_rmse, (lstm_rmse, naive_rmse)
