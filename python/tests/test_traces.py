"""Trace generators must match the statistics the paper publishes (§5.3)."""
import numpy as np

from compile import traces


def test_wits_statistics():
    rate = traces.wits_trace()
    # paper: average ~240-300 req/s, peak ~1200 req/s, peak ~5x median
    assert 200 <= rate.mean() <= 360, rate.mean()
    assert 1000 <= rate.max() <= 1500, rate.max()
    assert rate.max() / np.median(rate) >= 3.5
    assert (rate >= 1.0).all()


def test_wiki_statistics():
    rate = traces.wiki_trace()
    # paper: average ~1500 req/s, recurring pattern, no extreme spikes
    assert 1200 <= rate.mean() <= 1800, rate.mean()
    assert rate.max() / np.median(rate) <= 2.5  # diurnal, not bursty
    # recurring pattern: strong autocorrelation at the 600 s harmonic
    r = rate - rate.mean()
    lag = 600
    ac = np.corrcoef(r[:-lag], r[lag:])[0, 1]
    assert ac > 0.3, ac


def test_traces_deterministic():
    a, b = traces.wits_trace(), traces.wits_trace()
    np.testing.assert_allclose(a, b)


def test_window_maxima():
    rate = np.arange(20, dtype=float)
    w = traces.window_maxima(rate, window_s=5)
    np.testing.assert_allclose(w, [4, 9, 14, 19])


def test_window_maxima_includes_tail_window():
    # regression: the trailing partial window used to be dropped
    rate = np.arange(22, dtype=float)
    w = traces.window_maxima(rate, window_s=5)
    np.testing.assert_allclose(w, [4, 9, 14, 19, 21])


def test_make_dataset_shapes_and_alignment():
    rate = traces.wits_trace(duration_s=600)
    x, y = traces.make_dataset(rate, history=20, horizon=2)
    assert x.shape[1] == 20
    assert len(x) == len(y)
    # target is the max of the next two windows after the history
    w = traces.window_maxima(rate, 5)
    np.testing.assert_allclose(y[0], w[20:22].max())
    np.testing.assert_allclose(x[0], w[:20])
