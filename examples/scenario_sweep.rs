//! Drive the scenario subsystem programmatically: parse an inline
//! scenario, run the sweep sharded across threads, and print/compare
//! the deterministic JSON output.
//!
//! ```text
//! cargo run --release --example scenario_sweep
//! ```

use anyhow::Result;
use fifer::scenario::{self, ScenarioSpec};

fn main() -> Result<()> {
    // a small matrix: one composed trace x one mix x two RMs x two seeds
    let spec = ScenarioSpec::parse(
        r#"
[scenario]
name = "sweep-demo"
duration_s = 120
seeds = [7, 42]
traces = ["crowd"]
mixes = ["Heavy"]
policies = ["Bline", "Fifer"]

[cluster]
preset = "prototype"

[trace.crowd]
expr = "overlay(poisson(rate=30), flashcrowd(amp=120, start=40, width=20))"
"#,
    )?;

    println!(
        "running {} cells serially, then on 4 threads...",
        spec.cells().len()
    );
    let serial = scenario::run_scenario(&spec, 1)?;
    let parallel = scenario::run_scenario(&spec, 4)?;

    // sharding never changes results: the output is byte-identical
    let a = scenario::results_json(&spec, &serial).to_string();
    let b = scenario::results_json(&spec, &parallel).to_string();
    assert_eq!(a, b, "parallel sweep must equal serial sweep");

    for r in &serial {
        println!(
            "{:>6} seed {:>2}: {} jobs, {:.2}% SLO violations, p99 {:.0} ms, {:.1} containers",
            r.cell.policy.name(),
            r.cell.seed,
            r.summary.jobs,
            r.summary.slo_violation_pct,
            r.summary.p99_ms,
            r.summary.avg_containers,
        );
    }
    println!("\nCSV:\n{}", scenario::results_csv(&serial));
    Ok(())
}
