//! Predictor playground: score every Fig. 6 model on any trace, and
//! cross-check the rust-native LSTM forward against the PJRT artifact.
//!
//! ```bash
//! cargo run --release --example predictor_playground -- --trace wits
//! ```

use anyhow::Result;
use fifer::bench::Table;
use fifer::cli::Args;
use fifer::experiments::TraceKind;
use fifer::predictor::{all_predictors, evaluate, nn::LstmPredictor};
use fifer::runtime::Runtime;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let kind = match args.str_or("trace", "wits").as_str() {
        "wiki" => TraceKind::Wiki,
        "poisson" => TraceKind::Poisson,
        _ => TraceKind::Wits,
    };
    let art = args.str_or("artifacts", "artifacts");
    let trace = kind.build(4000, &art);
    let w = trace.window_maxima(5);
    println!(
        "trace {}: avg {:.0} req/s, peak {:.0} req/s, {} windows",
        kind.name(),
        trace.avg_rate(),
        trace.peak_rate(),
        w.len()
    );

    let weights = std::path::Path::new(&art).join("predictor_weights.json");
    let wp = weights.exists().then_some(weights.clone());
    let mut t = Table::new(&["model", "RMSE", "latency µs", "accuracy %"]);
    for p in all_predictors(wp.as_deref()).iter_mut() {
        let r = evaluate(p.as_mut(), &w, 2, 0.15);
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.rmse),
            format!("{:.1}", r.latency_us),
            format!("{:.1}", r.accuracy_pct),
        ]);
    }
    t.print();

    // cross-check: rust-native forward vs the AOT-compiled XLA artifact
    if weights.exists() {
        let native = LstmPredictor::load(&weights)?;
        let mut rt = Runtime::new(std::path::Path::new(&art))?;
        let xs: Vec<f32> = (0..native.window).map(|i| 0.4 + 0.02 * i as f32).collect();
        let a = native.forward(&xs);
        let b = rt.predict("lstm", &xs)?;
        println!(
            "\nLSTM forward cross-check: native={a:.6} pjrt={b:.6} (|Δ|={:.2e})",
            (a - b).abs()
        );
        assert!((a - b).abs() < 1e-4, "native and PJRT forwards diverge");
    }
    Ok(())
}
