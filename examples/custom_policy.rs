//! A user-defined scheduler policy, plugged into the simulator without
//! touching crate internals: implement `SchedulerPolicy`, hand a boxed
//! instance to `run_sim_with`, done. No registry edit, no engine edit.
//!
//! The policy here ("QueueDepth") is deliberately simple — a target-
//! tracking autoscaler that sizes each stage's pool to its queue depth
//! at every monitor tick — but it exercises the full hook surface
//! contract: decisions read only the `PolicyView` snapshot and return a
//! `ScalingPlan` for the engine to execute.
//!
//! ```bash
//! cargo run --release --example custom_policy
//! ```

use fifer::config::{Policy, SystemConfig};
use fifer::coordinator::policy::{PolicyView, ScalingPlan, SchedulerPolicy};
use fifer::coordinator::queue::Ordering as QueueOrdering;
use fifer::model::{Catalog, MsId};
use fifer::sim::{run_sim_with, SimParams};
use fifer::trace::Trace;

/// Spawn one container per `per_container` queued requests, per stage,
/// on every monitor tick. Idle containers are reclaimed by the default
/// `on_scan` after the configured idle timeout.
struct QueueDepth {
    per_container: usize,
}

impl SchedulerPolicy for QueueDepth {
    fn name(&self) -> &'static str {
        "QueueDepth"
    }

    fn queue_order(&self) -> QueueOrdering {
        QueueOrdering::Fifo
    }

    fn batching(&self) -> bool {
        true
    }

    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        let per = self.per_container.max(1);
        let spawns: Vec<(MsId, usize)> = view
            .stages
            .iter()
            .filter_map(|&ms_id| {
                // ceil(pending / per) containers wanted, minus live ones
                let want = (view.pending(ms_id) + per - 1) / per;
                let spawn = want.saturating_sub(view.live(ms_id));
                (spawn > 0).then_some((ms_id, spawn))
            })
            .collect();
        ScalingPlan {
            spawns,
            stop_on_full: false,
        }
    }
}

fn main() {
    let cat = Catalog::paper();
    // config still names a registered policy (it seeds RmConfig knobs);
    // the trait object we pass below is what actually schedules
    let mut cfg = SystemConfig::prototype(Policy::Fifer);
    cfg.rm.idle_timeout_s = 120.0;
    let params = SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(20.0, 120),
        drain_s: 40.0,
    };

    let policy = QueueDepth { per_container: 4 };
    let (rec, sum) = run_sim_with(params, Box::new(policy));

    println!(
        "QueueDepth policy, Poisson λ=20, 120 s:\n  \
         jobs={} slo-violations={:.2}% median={:.0}ms p99={:.0}ms\n  \
         avg-containers={:.1} spawned={} cold-starts={} energy={:.1}Wh",
        sum.jobs,
        sum.slo_violation_pct,
        sum.median_ms,
        sum.p99_ms,
        sum.avg_containers,
        sum.total_spawned,
        sum.cold_starts,
        sum.energy_wh
    );
    assert_eq!(rec.jobs.len() as u64, sum.jobs);
}
