//! Quickstart: the smallest end-to-end use of the Fifer public API.
//!
//! 1. Load the AOT artifacts and run one real batched inference via PJRT.
//! 2. Build the slack plan for a workload mix (Eq. 1 batch sizes).
//! 3. Run a short simulation of the Fifer RM and print the summary.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use fifer::config::{Policy, SystemConfig};
use fifer::coordinator::slack::SlackPlan;
use fifer::experiments::{run_policy, TraceKind};
use fifer::model::Catalog;
use fifer::runtime::Runtime;

fn main() -> Result<()> {
    let cat = Catalog::paper();

    // --- 1. real inference through an AOT artifact --------------------
    let art = std::path::Path::new("artifacts");
    if art.join("manifest.json").exists() {
        let mut rt = Runtime::new(art)?;
        let x: Vec<f32> = (0..4 * 256).map(|i| (i % 7) as f32 * 0.1).collect();
        let t0 = std::time::Instant::now();
        let out = rt.infer("FACER", 4, &x)?;
        println!(
            "FACER batch-4 inference: {} outputs in {:.2} ms (first={:.4})",
            out.len(),
            t0.elapsed().as_secs_f64() * 1e3,
            out[0]
        );
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the live path)");
    }

    // --- 2. the slack plan -------------------------------------------
    let cfg = SystemConfig::prototype(Policy::Fifer);
    let mix = cat.mix("Heavy").unwrap().clone();
    let plan = SlackPlan::build(&cat, &mix.chains, &cfg.rm, true);
    println!("\nper-stage batch sizes (Eq. 1), heavy mix:");
    for &ms_id in &cat.mix_stages(&mix) {
        println!(
            "  {:<6} exec {:>6.1} ms -> batch {}",
            cat.microservices[ms_id].name,
            cat.microservices[ms_id].exec_ms_mean,
            plan.batch_for(ms_id)
        );
    }

    // --- 3. a short simulation ----------------------------------------
    let run = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 300, true, 42);
    let s = &run.summary;
    println!(
        "\nFifer, Poisson λ=50, 300 s: {} jobs, {:.2}% SLO violations, \
         median {:.0} ms, p99 {:.0} ms, {:.1} containers avg",
        s.jobs, s.slo_violation_pct, s.median_ms, s.p99_ms, s.avg_containers
    );
    Ok(())
}
