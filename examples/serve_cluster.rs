//! End-to-end live serving driver (the docs/DESIGN.md validation workload).
//!
//! Loads the real AOT-compiled microservice models, serves Poisson
//! traffic for the heavy workload mix through Fifer's slack-based
//! batcher, and reports latency/throughput — with a batching-off
//! (Bline-style) run for comparison. Everything on the request path is
//! Rust + PJRT; Python was only involved at `make artifacts` time.
//!
//! ```bash
//! make artifacts && cargo build --release
//! cargo run --release --example serve_cluster -- --rate 30 --duration 20
//! ```

use anyhow::Result;
use fifer::cli::Args;
use fifer::config::{Policy, RmConfig};
use fifer::server::{serve, ServeParams, ServeReport};

fn report(tag: &str, r: &ServeReport) {
    println!(
        "{tag:>12}: {} jobs, {:.1} req/s, median {:.0} ms, p99 {:.0} ms, \
         {:.2}% SLO violations, {} batches (avg size {:.2}), {} cold compiles",
        r.jobs,
        r.throughput_rps,
        r.median_ms,
        r.p99_ms,
        r.slo_violation_pct,
        r.batches,
        r.avg_batch,
        r.cold_compiles
    );
    let mut rows: Vec<_> = r.stage_exec_ms.iter().collect();
    rows.sort_by_key(|(name, _)| **name);
    for (stage, ms) in rows {
        println!("              {stage:<6} mean batch exec {ms:>8.2} ms");
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let rate = args.f64_or("rate", 25.0)?;
    let duration = args.f64_or("duration", 15.0)?;
    let executors = args.usize_or("executors", 2)?;

    println!("== Fifer live cluster: heavy mix (IPA + DetectFatigue) ==");
    println!("rate {rate} req/s for {duration} s, {executors} executor thread(s)\n");

    let mut fifer = ServeParams::quick(rate, duration);
    fifer.executors = executors;
    let r1 = serve(fifer)?;
    report("Fifer", &r1);

    let mut bline = ServeParams::quick(rate, duration);
    bline.executors = executors;
    bline.cfg.rm = RmConfig::paper(Policy::Bline); // batching off via policy
    let r2 = serve(bline)?;
    report("no-batching", &r2);

    println!(
        "\nbatching amortization: {:.2}x fewer model invocations \
         ({} vs {} batches for ~the same jobs)",
        r2.batches as f64 / r1.batches.max(1) as f64,
        r1.batches,
        r2.batches
    );
    Ok(())
}
