//! End-to-end live serving driver (the docs/DESIGN.md validation workload).
//!
//! Serves Poisson traffic for the heavy workload mix through the
//! real-time driver: the registered policy spawns, batches onto, and
//! retires real executor threads ("containers") through the same
//! `coordinator::engine` core as the simulator — with a batching-off
//! (Bline-style) run for comparison. With `make artifacts` the executors
//! run real PJRT inference; pass `--synthetic` to run the modeled
//! executor backend anywhere:
//!
//! ```bash
//! cargo run --release --example serve_cluster -- --rate 25 --duration 15 --synthetic
//! ```

use anyhow::Result;
use fifer::cli::Args;
use fifer::config::{Policy, RmConfig};
use fifer::server::{serve, ServeParams, ServeReport};

fn report(tag: &str, r: &ServeReport) {
    let s = &r.summary;
    println!(
        "{tag:>12}: {} jobs, {:.1} req/s, median {:.0} ms, p99 {:.0} ms, \
         {:.2}% SLO violations, {} batches (avg size {:.2}), \
         {} containers spawned ({} reclaimed), {} cold compiles",
        s.jobs,
        r.throughput_rps,
        s.median_ms,
        s.p99_ms,
        s.slo_violation_pct,
        r.batches,
        r.avg_batch,
        s.total_spawned,
        r.recorder.reclaimed,
        r.cold_compiles
    );
    let mut rows: Vec<_> = r.stage_exec_ms.iter().collect();
    rows.sort_by_key(|(name, _)| **name);
    for (stage, ms) in rows {
        println!("              {stage:<6} mean batch exec {ms:>8.2} ms");
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let rate = args.f64_or("rate", 25.0)?;
    let duration = args.f64_or("duration", 15.0)?;
    let executors = args.usize_or("executors", 12)?;
    let synthetic = args.flag("synthetic");

    println!("== Fifer live cluster: heavy mix (IPA + DetectFatigue) ==");
    println!(
        "rate {rate} req/s for {duration} s, up to {executors} container thread(s), {} backend\n",
        if synthetic { "synthetic" } else { "PJRT" }
    );

    let quick = |policy: Policy| {
        let mut p = ServeParams::quick(rate, duration);
        p.executors = executors;
        p.synthetic = synthetic;
        p.cfg.rm = RmConfig::paper(policy);
        // a tight monitor loop keeps reactive scaling responsive over
        // short demo runs (the paper's T = 10 s suits long traces)
        p.cfg.rm.monitor_interval_s = 1.0;
        p.cfg.rm.sample_window_s = 1.0;
        p
    };

    let r1 = serve(quick(Policy::Fifer))?;
    report("Fifer", &r1);

    let r2 = serve(quick(Policy::Bline))?; // batching off via policy
    report("no-batching", &r2);

    println!(
        "\nbatching amortization: {:.2}x fewer executor invocations \
         ({} vs {} batches for ~the same jobs)",
        r2.batches as f64 / r1.batches.max(1) as f64,
        r1.batches,
        r2.batches
    );
    Ok(())
}
