//! Large-scale trace-driven simulation (paper §6.2) from the public API.
//!
//! Runs all five RMs on the WITS- or Wiki-like trace against the
//! 2500-core simulated cluster and prints the Fig. 14/15-style rows.
//!
//! ```bash
//! cargo run --release --example trace_sim -- --trace wits --duration 1200
//! ```

use anyhow::Result;
use fifer::bench::{norm, Table};
use fifer::cli::Args;
use fifer::config::Policy;
use fifer::experiments::{run_macro, TraceKind};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let kind = match args.str_or("trace", "wits").as_str() {
        "wiki" => TraceKind::Wiki,
        _ => TraceKind::Wits,
    };
    let duration = args.usize_or("duration", 1200)?;
    let mix = args.str_or("mix", "Heavy");

    println!(
        "== {} trace, {} mix, {duration} s, 2500-core simulated cluster ==",
        kind.name(),
        mix
    );
    let t0 = std::time::Instant::now();
    let runs = run_macro(kind, &mix, duration, 42);
    let base = runs
        .iter()
        .find(|r| r.policy == Policy::Bline)
        .unwrap()
        .summary
        .clone();

    let mut t = Table::new(&[
        "policy",
        "SLO viol %",
        "avg containers",
        "norm. to Bline",
        "median ms",
        "tail (p99) ms",
        "cold starts",
    ]);
    for r in &runs {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.2}", r.summary.slo_violation_pct),
            format!("{:.0}", r.summary.avg_containers),
            norm(r.summary.avg_containers, base.avg_containers),
            format!("{:.0}", r.summary.median_ms),
            format!("{:.0}", r.summary.p99_ms),
            format!("{}", r.summary.cold_starts),
        ]);
    }
    t.print();
    println!(
        "({} sim-jobs total, wall {:.0} s)",
        runs.iter().map(|r| r.summary.jobs).sum::<u64>(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
