//! Cluster state store: containers, nodes, placement (§4.4, §5.1).
//!
//! This is the in-process substitute for the paper's MongoDB statistics
//! store (container free-slots, lastUsedTime, batch size — §5.1) plus the
//! Kubernetes scheduler's view of node resources. Two greedy policies live
//! here:
//!
//! * **Container selection** — submit a request to the warm container with
//!   the *least remaining free slots* (§4.4.1), which drains lightly-loaded
//!   containers and enables early scale-in.
//! * **Node selection** — place new containers on the node with the
//!   *least available cores* that still fits (the paper's modified
//!   `MostRequestedPriority`, §4.4.2 / §5.1), consolidating for energy.
//!
//! # Indexed layout
//!
//! Both greedy decisions sit on the request critical path (one
//! `pick_container` per dispatched request, one `pick_node` per spawn), so
//! the store is *incrementally indexed* rather than scanned:
//!
//! * Containers live in a **slab arena** (`slots` + `free_list`). A
//!   container id encodes `(spawn_seq << 32) | slot`, so ids stay unique
//!   and monotone in spawn order (the dispatch tie-breaker) while slots are
//!   reused; a stale id aimed at a recycled slot fails the `id` check
//!   instead of aliasing the new tenant.
//! * `StageTable::ready` — one `BTreeSet<(free_slots, Reverse(node
//!   containers), id)>` per stage holding exactly the warm containers with
//!   at least one free slot. `pick_container` is its first element:
//!   O(log n) maintenance, O(log n) query, instead of an O(pool) scan.
//! * `node_index` — a `BTreeSet<(free_cores_bits, node_id)>` over all
//!   nodes. `pick_node` is a range query from the required share upward:
//!   the first hit is the most-packed node that still fits.
//! * `StageTable::{warm_free, starting, live}` — running aggregates so
//!   `warm_free_slots` / `starting_slots` / `stage_containers` are O(1)
//!   (the `Monitor` tick reads them for every stage every interval).
//! * `StageTable::idle` and the global `idle_lru` — ordered
//!   `(last_used, id)` sets over idle-and-empty containers, so
//!   `idle_since` walks only its result set and `lru_idle_since` is the
//!   first element.
//! * `node_busy` counts Busy containers per node, and
//!   `Node::{containers, alloc_cores}` are **derived from the container
//!   count** (count × `cpu_per_container`) at every transition — never by
//!   repeated f64 subtraction, which drifted on long runs.
//!
//! # Index invariants (checked by [`StateStore::check_consistency`])
//!
//! For every live container `c` in slot `s`:
//!
//! * `s.stage_key = Some((c.free_slots(), Reverse(node.containers), c.id))`
//!   iff `c.is_warm() && c.free_slots() > 0`, and that key is present in
//!   `stages[c.ms_id].ready`;
//! * `s.idle_key = Some((c.last_used, c.id))` iff `c.state == Idle &&
//!   c.local.is_empty()`, present in both `stages[c.ms_id].idle` and
//!   `idle_lru`;
//! * `s.warm_free` / `s.starting` / `s.busy` equal `c`'s current
//!   contribution to the per-stage and per-node aggregates.
//!
//! Every mutation goes through the private `refresh` helper (single
//! container) or `refresh_node_members` (node membership change, which
//! shifts the packing tie-breaker of *every* container on that node).
//! The transition points are exactly: [`StateStore::spawn`],
//! [`StateStore::remove`],
//! [`StateStore::dispatch`], [`StateStore::begin_batch`],
//! [`StateStore::finish_batch`], [`StateStore::warm_up`].

use std::cmp::Reverse;
use std::collections::{BTreeSet, VecDeque};

use crate::model::MsId;
use crate::util::Micros;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CState {
    /// Cold-starting; becomes Idle at `ready_at`.
    Starting,
    /// Warm, not executing.
    Idle,
    /// Executing a request (head of `local`).
    Busy,
}

/// One container (Kubernetes pod hosting one microservice instance).
#[derive(Debug, Clone)]
pub struct Container {
    pub id: u64,
    pub ms_id: MsId,
    pub node: usize,
    /// Local-queue capacity = the stage's batch size (free slots counted
    /// against `in_flight()`).
    pub batch_size: usize,
    /// Queued job ids (head is executing when state == Busy).
    pub local: VecDeque<u64>,
    pub state: CState,
    /// Number of head-of-local-queue jobs in the currently executing batch.
    pub cur_batch: usize,
    pub ready_at: Micros,
    /// Spawn latency (cold-start duration) for delay attribution.
    pub spawn_latency: Micros,
    pub started_cold: bool,
    pub last_used: Micros,
    pub jobs_executed: u64,
}

impl Container {
    /// Jobs currently owned by this container (executing + queued).
    #[inline]
    pub fn in_flight(&self) -> usize {
        self.local.len()
    }

    #[inline]
    pub fn free_slots(&self) -> usize {
        self.batch_size.saturating_sub(self.in_flight())
    }

    #[inline]
    pub fn is_warm(&self) -> bool {
        matches!(self.state, CState::Idle | CState::Busy)
    }
}

/// One server (VM / bare-metal node). `containers` is authoritative;
/// `alloc_cores` is kept equal to `containers × cpu_per_container` at
/// every transition (derived, so it cannot drift).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub total_cores: f64,
    pub alloc_cores: f64,
    pub containers: usize,
}

impl Node {
    #[inline]
    pub fn free_cores(&self) -> f64 {
        self.total_cores - self.alloc_cores
    }
}

/// Batch kickoff info returned by [`StateStore::begin_batch`]. The
/// captured job ids land in the caller-provided scratch buffer (the
/// engine reuses one across every batch), so kicking off a batch
/// performs no heap allocation.
#[derive(Debug, Clone, Copy)]
pub struct BatchStart {
    /// Number of jobs captured into this batch (everything queued
    /// locally at kickoff).
    pub len: usize,
    pub ms_id: MsId,
    pub ready_at: Micros,
    pub spawn_latency: Micros,
    pub started_cold: bool,
}

/// Ordering key of the per-stage ready index: least free slots first,
/// then most-packed node, then earliest-spawned container.
type ReadyKey = (usize, Reverse<usize>, u64);
/// Ordering key of the idle-LRU sets: least recently used first.
type IdleKey = (Micros, u64);

/// One container slot plus its cached index contributions (what this
/// container currently adds to each index/aggregate — subtracted before
/// a re-key so updates can never leak stale entries).
#[derive(Debug)]
struct Slot {
    c: Container,
    ready_key: Option<ReadyKey>,
    idle_key: Option<IdleKey>,
    warm_free: usize,
    starting: usize,
    busy: bool,
}

/// Per-stage indexes + running aggregates.
#[derive(Debug, Default)]
struct StageTable {
    /// Warm containers with ≥1 free slot, dispatch order.
    ready: BTreeSet<ReadyKey>,
    /// Idle containers with an empty local queue, LRU order.
    idle: BTreeSet<IdleKey>,
    /// Live containers (warm + starting).
    live: usize,
    /// Σ free_slots over warm containers.
    warm_free: usize,
    /// Σ batch_size over Starting containers.
    starting: usize,
}

/// The state store: all containers + nodes, incrementally indexed.
#[derive(Debug)]
pub struct StateStore {
    slots: Vec<Option<Slot>>,
    free_list: Vec<u32>,
    live_count: usize,
    pub nodes: Vec<Node>,
    /// Container ids hosted per node (re-keyed when the node's packing
    /// count changes, since the dispatch tie-breaker includes it).
    node_members: Vec<BTreeSet<u64>>,
    /// Busy containers per node — feeds `node_loads` without a scan.
    node_busy: Vec<usize>,
    /// (free_cores bit pattern, node id): `pick_node` range-queries this.
    node_index: BTreeSet<(u64, usize)>,
    stages: Vec<StageTable>,
    /// Global idle-LRU set (any stage) for pressure-driven reclaim.
    idle_lru: BTreeSet<IdleKey>,
    pub cpu_per_container: f64,
    next_seq: u64,
}

/// Order-preserving bit pattern for a non-negative f64 (free cores).
#[inline]
fn f64_key(x: f64) -> u64 {
    x.max(0.0).to_bits()
}

#[inline]
fn slot_of(cid: u64) -> usize {
    (cid & 0xffff_ffff) as usize
}

impl StateStore {
    pub fn new(nodes: usize, cores_per_node: usize, cpu_per_container: f64) -> StateStore {
        let mut s = StateStore {
            slots: Vec::new(),
            free_list: Vec::new(),
            live_count: 0,
            nodes: (0..nodes)
                .map(|id| Node {
                    id,
                    total_cores: cores_per_node as f64,
                    alloc_cores: 0.0,
                    containers: 0,
                })
                .collect(),
            node_members: (0..nodes).map(|_| BTreeSet::new()).collect(),
            node_busy: vec![0; nodes],
            node_index: BTreeSet::new(),
            stages: Vec::new(),
            idle_lru: BTreeSet::new(),
            cpu_per_container,
            next_seq: 0,
        };
        for n in &s.nodes {
            s.node_index.insert((f64_key(n.total_cores), n.id));
        }
        s
    }

    fn ensure_stage(&mut self, ms_id: MsId) {
        while self.stages.len() <= ms_id {
            self.stages.push(StageTable::default());
        }
    }

    /// Free cores derived from the container count (never accumulated).
    #[inline]
    fn node_free(&self, node: usize) -> f64 {
        let n = &self.nodes[node];
        n.total_cores - n.containers as f64 * self.cpu_per_container
    }

    /// Move a node to a new container count, updating the packing index
    /// and the derived `alloc_cores`.
    fn set_node_count(&mut self, node: usize, count: usize) {
        let old_key = (f64_key(self.node_free(node)), node);
        self.node_index.remove(&old_key);
        self.nodes[node].containers = count;
        self.nodes[node].alloc_cores = count as f64 * self.cpu_per_container;
        let new_key = (f64_key(self.node_free(node)), node);
        self.node_index.insert(new_key);
    }

    /// Recompute one container's index membership from its current state,
    /// replacing whatever it contributed before. O(log n).
    fn refresh(&mut self, cid: u64) {
        let slot = slot_of(cid);
        let (ms_id, node) = {
            let c = &self.slots[slot].as_ref().expect("refresh of dead slot").c;
            debug_assert_eq!(c.id, cid);
            (c.ms_id, c.node)
        };
        let node_count = self.nodes[node].containers;
        let (
            old_ready,
            old_idle,
            old_warm_free,
            old_starting,
            old_busy,
            new_ready,
            new_idle,
            new_warm_free,
            new_starting,
            new_busy,
        ) = {
            let s = self.slots[slot].as_mut().unwrap();
            let free = s.c.free_slots();
            let warm = s.c.is_warm();
            let new_ready = (warm && free > 0).then_some((free, Reverse(node_count), cid));
            let new_idle = (s.c.state == CState::Idle && s.c.local.is_empty())
                .then_some((s.c.last_used, cid));
            let new_warm_free = if warm { free } else { 0 };
            let new_starting = if s.c.state == CState::Starting {
                s.c.batch_size
            } else {
                0
            };
            let new_busy = s.c.state == CState::Busy;
            (
                std::mem::replace(&mut s.ready_key, new_ready),
                std::mem::replace(&mut s.idle_key, new_idle),
                std::mem::replace(&mut s.warm_free, new_warm_free),
                std::mem::replace(&mut s.starting, new_starting),
                std::mem::replace(&mut s.busy, new_busy),
                new_ready,
                new_idle,
                new_warm_free,
                new_starting,
                new_busy,
            )
        };
        let st = &mut self.stages[ms_id];
        if let Some(k) = old_ready {
            st.ready.remove(&k);
        }
        if let Some(k) = new_ready {
            st.ready.insert(k);
        }
        if let Some(k) = old_idle {
            st.idle.remove(&k);
            self.idle_lru.remove(&k);
        }
        if let Some(k) = new_idle {
            st.idle.insert(k);
            self.idle_lru.insert(k);
        }
        st.warm_free = st.warm_free + new_warm_free - old_warm_free;
        st.starting = st.starting + new_starting - old_starting;
        self.node_busy[node] =
            self.node_busy[node] + new_busy as usize - old_busy as usize;
    }

    /// Re-key the ready-index entries of containers hosted on `node`
    /// after its packing count changed. Only the `Reverse(node
    /// containers)` tie-break component of a ready key embeds that count
    /// — idle keys and aggregates are count-independent — so this touches
    /// exactly the stale entries and nothing else.
    fn refresh_node_members(&mut self, node: usize) {
        let count = self.nodes[node].containers;
        let members: Vec<u64> = self.node_members[node].iter().copied().collect();
        for cid in members {
            let slot = slot_of(cid);
            let (old, new, ms_id) = {
                let s = self.slots[slot].as_mut().unwrap();
                let Some(old) = s.ready_key else { continue };
                let new = (old.0, Reverse(count), old.2);
                if new == old {
                    continue;
                }
                s.ready_key = Some(new);
                (old, new, s.c.ms_id)
            };
            let st = &mut self.stages[ms_id];
            st.ready.remove(&old);
            st.ready.insert(new);
        }
    }

    /// Greedy node selection: lowest-numbered node with the *least free
    /// cores* that still fits one container (§4.4.2). None if cluster
    /// full. O(log nodes) via the packing index.
    pub fn pick_node(&self) -> Option<usize> {
        let thresh = f64_key(self.cpu_per_container - 1e-9);
        self.node_index
            .range((thresh, 0)..)
            .next()
            .map(|&(_, id)| id)
    }

    /// Spawn a container (Starting until `ready_at`). Returns its id, or
    /// None when no node has capacity.
    pub fn spawn(
        &mut self,
        ms_id: MsId,
        batch_size: usize,
        now: Micros,
        spawn_latency: Micros,
        cold: bool,
    ) -> Option<u64> {
        let node = self.pick_node()?;
        self.ensure_stage(ms_id);
        let slot = match self.free_list.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.next_seq += 1;
        let id = (self.next_seq << 32) | slot as u64;
        let ready_at = now + spawn_latency;
        self.slots[slot] = Some(Slot {
            c: Container {
                id,
                ms_id,
                node,
                batch_size: batch_size.max(1),
                // full local-queue capacity up front: dispatch into this
                // container never reallocates
                local: VecDeque::with_capacity(batch_size.max(1)),
                state: if spawn_latency == 0 {
                    CState::Idle
                } else {
                    CState::Starting
                },
                cur_batch: 0,
                ready_at,
                spawn_latency,
                started_cold: cold,
                last_used: now,
                jobs_executed: 0,
            },
            ready_key: None,
            idle_key: None,
            warm_free: 0,
            starting: 0,
            busy: false,
        });
        self.live_count += 1;
        self.stages[ms_id].live += 1;
        self.node_members[node].insert(id);
        let count = self.nodes[node].containers;
        self.set_node_count(node, count + 1);
        // establish the newcomer's index entries, then re-key its
        // neighbours' packing tie-breaks
        self.refresh(id);
        self.refresh_node_members(node);
        Some(id)
    }

    /// Remove a container and release its node resources.
    pub fn remove(&mut self, cid: u64) -> Option<Container> {
        let slot = slot_of(cid);
        match self.slots.get(slot)?.as_ref() {
            Some(s) if s.c.id == cid => {}
            _ => return None,
        }
        let s = self.slots[slot].take().unwrap();
        let (ms_id, node) = (s.c.ms_id, s.c.node);
        let st = &mut self.stages[ms_id];
        if let Some(k) = s.ready_key {
            st.ready.remove(&k);
        }
        if let Some(k) = s.idle_key {
            st.idle.remove(&k);
            self.idle_lru.remove(&k);
        }
        st.warm_free -= s.warm_free;
        st.starting -= s.starting;
        st.live -= 1;
        self.node_busy[node] -= s.busy as usize;
        self.node_members[node].remove(&cid);
        let count = self.nodes[node].containers;
        self.set_node_count(node, count - 1);
        self.refresh_node_members(node);
        self.live_count -= 1;
        self.free_list.push(slot as u32);
        Some(s.c)
    }

    /// Greedy container selection (§4.4.1): among warm containers of this
    /// stage with at least one free slot, pick the one with the least
    /// remaining free slots; ties prefer containers on the *most packed*
    /// node. Work funnels onto crowded nodes, so containers on sparse
    /// nodes idle out first and their nodes can power off — the
    /// consolidation that drives the paper's Fig. 13 energy savings.
    /// O(log n): first element of the stage's ready index.
    #[inline]
    pub fn pick_container(&self, ms_id: MsId) -> Option<u64> {
        self.stages
            .get(ms_id)?
            .ready
            .iter()
            .next()
            .map(|&(_, _, id)| id)
    }

    /// Queue a request on a container and mark it used. Returns whether
    /// the container was Idle (i.e. the caller should kick off a batch).
    /// The container must be warm with a free slot — dispatch targets come
    /// from [`StateStore::pick_container`].
    #[inline]
    pub fn dispatch(&mut self, cid: u64, job_id: u64, now: Micros) -> bool {
        let slot = slot_of(cid);
        let was_idle = {
            let s = self.slots[slot].as_mut().expect("dispatch to dead container");
            debug_assert_eq!(s.c.id, cid);
            debug_assert!(s.c.is_warm() && s.c.free_slots() > 0);
            s.c.local.push_back(job_id);
            s.c.last_used = now;
            s.c.state == CState::Idle
        };
        self.refresh(cid);
        was_idle
    }

    /// Begin executing everything queued locally as one batch (continuous
    /// batching). Transitions Idle → Busy; the captured job ids replace
    /// the contents of `jobs` (a caller-owned scratch buffer, so the hot
    /// path reuses one allocation across every batch).
    pub fn begin_batch(&mut self, cid: u64, jobs: &mut Vec<u64>) -> BatchStart {
        let slot = slot_of(cid);
        let start = {
            let s = self.slots[slot].as_mut().expect("begin_batch on dead container");
            debug_assert_eq!(s.c.id, cid);
            debug_assert_eq!(s.c.state, CState::Idle);
            debug_assert_eq!(s.c.cur_batch, 0);
            s.c.state = CState::Busy;
            s.c.cur_batch = s.c.local.len();
            jobs.clear();
            jobs.extend(s.c.local.iter().copied());
            BatchStart {
                len: jobs.len(),
                ms_id: s.c.ms_id,
                ready_at: s.c.ready_at,
                spawn_latency: s.c.spawn_latency,
                started_cold: s.c.started_cold,
            }
        };
        self.refresh(cid);
        start
    }

    /// Complete the executing batch: drain its jobs into `jobs`
    /// (replacing its contents — same scratch-buffer contract as
    /// [`StateStore::begin_batch`]), transition Busy → Idle, mark used.
    /// Returns the stage.
    pub fn finish_batch(&mut self, cid: u64, now: Micros, jobs: &mut Vec<u64>) -> MsId {
        let slot = slot_of(cid);
        let ms_id = {
            let s = self.slots[slot].as_mut().expect("finish_batch on dead container");
            debug_assert_eq!(s.c.id, cid);
            debug_assert_eq!(s.c.state, CState::Busy);
            let n = s.c.cur_batch;
            jobs.clear();
            jobs.extend(s.c.local.drain(..n));
            s.c.cur_batch = 0;
            s.c.jobs_executed += jobs.len() as u64;
            s.c.last_used = now;
            s.c.state = CState::Idle;
            s.c.ms_id
        };
        self.refresh(cid);
        ms_id
    }

    /// Cold start finished: Starting → Idle. Returns the stage, or None
    /// if the container was reclaimed (or its slot recycled) meanwhile —
    /// or is no longer Starting (a duplicate/late warm-up notification
    /// must never yank a dispatched container back to Idle).
    pub fn warm_up(&mut self, cid: u64, now: Micros) -> Option<MsId> {
        let slot = slot_of(cid);
        let ms_id = match self.slots.get_mut(slot)?.as_mut() {
            Some(s) if s.c.id == cid && s.c.state == CState::Starting => {
                s.c.state = CState::Idle;
                s.c.last_used = now;
                s.c.ms_id
            }
            _ => return None,
        };
        self.refresh(cid);
        Some(ms_id)
    }

    /// Total free slots across warm containers of a stage. O(1).
    #[inline]
    pub fn warm_free_slots(&self, ms_id: MsId) -> usize {
        self.stages.get(ms_id).map(|s| s.warm_free).unwrap_or(0)
    }

    /// Slots that will come online from still-starting containers. O(1).
    #[inline]
    pub fn starting_slots(&self, ms_id: MsId) -> usize {
        self.stages.get(ms_id).map(|s| s.starting).unwrap_or(0)
    }

    /// Live container count for a stage (warm + starting). O(1).
    #[inline]
    pub fn stage_containers(&self, ms_id: MsId) -> usize {
        self.stages.get(ms_id).map(|s| s.live).unwrap_or(0)
    }

    /// Idle containers of a stage unused since before `cutoff`, oldest
    /// first. O(log n + |result|): a prefix of the stage's idle-LRU set,
    /// yielded lazily so callers decide whether to collect.
    pub fn idle_since(&self, ms_id: MsId, cutoff: Micros) -> impl Iterator<Item = u64> + '_ {
        self.stages.get(ms_id).into_iter().flat_map(move |s| {
            s.idle
                .iter()
                .take_while(move |&&(t, _)| t < cutoff)
                .map(|&(_, id)| id)
        })
    }

    /// Globally least-recently-used idle container (any stage). Used for
    /// eviction when the cluster is full but a stage with pending work has
    /// no capacity — the same pressure-driven reclaim a real cluster
    /// scheduler performs on idle pods.
    pub fn lru_idle(&self) -> Option<u64> {
        self.lru_idle_since(Micros::MAX)
    }

    /// LRU idle container last used before `cutoff` (grace-period variant:
    /// only containers idle "long enough" are eviction victims). O(log n).
    #[inline]
    pub fn lru_idle_since(&self, cutoff: Micros) -> Option<u64> {
        match self.idle_lru.iter().next() {
            Some(&(t, id)) if t < cutoff => Some(id),
            _ => None,
        }
    }

    /// (busy_cores, alloc_cores) of one node — feeds the energy model.
    /// O(1) from the per-node counters; no container scan.
    #[inline]
    pub fn node_load(&self, node: usize) -> (f64, f64) {
        (
            self.node_busy[node] as f64 * self.cpu_per_container,
            self.nodes[node].containers as f64 * self.cpu_per_container,
        )
    }

    /// (busy_cores, alloc_cores) per node. O(nodes); allocates — the
    /// settle loop uses [`StateStore::node_load`] per index instead.
    pub fn node_loads(&self) -> Vec<(f64, f64)> {
        (0..self.nodes.len()).map(|i| self.node_load(i)).collect()
    }

    /// Total containers alive.
    #[inline]
    pub fn total_containers(&self) -> usize {
        self.live_count
    }

    /// Total provisioned cores across all nodes (drained tombstones
    /// contribute 0). The sharded rebalancer reads this as a shard's
    /// capacity denominator.
    pub fn capacity_cores(&self) -> f64 {
        self.nodes.iter().map(|n| n.total_cores).sum()
    }

    /// Whether a node hosts no containers (warm, starting, or busy).
    #[inline]
    pub fn node_is_empty(&self, node: usize) -> bool {
        self.node_members[node].is_empty()
    }

    /// Append a new node with `cores` capacity, returning its id. Used
    /// by the sharded rebalancer to accept capacity migrated from
    /// another shard; nodes are only ever appended (never removed), so
    /// container `node` indices and the dense per-node aggregate vectors
    /// stay valid.
    pub fn add_node(&mut self, cores: f64) -> usize {
        let id = self.nodes.len();
        let cores = cores.max(0.0);
        self.nodes.push(Node {
            id,
            total_cores: cores,
            alloc_cores: 0.0,
            containers: 0,
        });
        self.node_members.push(BTreeSet::new());
        self.node_busy.push(0);
        self.node_index.insert((f64_key(cores), id));
        id
    }

    /// Drain a node's capacity to zero, returning the cores taken. The
    /// node stays in place as a zero-capacity tombstone (so indices stay
    /// dense and `check_consistency` invariants hold); with zero free
    /// cores it can never be picked for placement again. Refuses nodes
    /// that currently host containers — migration must never strand a
    /// running container's resources.
    pub fn drain_node(&mut self, node: usize) -> Result<f64, String> {
        if node >= self.nodes.len() {
            return Err(format!("drain_node: no node {node}"));
        }
        if !self.node_members[node].is_empty() {
            return Err(format!(
                "drain_node: node {node} hosts {} container(s)",
                self.node_members[node].len()
            ));
        }
        let cores = self.nodes[node].total_cores;
        let old_key = (f64_key(self.node_free(node)), node);
        self.node_index.remove(&old_key);
        self.nodes[node].total_cores = 0.0;
        self.node_index.insert((f64_key(0.0), node));
        Ok(cores)
    }

    /// Look up a live container by id (None for removed/recycled ids).
    #[inline]
    pub fn get(&self, cid: u64) -> Option<&Container> {
        self.slots
            .get(slot_of(cid))?
            .as_ref()
            .filter(|s| s.c.id == cid)
            .map(|s| &s.c)
    }

    /// Iterate live containers in slot order (deterministic).
    pub fn iter(&self) -> impl Iterator<Item = &Container> {
        self.slots.iter().filter_map(|s| s.as_ref().map(|s| &s.c))
    }

    /// Ids of all live containers in slot order (deterministic).
    pub fn container_ids(&self) -> Vec<u64> {
        self.iter().map(|c| c.id).collect()
    }

    /// Validate every index and aggregate against a from-scratch recompute
    /// of the documented invariants. O(pool); test/debug use only.
    pub fn check_consistency(&self) -> Result<(), String> {
        let mut live = 0usize;
        let mut stage_live = vec![0usize; self.stages.len()];
        let mut stage_warm_free = vec![0usize; self.stages.len()];
        let mut stage_starting = vec![0usize; self.stages.len()];
        let mut stage_ready = vec![0usize; self.stages.len()];
        let mut stage_idle = vec![0usize; self.stages.len()];
        let mut node_count = vec![0usize; self.nodes.len()];
        let mut node_busy = vec![0usize; self.nodes.len()];
        for (slot, entry) in self.slots.iter().enumerate() {
            let Some(s) = entry else { continue };
            let c = &s.c;
            live += 1;
            if slot_of(c.id) != slot {
                return Err(format!("container {} stored in wrong slot {slot}", c.id));
            }
            if c.ms_id >= self.stages.len() {
                return Err(format!("container {} has unindexed stage {}", c.id, c.ms_id));
            }
            if c.local.len() > c.batch_size {
                return Err(format!("container {} over batch capacity", c.id));
            }
            let st = &self.stages[c.ms_id];
            let want_ready = (c.is_warm() && c.free_slots() > 0)
                .then_some((c.free_slots(), Reverse(self.nodes[c.node].containers), c.id));
            if s.ready_key != want_ready {
                return Err(format!("container {} has stale ready key", c.id));
            }
            if let Some(k) = want_ready {
                if !st.ready.contains(&k) {
                    return Err(format!("container {} missing from ready index", c.id));
                }
                stage_ready[c.ms_id] += 1;
            }
            let want_idle = (c.state == CState::Idle && c.local.is_empty())
                .then_some((c.last_used, c.id));
            if s.idle_key != want_idle {
                return Err(format!("container {} has stale idle key", c.id));
            }
            if let Some(k) = want_idle {
                if !st.idle.contains(&k) || !self.idle_lru.contains(&k) {
                    return Err(format!("container {} missing from idle sets", c.id));
                }
                stage_idle[c.ms_id] += 1;
            }
            let warm_free = if c.is_warm() { c.free_slots() } else { 0 };
            let starting = if c.state == CState::Starting {
                c.batch_size
            } else {
                0
            };
            if s.warm_free != warm_free || s.starting != starting
                || s.busy != (c.state == CState::Busy)
            {
                return Err(format!("container {} has stale aggregate cache", c.id));
            }
            stage_live[c.ms_id] += 1;
            stage_warm_free[c.ms_id] += warm_free;
            stage_starting[c.ms_id] += starting;
            node_count[c.node] += 1;
            node_busy[c.node] += (c.state == CState::Busy) as usize;
            if !self.node_members[c.node].contains(&c.id) {
                return Err(format!("container {} missing from node members", c.id));
            }
        }
        if live != self.live_count {
            return Err(format!("live count {} != {}", self.live_count, live));
        }
        let idle_total: usize = stage_idle.iter().sum();
        if self.idle_lru.len() != idle_total {
            return Err("idle_lru holds stale entries".into());
        }
        for (ms, st) in self.stages.iter().enumerate() {
            if st.live != stage_live[ms]
                || st.warm_free != stage_warm_free[ms]
                || st.starting != stage_starting[ms]
            {
                return Err(format!("stage {ms} aggregates drifted"));
            }
            if st.ready.len() != stage_ready[ms] || st.idle.len() != stage_idle[ms] {
                return Err(format!("stage {ms} index holds stale entries"));
            }
        }
        if self.node_index.len() != self.nodes.len() {
            return Err("node index cardinality drifted".into());
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.containers != node_count[i] {
                return Err(format!("node {i} container count drifted"));
            }
            let alloc = node_count[i] as f64 * self.cpu_per_container;
            if (n.alloc_cores - alloc).abs() > 1e-12 {
                return Err(format!("node {i} alloc_cores not derived from count"));
            }
            if self.node_busy[i] != node_busy[i] {
                return Err(format!("node {i} busy count drifted"));
            }
            if self.node_members[i].len() != node_count[i] {
                return Err(format!("node {i} member set drifted"));
            }
            if !self.node_index.contains(&(f64_key(self.node_free(i)), i)) {
                return Err(format!("node {i} missing from packing index"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> StateStore {
        StateStore::new(2, 2, 0.5) // 2 nodes x 2 cores, 4 containers/node
    }

    #[test]
    fn spawn_places_greedily() {
        let mut s = store();
        // first container goes to node 0 (tie -> lowest id)
        let a = s.spawn(0, 4, 0, 1000, true).unwrap();
        assert_eq!(s.get(a).unwrap().node, 0);
        // node 0 now has less free capacity -> next goes there too
        let b = s.spawn(0, 4, 0, 1000, true).unwrap();
        assert_eq!(s.get(b).unwrap().node, 0);
        s.check_consistency().unwrap();
    }

    #[test]
    fn cluster_capacity_enforced() {
        let mut s = store();
        let mut spawned = Vec::new();
        while let Some(cid) = s.spawn(0, 1, 0, 0, false) {
            spawned.push(cid);
        }
        assert_eq!(spawned.len(), 8); // 2 nodes * 2 cores / 0.5
        assert!(s.pick_node().is_none());
        // removing frees capacity
        s.remove(spawned[0]);
        assert!(s.pick_node().is_some());
        s.check_consistency().unwrap();
    }

    #[test]
    fn pick_container_least_free_slots() {
        let mut s = store();
        let a = s.spawn(3, 4, 0, 0, false).unwrap();
        let b = s.spawn(3, 4, 0, 0, false).unwrap();
        s.dispatch(a, 101, 0);
        s.dispatch(a, 102, 0);
        s.dispatch(b, 103, 0);
        // a has 2 free, b has 3 free -> pick a
        assert_eq!(s.pick_container(3), Some(a));
        // fill a completely -> pick b
        s.dispatch(a, 104, 0);
        s.dispatch(a, 105, 0);
        assert_eq!(s.pick_container(3), Some(b));
        s.check_consistency().unwrap();
    }

    #[test]
    fn starting_containers_not_pickable() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 5_000_000, true).unwrap();
        assert_eq!(s.get(a).unwrap().state, CState::Starting);
        assert_eq!(s.pick_container(1), None);
        assert_eq!(s.warm_free_slots(1), 0);
        assert_eq!(s.starting_slots(1), 2);
        // warm it up
        assert_eq!(s.warm_up(a, 5_000_000), Some(1));
        assert_eq!(s.pick_container(1), Some(a));
        assert_eq!(s.warm_free_slots(1), 2);
        s.check_consistency().unwrap();
    }

    #[test]
    fn zero_latency_spawn_is_warm() {
        let mut s = store();
        let a = s.spawn(1, 2, 100, 0, false).unwrap();
        assert_eq!(s.get(a).unwrap().state, CState::Idle);
    }

    #[test]
    fn idle_reclaim_candidates() {
        let mut s = store();
        let mut jobs = Vec::new();
        let a = s.spawn(1, 2, 100, 0, false).unwrap();
        let b = s.spawn(1, 2, 900, 0, false).unwrap();
        let idle: Vec<u64> = s.idle_since(1, 500).collect();
        assert_eq!(idle, vec![a]);
        assert_eq!(s.lru_idle_since(500), Some(a));
        assert_eq!(s.lru_idle(), Some(a));
        // busy containers are never reclaimed
        s.dispatch(a, 7, 200);
        let start = s.begin_batch(a, &mut jobs);
        assert_eq!((start.len, jobs.as_slice()), (1, &[7][..]));
        assert_eq!(s.idle_since(1, 500).count(), 0);
        // ... and return to the LRU set once drained
        let ms = s.finish_batch(a, 300, &mut jobs);
        assert_eq!((ms, jobs.as_slice()), (1, &[7][..]));
        assert_eq!(s.idle_since(1, 500).collect::<Vec<u64>>(), vec![a]);
        let _ = b;
        s.check_consistency().unwrap();
    }

    #[test]
    fn node_loads_track_busy() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        let _b = s.spawn(1, 2, 0, 0, false).unwrap();
        s.dispatch(a, 1, 0);
        s.begin_batch(a, &mut Vec::new());
        let loads = s.node_loads();
        assert_eq!(loads[0], (0.5, 1.0));
        assert_eq!(loads[1], (0.0, 0.0));
        assert_eq!(s.node_load(0), loads[0]);
        assert_eq!(s.node_load(1), loads[1]);
        s.check_consistency().unwrap();
    }

    #[test]
    fn remove_cleans_indexes() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        assert_eq!(s.stage_containers(1), 1);
        let c = s.remove(a).unwrap();
        assert_eq!(c.id, a);
        assert_eq!(s.stage_containers(1), 0);
        assert_eq!(s.nodes[0].containers, 0);
        assert_eq!(s.nodes[0].alloc_cores, 0.0);
        assert!(s.remove(a).is_none());
        assert!(s.get(a).is_none());
        assert_eq!(s.pick_container(1), None);
        assert_eq!(s.warm_free_slots(1), 0);
        assert!(s.lru_idle().is_none());
        s.check_consistency().unwrap();
    }

    #[test]
    fn recycled_slot_rejects_stale_id() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 1000, true).unwrap();
        s.remove(a);
        // slot is reused by b, but a's id must not alias it
        let b = s.spawn(2, 2, 0, 0, false).unwrap();
        assert_ne!(a, b);
        assert!(s.get(a).is_none());
        assert_eq!(s.warm_up(a, 1000), None); // stale SpawnDone is a no-op
        assert_eq!(s.get(b).unwrap().ms_id, 2);
        s.check_consistency().unwrap();
    }

    #[test]
    fn dispatch_reports_idle_state() {
        let mut s = store();
        let a = s.spawn(1, 3, 0, 0, false).unwrap();
        assert!(s.dispatch(a, 1, 10)); // was idle -> caller starts a batch
        s.begin_batch(a, &mut Vec::new());
        assert!(!s.dispatch(a, 2, 20)); // busy -> just queue
        s.check_consistency().unwrap();
    }

    #[test]
    fn packing_tiebreak_prefers_crowded_node() {
        // same free slots everywhere -> the container on the fuller node
        // wins the dispatch (consolidation tie-break)
        let mut s = StateStore::new(2, 2, 1.0); // 2 container slots per node
        let a = s.spawn(1, 4, 0, 0, false).unwrap();
        let b = s.spawn(1, 4, 0, 0, false).unwrap(); // both on node 0
        let c = s.spawn(1, 4, 0, 0, false).unwrap(); // node 0 full -> node 1
        assert_eq!(s.get(a).unwrap().node, 0);
        assert_eq!(s.get(b).unwrap().node, 0);
        assert_eq!(s.get(c).unwrap().node, 1);
        // node 0 hosts 2 containers vs node 1's one -> a (earliest on the
        // crowded node) wins
        assert_eq!(s.pick_container(1), Some(a));
        // removing b levels the packing (1 vs 1) -> tie broken by
        // earliest-spawned container id, and c's re-keyed entry must
        // reflect node 0's new count
        s.remove(b);
        assert_eq!(s.pick_container(1), Some(a));
        s.remove(a);
        assert_eq!(s.pick_container(1), Some(c));
        s.check_consistency().unwrap();
    }

    #[test]
    fn drain_refuses_occupied_node_and_tombstones_empty_ones() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        assert_eq!(s.get(a).unwrap().node, 0);
        // node 0 hosts a container -> migration must refuse it
        assert!(s.drain_node(0).is_err());
        assert!(s.drain_node(99).is_err());
        // node 1 is empty -> drains to a zero-capacity tombstone
        assert!(s.node_is_empty(1));
        assert_eq!(s.drain_node(1).unwrap(), 2.0);
        assert_eq!(s.nodes[1].total_cores, 0.0);
        assert_eq!(s.capacity_cores(), 2.0);
        s.check_consistency().unwrap();
        // a tombstone is never picked for placement
        for _ in 0..4 {
            let cid = s.spawn(1, 1, 0, 0, false).unwrap();
            assert_eq!(s.get(cid).unwrap().node, 0);
        }
        assert!(s.pick_node().is_none());
        s.check_consistency().unwrap();
    }

    #[test]
    fn add_node_extends_capacity_in_place() {
        let mut s = store();
        assert_eq!(s.capacity_cores(), 4.0);
        let id = s.add_node(2.0);
        assert_eq!(id, 2);
        assert_eq!(s.capacity_cores(), 6.0);
        s.check_consistency().unwrap();
        // fill the original nodes, then placement spills onto the newcomer
        let mut spawned = Vec::new();
        while let Some(cid) = s.spawn(0, 1, 0, 0, false) {
            spawned.push(cid);
        }
        assert_eq!(spawned.len(), 12); // 6 cores / 0.5
        assert!(spawned.iter().any(|&c| s.get(c).unwrap().node == id));
        // drain -> add round-trips capacity exactly
        for cid in spawned {
            s.remove(cid);
        }
        let cores = s.drain_node(id).unwrap();
        assert_eq!(cores, 2.0);
        let id2 = s.add_node(cores);
        assert_eq!(id2, 3);
        assert_eq!(s.capacity_cores(), 6.0);
        s.check_consistency().unwrap();
    }

    #[test]
    fn alloc_cores_never_drift() {
        // regression for the float-drift bug: alloc_cores is derived from
        // the container count, so long spawn/remove churn stays exact
        let mut s = StateStore::new(3, 7, 0.3);
        let mut live: Vec<u64> = Vec::new();
        for round in 0..5000u64 {
            if round % 3 != 0 {
                if let Some(cid) = s.spawn((round % 4) as usize, 2, round, 0, false) {
                    live.push(cid);
                }
            } else if !live.is_empty() {
                let cid = live.remove((round as usize * 7) % live.len());
                s.remove(cid);
            }
        }
        for cid in live {
            s.remove(cid);
        }
        for n in &s.nodes {
            assert_eq!(n.alloc_cores, 0.0, "node {} leaked cores", n.id);
            assert_eq!(n.containers, 0);
        }
        s.check_consistency().unwrap();
    }
}
