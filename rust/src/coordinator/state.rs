//! Cluster state store: containers, nodes, placement (§4.4, §5.1).
//!
//! This is the in-process substitute for the paper's MongoDB statistics
//! store (container free-slots, lastUsedTime, batch size — §5.1) plus the
//! Kubernetes scheduler's view of node resources. Two greedy policies live
//! here:
//!
//! * **Container selection** — submit a request to the warm container with
//!   the *least remaining free slots* (§4.4.1), which drains lightly-loaded
//!   containers and enables early scale-in.
//! * **Node selection** — place new containers on the node with the
//!   *least available cores* that still fits (the paper's modified
//!   `MostRequestedPriority`, §4.4.2 / §5.1), consolidating for energy.

use std::collections::{HashMap, VecDeque};

use crate::model::MsId;
use crate::util::Micros;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CState {
    /// Cold-starting; becomes Idle at `ready_at`.
    Starting,
    /// Warm, not executing.
    Idle,
    /// Executing a request (head of `local`).
    Busy,
}

/// One container (Kubernetes pod hosting one microservice instance).
#[derive(Debug, Clone)]
pub struct Container {
    pub id: u64,
    pub ms_id: MsId,
    pub node: usize,
    /// Local-queue capacity = the stage's batch size (free slots counted
    /// against `in_flight()`).
    pub batch_size: usize,
    /// Queued job ids (head is executing when state == Busy).
    pub local: VecDeque<u64>,
    pub state: CState,
    /// Number of head-of-local-queue jobs in the currently executing batch.
    pub cur_batch: usize,
    pub ready_at: Micros,
    /// Spawn latency (cold-start duration) for delay attribution.
    pub spawn_latency: Micros,
    pub started_cold: bool,
    pub last_used: Micros,
    pub jobs_executed: u64,
}

impl Container {
    /// Jobs currently owned by this container (executing + queued).
    pub fn in_flight(&self) -> usize {
        self.local.len()
    }

    pub fn free_slots(&self) -> usize {
        self.batch_size.saturating_sub(self.in_flight())
    }

    pub fn is_warm(&self) -> bool {
        matches!(self.state, CState::Idle | CState::Busy)
    }
}

/// One server (VM / bare-metal node).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub total_cores: f64,
    pub alloc_cores: f64,
    pub containers: usize,
}

impl Node {
    pub fn free_cores(&self) -> f64 {
        self.total_cores - self.alloc_cores
    }
}

/// The state store: all containers + nodes, indexed per stage.
#[derive(Debug)]
pub struct StateStore {
    pub containers: HashMap<u64, Container>,
    /// Container ids per microservice (the per-stage pool).
    pub by_stage: HashMap<MsId, Vec<u64>>,
    pub nodes: Vec<Node>,
    pub cpu_per_container: f64,
    next_cid: u64,
}

impl StateStore {
    pub fn new(nodes: usize, cores_per_node: usize, cpu_per_container: f64) -> StateStore {
        StateStore {
            containers: HashMap::new(),
            by_stage: HashMap::new(),
            nodes: (0..nodes)
                .map(|id| Node {
                    id,
                    total_cores: cores_per_node as f64,
                    alloc_cores: 0.0,
                    containers: 0,
                })
                .collect(),
            cpu_per_container,
            next_cid: 1,
        }
    }

    /// Greedy node selection: lowest-numbered node with the *least free
    /// cores* that still fits one container (§4.4.2). None if cluster full.
    pub fn pick_node(&self) -> Option<usize> {
        let need = self.cpu_per_container;
        self.nodes
            .iter()
            .filter(|n| n.free_cores() >= need - 1e-9)
            .min_by(|a, b| {
                a.free_cores()
                    .partial_cmp(&b.free_cores())
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            })
            .map(|n| n.id)
    }

    /// Spawn a container (Starting until `ready_at`). Returns its id, or
    /// None when no node has capacity.
    pub fn spawn(
        &mut self,
        ms_id: MsId,
        batch_size: usize,
        now: Micros,
        spawn_latency: Micros,
        cold: bool,
    ) -> Option<u64> {
        let node = self.pick_node()?;
        let id = self.next_cid;
        self.next_cid += 1;
        self.nodes[node].alloc_cores += self.cpu_per_container;
        self.nodes[node].containers += 1;
        let ready_at = now + spawn_latency;
        self.containers.insert(
            id,
            Container {
                id,
                ms_id,
                node,
                batch_size: batch_size.max(1),
                local: VecDeque::new(),
                state: if spawn_latency == 0 {
                    CState::Idle
                } else {
                    CState::Starting
                },
                cur_batch: 0,
                ready_at,
                spawn_latency,
                started_cold: cold,
                last_used: now,
                jobs_executed: 0,
            },
        );
        self.by_stage.entry(ms_id).or_default().push(id);
        Some(id)
    }

    /// Remove a container and release its node resources.
    pub fn remove(&mut self, cid: u64) -> Option<Container> {
        let c = self.containers.remove(&cid)?;
        let node = &mut self.nodes[c.node];
        node.alloc_cores = (node.alloc_cores - self.cpu_per_container).max(0.0);
        node.containers = node.containers.saturating_sub(1);
        if let Some(v) = self.by_stage.get_mut(&c.ms_id) {
            if let Some(pos) = v.iter().position(|&x| x == cid) {
                v.swap_remove(pos);
            }
        }
        Some(c)
    }

    /// Greedy container selection (§4.4.1): among warm containers of this
    /// stage with at least one free slot, pick the one with the least
    /// remaining free slots; ties prefer containers on the *most packed*
    /// node. Work funnels onto crowded nodes, so containers on sparse
    /// nodes idle out first and their nodes can power off — the
    /// consolidation that drives the paper's Fig. 13 energy savings.
    pub fn pick_container(&self, ms_id: MsId) -> Option<u64> {
        let ids = self.by_stage.get(&ms_id)?;
        ids.iter()
            .filter_map(|&id| {
                let c = &self.containers[&id];
                (c.is_warm() && c.free_slots() > 0).then_some((
                    c.free_slots(),
                    std::cmp::Reverse(self.nodes[c.node].containers),
                    id,
                ))
            })
            .min()
            .map(|(_, _, id)| id)
    }

    /// Total free slots across warm containers of a stage.
    pub fn warm_free_slots(&self, ms_id: MsId) -> usize {
        self.by_stage
            .get(&ms_id)
            .map(|ids| {
                ids.iter()
                    .map(|id| {
                        let c = &self.containers[id];
                        if c.is_warm() {
                            c.free_slots()
                        } else {
                            0
                        }
                    })
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Slots that will come online from still-starting containers.
    pub fn starting_slots(&self, ms_id: MsId) -> usize {
        self.by_stage
            .get(&ms_id)
            .map(|ids| {
                ids.iter()
                    .map(|id| {
                        let c = &self.containers[id];
                        if c.state == CState::Starting {
                            c.batch_size
                        } else {
                            0
                        }
                    })
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Live container count for a stage (warm + starting).
    pub fn stage_containers(&self, ms_id: MsId) -> usize {
        self.by_stage.get(&ms_id).map(|v| v.len()).unwrap_or(0)
    }

    /// Idle containers of a stage unused since before `cutoff`.
    pub fn idle_since(&self, ms_id: MsId, cutoff: Micros) -> Vec<u64> {
        self.by_stage
            .get(&ms_id)
            .map(|ids| {
                ids.iter()
                    .filter(|&&id| {
                        let c = &self.containers[&id];
                        c.state == CState::Idle && c.local.is_empty() && c.last_used < cutoff
                    })
                    .copied()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Globally least-recently-used idle container (any stage). Used for
    /// eviction when the cluster is full but a stage with pending work has
    /// no capacity — the same pressure-driven reclaim a real cluster
    /// scheduler performs on idle pods.
    pub fn lru_idle(&self) -> Option<u64> {
        self.lru_idle_since(Micros::MAX)
    }

    /// LRU idle container last used before `cutoff` (grace-period variant:
    /// only containers idle "long enough" are eviction victims).
    pub fn lru_idle_since(&self, cutoff: Micros) -> Option<u64> {
        self.containers
            .values()
            .filter(|c| c.state == CState::Idle && c.local.is_empty() && c.last_used < cutoff)
            .min_by_key(|c| (c.last_used, c.id))
            .map(|c| c.id)
    }

    /// (busy_cores, alloc_cores) per node — feeds the energy model.
    pub fn node_loads(&self) -> Vec<(f64, f64)> {
        let mut loads = vec![(0.0f64, 0.0f64); self.nodes.len()];
        for c in self.containers.values() {
            loads[c.node].1 += self.cpu_per_container;
            if c.state == CState::Busy {
                loads[c.node].0 += self.cpu_per_container;
            }
        }
        loads
    }

    /// Total containers alive.
    pub fn total_containers(&self) -> usize {
        self.containers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> StateStore {
        StateStore::new(2, 2, 0.5) // 2 nodes x 2 cores, 4 containers/node
    }

    #[test]
    fn spawn_places_greedily() {
        let mut s = store();
        // first container goes to node 0 (tie -> lowest id)
        let a = s.spawn(0, 4, 0, 1000, true).unwrap();
        assert_eq!(s.containers[&a].node, 0);
        // node 0 now has less free capacity -> next goes there too
        let b = s.spawn(0, 4, 0, 1000, true).unwrap();
        assert_eq!(s.containers[&b].node, 0);
    }

    #[test]
    fn cluster_capacity_enforced() {
        let mut s = store();
        let mut spawned = 0;
        while s.spawn(0, 1, 0, 0, false).is_some() {
            spawned += 1;
        }
        assert_eq!(spawned, 8); // 2 nodes * 2 cores / 0.5
        assert!(s.pick_node().is_none());
        // removing frees capacity
        let any = *s.containers.keys().next().unwrap();
        s.remove(any);
        assert!(s.pick_node().is_some());
    }

    #[test]
    fn pick_container_least_free_slots() {
        let mut s = store();
        let a = s.spawn(3, 4, 0, 0, false).unwrap();
        let b = s.spawn(3, 4, 0, 0, false).unwrap();
        s.containers.get_mut(&a).unwrap().local.push_back(101);
        s.containers.get_mut(&a).unwrap().local.push_back(102);
        s.containers.get_mut(&b).unwrap().local.push_back(103);
        // a has 2 free, b has 3 free -> pick a
        assert_eq!(s.pick_container(3), Some(a));
        // fill a completely -> pick b
        let ca = s.containers.get_mut(&a).unwrap();
        ca.local.push_back(104);
        ca.local.push_back(105);
        assert_eq!(s.pick_container(3), Some(b));
    }

    #[test]
    fn starting_containers_not_pickable() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 5_000_000, true).unwrap();
        assert_eq!(s.containers[&a].state, CState::Starting);
        assert_eq!(s.pick_container(1), None);
        assert_eq!(s.warm_free_slots(1), 0);
        assert_eq!(s.starting_slots(1), 2);
        // warm it up
        s.containers.get_mut(&a).unwrap().state = CState::Idle;
        assert_eq!(s.pick_container(1), Some(a));
        assert_eq!(s.warm_free_slots(1), 2);
    }

    #[test]
    fn zero_latency_spawn_is_warm() {
        let mut s = store();
        let a = s.spawn(1, 2, 100, 0, false).unwrap();
        assert_eq!(s.containers[&a].state, CState::Idle);
    }

    #[test]
    fn idle_reclaim_candidates() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        let b = s.spawn(1, 2, 0, 0, false).unwrap();
        s.containers.get_mut(&a).unwrap().last_used = 100;
        s.containers.get_mut(&b).unwrap().last_used = 900;
        let idle = s.idle_since(1, 500);
        assert_eq!(idle, vec![a]);
        // busy containers are never reclaimed
        s.containers.get_mut(&a).unwrap().state = CState::Busy;
        assert!(s.idle_since(1, 500).is_empty());
    }

    #[test]
    fn node_loads_track_busy() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        let _b = s.spawn(1, 2, 0, 0, false).unwrap();
        s.containers.get_mut(&a).unwrap().state = CState::Busy;
        let loads = s.node_loads();
        assert_eq!(loads[0], (0.5, 1.0));
        assert_eq!(loads[1], (0.0, 0.0));
    }

    #[test]
    fn remove_cleans_indexes() {
        let mut s = store();
        let a = s.spawn(1, 2, 0, 0, false).unwrap();
        assert_eq!(s.stage_containers(1), 1);
        let c = s.remove(a).unwrap();
        assert_eq!(c.id, a);
        assert_eq!(s.stage_containers(1), 0);
        assert_eq!(s.nodes[0].containers, 0);
        assert_eq!(s.nodes[0].alloc_cores, 0.0);
        assert!(s.remove(a).is_none());
    }
}
