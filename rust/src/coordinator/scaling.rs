//! Container scaling decisions: reactive (RScale, §4.2) and proactive
//! (prediction-driven, §4.5 / Algorithm 1).

/// Dynamic reactive scaling (RScale): decide how many containers a stage
/// needs *right now* given its pending queue.
///
/// Paper §4.2: the queuing-delay threshold for stage S is
/// `D_f = T_d / L` with `T_d = PQ_len · S_r` (time to satisfy all pending
/// requests) and `L = Σ B_size_i` (requests servable within SLO across the
/// N live containers). New containers `N_c = PQ_len / B_size` are spawned
/// only when `D_f > C_d` — i.e. when queuing the backlog on existing
/// containers would take longer than a cold start would.
#[derive(Debug, Clone, Copy)]
pub struct ReactiveDecision {
    /// Estimated queuing delay D_f in ms.
    pub d_f_ms: f64,
    /// Containers to spawn now.
    pub spawn: usize,
}

pub fn reactive_scale(
    pending: usize,
    batch: usize,
    s_r_ms: f64,
    live_containers: usize,
    cold_start_ms: f64,
) -> ReactiveDecision {
    let batch = batch.max(1);
    if pending == 0 {
        return ReactiveDecision {
            d_f_ms: 0.0,
            spawn: 0,
        };
    }
    let l = (live_containers * batch) as f64;
    let t_d = pending as f64 * s_r_ms;
    let d_f = if l > 0.0 { t_d / l } else { f64::INFINITY };
    let spawn = if d_f > cold_start_ms {
        // N_c = PQ_len / B_size as a *target* container count; containers
        // already live (whose slots the backlog is counted against) are
        // subtracted so repeated monitor ticks don't compound the estimate.
        let target = (pending as f64 / batch as f64).ceil() as usize;
        target.saturating_sub(live_containers).max(1)
    } else {
        0
    };
    ReactiveDecision { d_f_ms: d_f, spawn }
}

/// Sustainable per-container service rate (req/s) under batched
/// inference: a container drains `batch` requests per batched pass,
/// where `exec(B) = exec(1) · (1 + γ·(B−1))` (see RmConfig docs).
pub fn container_rate(batch: usize, exec_ms: f64, gamma: f64) -> f64 {
    let b = batch.max(1) as f64;
    let batch_exec_ms = exec_ms.max(1e-6) * (1.0 + gamma * (b - 1.0));
    b * 1000.0 / batch_exec_ms
}

/// Proactive scaling (Algorithm 1b): containers needed so that forecast
/// load fits the per-stage batched service capacity, minus live ones.
pub fn proactive_scale(
    forecast_rate_per_s: f64,
    batch: usize,
    exec_ms: f64,
    gamma: f64,
    live_containers: usize,
) -> usize {
    if forecast_rate_per_s <= 0.0 || exec_ms <= 0.0 {
        return 0;
    }
    let needed = (forecast_rate_per_s / container_rate(batch, exec_ms, gamma)).ceil() as usize;
    needed.saturating_sub(live_containers)
}

/// Cluster-capacity guard (see `RmConfig::max_stage_fraction`): the
/// maximum number of container slots a single stage may hold. Shared by
/// the simulator and any live scaler so both enforce the same ceiling.
pub fn stage_cap(max_containers: usize, max_stage_fraction: f64) -> usize {
    ((max_containers as f64 * max_stage_fraction) as usize).max(1)
}

/// SBatch's fixed pool size (§5.3): sized once from the trace's average
/// arrival rate with a small headroom factor, never scaled after.
pub fn sbatch_pool(
    avg_rate_per_s: f64,
    batch: usize,
    exec_ms: f64,
    gamma: f64,
    headroom: f64,
) -> usize {
    ((avg_rate_per_s / container_rate(batch, exec_ms, gamma)) * headroom)
        .ceil()
        .max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_pending_no_spawn() {
        let d = reactive_scale(0, 4, 100.0, 2, 3000.0);
        assert_eq!(d.spawn, 0);
        assert_eq!(d.d_f_ms, 0.0);
    }

    #[test]
    fn queue_below_coldstart_threshold_queues() {
        // 10 pending, 2 containers x batch 4 -> D_f = 10*100/8 = 125ms
        // cold start 3000ms -> queuing wins, no spawn
        let d = reactive_scale(10, 4, 100.0, 2, 3000.0);
        assert!((d.d_f_ms - 125.0).abs() < 1e-9);
        assert_eq!(d.spawn, 0);
    }

    #[test]
    fn large_backlog_spawns() {
        // 400 pending, 2x4 slots -> D_f = 400*100/8 = 5000ms > 3000ms
        let d = reactive_scale(400, 4, 100.0, 2, 3000.0);
        assert_eq!(d.spawn, 98); // target 400/4 = 100, minus 2 live
    }

    #[test]
    fn triggered_threshold_spawns_at_least_one() {
        // D_f over threshold but live already exceeds the naive target
        let d = reactive_scale(10, 4, 10_000.0, 5, 3000.0);
        assert!(d.d_f_ms > 3000.0);
        assert_eq!(d.spawn, 1);
    }

    #[test]
    fn zero_containers_always_spawns() {
        let d = reactive_scale(1, 4, 100.0, 0, 3000.0);
        assert!(d.d_f_ms.is_infinite());
        assert_eq!(d.spawn, 1);
    }

    #[test]
    fn container_rate_model() {
        // serial: batch 1 -> 1/exec
        assert!((container_rate(1, 100.0, 0.25) - 10.0).abs() < 1e-9);
        // gamma = 1 is serial regardless of batch
        assert!((container_rate(8, 100.0, 1.0) - 10.0).abs() < 1e-9);
        // gamma = 0.25, batch 8: exec(8) = 275ms -> 29.1 req/s
        let r = container_rate(8, 100.0, 0.25);
        assert!((r - 8.0 * 1000.0 / 275.0).abs() < 1e-9);
        // batching never reduces throughput
        assert!(container_rate(32, 100.0, 0.25) > container_rate(1, 100.0, 0.25));
    }

    #[test]
    fn proactive_sizing() {
        // 100 req/s, batch 1, exec 100ms -> 10 req/s/container -> 10
        assert_eq!(proactive_scale(100.0, 1, 100.0, 0.25, 0), 10);
        assert_eq!(proactive_scale(100.0, 1, 100.0, 0.25, 7), 3);
        assert_eq!(proactive_scale(100.0, 1, 100.0, 0.25, 15), 0);
        assert_eq!(proactive_scale(0.0, 1, 100.0, 0.25, 0), 0);
        // batching shrinks the pool
        assert!(
            proactive_scale(100.0, 8, 100.0, 0.25, 0)
                < proactive_scale(100.0, 1, 100.0, 0.25, 0)
        );
    }

    #[test]
    fn stage_cap_floor() {
        assert_eq!(stage_cap(4992, 0.5), 2496);
        assert_eq!(stage_cap(1, 0.1), 1); // never below one container
        assert_eq!(stage_cap(0, 0.5), 1);
    }

    #[test]
    fn sbatch_pool_headroom() {
        // 50 req/s, batch 1, exec 100ms -> 10/s per container -> 5 x1.2 = 6
        assert_eq!(sbatch_pool(50.0, 1, 100.0, 0.25, 1.2), 6);
        assert!(sbatch_pool(0.1, 1, 100.0, 0.25, 1.0) >= 1);
    }
}
