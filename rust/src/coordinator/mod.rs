//! The Fifer coordinator — the paper's system contribution (§4).
//!
//! The coordinator is split into *mechanics* and *policy*:
//!
//! * **Mechanics** (pure, clock-agnostic primitives shared by every RM):
//!   * [`slack`] — slack estimation/distribution + Eq. 1 batch sizing (§4.1)
//!   * [`queue`] — per-stage global queues, LSF ordering (§4.3)
//!   * [`state`] — container/node state store + greedy bin-packing (§4.4)
//!   * [`scaling`] — reactive and proactive scaling math (§4.2/§4.5)
//! * **Policy** ([`policy`]) — the pluggable [`policy::SchedulerPolicy`]
//!   trait: one hook per decision point (queue ordering, predictor
//!   construction, initial provisioning, per-arrival spawning,
//!   monitor-tick scaling, idle reclamation). The paper's five RM
//!   frameworks (Bline/SBatch/RScale/BPred/Fifer), the `Kn` Knative-style
//!   autoscaler, and the `FiferEq` ablation are all plug-ins behind this
//!   trait — the engines contain no per-policy branches.
//!
//! Policies *decide* over a read-only [`policy::PolicyView`] snapshot and
//! return plans; the engine *executes*. Since PR 4 there is exactly one
//! engine: [`engine::EngineCore`] owns the whole control loop (queues,
//! store transitions, slack batching, predictor windows, every policy
//! hook) and is parameterized over a small [`engine::Driver`] that
//! supplies time and effects. The event-driven simulator (`crate::sim`)
//! is the virtual-time driver and the live serving runtime
//! (`crate::server`) is the real-time driver, so a policy written once
//! runs — and makes the *same decisions* — in both worlds (and in yours;
//! see `examples/custom_policy.rs` for a user-defined policy that plugs
//! into `run_sim_with` without touching crate internals).

pub mod engine;
pub mod policy;
pub mod queue;
pub mod scaling;
pub mod sharded;
pub mod slack;
pub mod state;

use crate::model::{Catalog, ChainId, MsId};
use crate::util::{ms, Micros};

/// LSF priority key for a job at a given stage: `arrival + SLO − mean
/// remaining exec` in µs (time-invariant form of "remaining slack";
/// see [`queue`] docs). Smaller = more urgent.
pub fn lsf_key(cat: &Catalog, chain: ChainId, stage_idx: usize, arrival: Micros) -> Micros {
    let c = &cat.chains[chain];
    let remaining_exec: f64 = c.stages[stage_idx..]
        .iter()
        .map(|&s| cat.microservices[s].exec_ms_mean)
        .sum();
    (arrival + ms(c.slo_ms)).saturating_sub(ms(remaining_exec))
}

/// Fraction of arriving jobs that pass through a microservice under a
/// uniform chain mix (used to split a global load forecast per stage).
pub fn stage_share(cat: &Catalog, chains: &[ChainId], ms_id: MsId) -> f64 {
    if chains.is_empty() {
        return 0.0;
    }
    let hits = chains
        .iter()
        .filter(|&&c| cat.chains[c].stages.contains(&ms_id))
        .count();
    hits as f64 / chains.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lsf_key_orders_by_urgency() {
        let cat = Catalog::paper();
        let ipa = cat.chain_id("IPA").unwrap();
        let df = cat.chain_id("DetectFatigue").unwrap();
        // same arrival: DetectFatigue has more remaining exec at stage 0
        // -> smaller key -> scheduled first
        let k_ipa = lsf_key(&cat, ipa, 0, 1_000_000);
        let k_df = lsf_key(&cat, df, 0, 1_000_000);
        assert!(k_df < k_ipa);
        // later stages have larger keys (less remaining work)
        assert!(lsf_key(&cat, df, 3, 1_000_000) > k_df);
        // later arrival -> larger key
        assert!(lsf_key(&cat, ipa, 0, 2_000_000) > k_ipa);
    }

    #[test]
    fn stage_share_counts_chains() {
        let cat = Catalog::paper();
        let chains = vec![
            cat.chain_id("IPA").unwrap(),
            cat.chain_id("IMG").unwrap(),
        ];
        let qa = cat.ms_id("QA").unwrap();
        let asr = cat.ms_id("ASR").unwrap();
        let hs = cat.ms_id("HS").unwrap();
        assert_eq!(stage_share(&cat, &chains, qa), 1.0); // both chains
        assert_eq!(stage_share(&cat, &chains, asr), 0.5); // IPA only
        assert_eq!(stage_share(&cat, &chains, hs), 0.0); // neither
    }
}
