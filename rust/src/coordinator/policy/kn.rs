//! `Kn` — a Knative-style per-container concurrency autoscaler.
//!
//! Proof that the `SchedulerPolicy` API is closed over the engine: this
//! policy ships with **zero** engine edits. It reproduces the KPA
//! (Knative Pod Autoscaler) semantics as characterized in the serverless
//! platform studies (arXiv:1911.07449): each stage targets a fixed
//! per-container concurrency; desired scale is observed concurrency over
//! that target, averaged over a *stable* window, except in *panic* mode —
//! when the instantaneous demand implies ≥ `panic_threshold` times the
//! current scale, the autoscaler acts on the instantaneous (panic-window)
//! signal instead and never scales below it.
//!
//! Mapping onto this cluster model:
//! * target concurrency      = the stage's Eq. 1 batch size (container
//!   slots), i.e. `containerConcurrency`;
//! * observed concurrency    = queued requests + occupied warm slots;
//! * stable window           = `STABLE_TICKS` monitor intervals (~60 s at
//!   the paper's T = 10 s);
//! * panic window            = the latest monitor sample;
//! * scale-down / to-zero    = the engine's idle reclamation (default
//!   `on_scan`), Knative's scale-to-zero analog.
//!
//! Containers are spawned *only* from `on_monitor` — `on_arrival` never
//! spawns — so Kn also exercises the conformance requirement that a
//! policy with no per-request spawning still drains via monitor scaling.

use std::collections::VecDeque;

use super::{PolicyView, ScalingPlan, SchedulerPolicy};

/// Monitor ticks in the stable window (Knative default: 60 s).
const STABLE_TICKS: usize = 6;
/// Panic when instantaneous desired scale ≥ this multiple of current
/// scale (Knative default: 200%).
const PANIC_THRESHOLD: f64 = 2.0;

pub struct Kn {
    stable_ticks: usize,
    panic_threshold: f64,
    /// Per-stage trailing observed-concurrency samples, one per tick.
    /// Dense table indexed by `MsId`, matching the engine's stage tables.
    history: Vec<VecDeque<f64>>,
}

impl Kn {
    pub fn new() -> Kn {
        Kn {
            stable_ticks: STABLE_TICKS,
            panic_threshold: PANIC_THRESHOLD,
            history: Vec::new(),
        }
    }
}

impl Default for Kn {
    fn default() -> Kn {
        Kn::new()
    }
}

impl SchedulerPolicy for Kn {
    fn name(&self) -> &'static str {
        "Kn"
    }

    /// Per-container concurrency > 1 is Knative's `containerConcurrency`;
    /// the slack plan's Eq. 1 batch provides the target.
    fn batching(&self) -> bool {
        true
    }

    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        let mut spawns = Vec::new();
        for &ms_id in view.stages {
            let target = view.batch(ms_id).max(1) as f64;
            let observed = (view.pending(ms_id) + view.in_flight_slots(ms_id)) as f64;

            if self.history.len() <= ms_id {
                self.history.resize_with(ms_id + 1, VecDeque::new);
            }
            let h = &mut self.history[ms_id];
            h.push_back(observed);
            if h.len() > self.stable_ticks {
                h.pop_front();
            }
            let stable_avg = h.iter().sum::<f64>() / h.len() as f64;

            let desired_stable = (stable_avg / target).ceil() as usize;
            let desired_panic = (observed / target).ceil() as usize;
            let live = view.live(ms_id);
            let panicking =
                desired_panic > 0 && desired_panic as f64 >= self.panic_threshold * live.max(1) as f64;
            // In panic mode, act on the instantaneous signal and never
            // scale below it; otherwise follow the stable average.
            let desired = if panicking {
                desired_stable.max(desired_panic)
            } else {
                desired_stable
            };
            let spawn = desired.saturating_sub(live);
            if spawn > 0 {
                spawns.push((ms_id, spawn));
            }
        }
        ScalingPlan {
            spawns,
            stop_on_full: false,
        }
    }
}
