//! The paper's five RM frameworks (§5.3), each as one `SchedulerPolicy`
//! impl, plus `FiferEq` — Fifer ablated to equal-division slack and FIFO
//! ordering (the §6 ablation axis as a runnable policy).
//!
//! Decision math lives in [`crate::coordinator::scaling`]; these impls
//! only wire it to the hook surface. The spawn *order* inside each
//! returned plan matters: the engine draws cold-start latencies from its
//! seeded RNG per spawn, so plans list reactive entries (stage order)
//! before proactive entries to reproduce the pre-trait engine exactly.

use crate::config::{SlackPolicy, SystemConfig};
use crate::coordinator::queue::Ordering as QueueOrdering;
use crate::coordinator::scaling;
use crate::model::MsId;
use crate::predictor::{classic, nn, Predictor};

use super::{PolicyView, ScalingPlan, SchedulerPolicy};

/// Per-request deficit: queued requests not covered by a warm free slot
/// or a slot already starting (Bline/BPred event-driven spawning, §3).
pub(crate) fn arrival_deficit(view: &PolicyView, ms_id: MsId) -> usize {
    let covered = view.warm_free_slots(ms_id) + view.starting_slots(ms_id);
    view.pending(ms_id).saturating_sub(covered)
}

/// Algorithm 1a: dynamic reactive scaling across all stages (RScale,
/// Fifer). One plan entry per stage with a non-zero spawn count, in the
/// engine's canonical stage order.
pub(crate) fn reactive_spawns(view: &PolicyView) -> Vec<(MsId, usize)> {
    let mut spawns = Vec::new();
    for &ms_id in view.stages {
        let d = scaling::reactive_scale(
            view.pending(ms_id),
            view.batch(ms_id),
            view.s_r_ms(ms_id),
            view.live(ms_id),
            view.expected_cold_ms(ms_id),
        );
        if d.spawn > 0 {
            spawns.push((ms_id, d.spawn));
        }
    }
    spawns
}

/// Algorithm 1b: proactive prediction-driven scaling (BPred, Fifer).
/// `already_planned` holds spawns queued earlier in the same plan (e.g.
/// Fifer's reactive pass) so live capacity is not double-counted.
pub(crate) fn proactive_spawns(
    view: &PolicyView,
    already_planned: &[(MsId, usize)],
) -> Vec<(MsId, usize)> {
    let Some(forecast) = view.forecast else {
        return Vec::new();
    };
    let mut spawns = Vec::new();
    for &ms_id in view.stages {
        let planned: usize = already_planned
            .iter()
            .filter(|&&(m, _)| m == ms_id)
            .map(|&(_, n)| n)
            .sum();
        let rate = forecast * view.share(ms_id);
        let spawn = scaling::proactive_scale(
            rate,
            view.batch(ms_id),
            view.exec_ms_mean(ms_id),
            view.gamma(),
            view.live(ms_id) + planned,
        );
        if spawn > 0 {
            spawns.push((ms_id, spawn));
        }
    }
    spawns
}

/// Bline: per-request container spawning, no batching, FIFO queues.
pub struct Bline;

impl SchedulerPolicy for Bline {
    fn name(&self) -> &'static str {
        "Bline"
    }

    fn on_arrival(&mut self, ms_id: MsId, view: &PolicyView) -> usize {
        arrival_deficit(view, ms_id)
    }
}

/// SBatch: slack-aware batching over a fixed pool sized once from the
/// workload's average rate (§5.3); equal-division slack, FIFO, never
/// scaled or reclaimed after start.
pub struct SBatch;

impl SchedulerPolicy for SBatch {
    fn name(&self) -> &'static str {
        "SBatch"
    }

    fn batching(&self) -> bool {
        true
    }

    fn slack_policy(&self) -> Option<SlackPolicy> {
        Some(SlackPolicy::EqualDivision)
    }

    fn on_start(&mut self, view: &PolicyView) -> ScalingPlan {
        let mut spawns = Vec::new();
        for &ms_id in view.stages {
            let pool = scaling::sbatch_pool(
                view.avg_rate_hint * view.share(ms_id),
                view.batch(ms_id),
                view.exec_ms_mean(ms_id),
                view.gamma(),
                view.cfg.rm.sbatch_headroom,
            );
            spawns.push((ms_id, pool));
        }
        ScalingPlan {
            spawns,
            stop_on_full: true,
        }
    }

    fn on_scan(&mut self, _view: &PolicyView) -> Vec<u64> {
        Vec::new() // fixed pool: nothing is ever reclaimed
    }
}

/// RScale: Fifer minus prediction (GrandSLAm-like) — batching, LSF, and
/// reactive queuing-delay scaling only.
pub struct RScale;

impl SchedulerPolicy for RScale {
    fn name(&self) -> &'static str {
        "RScale"
    }

    fn queue_order(&self) -> QueueOrdering {
        QueueOrdering::LeastSlackFirst
    }

    fn batching(&self) -> bool {
        true
    }

    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        ScalingPlan {
            spawns: reactive_spawns(view),
            stop_on_full: false,
        }
    }
}

/// BPred: Bline plus LSF plus EWMA prediction (Archipelago-like) — no
/// batching, per-request spawning, proactive provisioning.
pub struct BPred;

impl SchedulerPolicy for BPred {
    fn name(&self) -> &'static str {
        "BPred"
    }

    fn queue_order(&self) -> QueueOrdering {
        QueueOrdering::LeastSlackFirst
    }

    fn proactive(&self) -> bool {
        true
    }

    fn make_predictor(&self, cfg: &SystemConfig) -> Option<Box<dyn Predictor>> {
        Some(Box::new(classic::Ewma::new(cfg.rm.ewma_alpha)))
    }

    fn on_arrival(&mut self, ms_id: MsId, view: &PolicyView) -> usize {
        arrival_deficit(view, ms_id)
    }

    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        ScalingPlan {
            spawns: proactive_spawns(view, &[]),
            stop_on_full: false,
        }
    }
}

/// Fifer: the full framework — slack-aware batching, LSF, reactive +
/// LSTM-proactive scaling. `equal_division` ablates it to equal slack
/// division + FIFO ordering (registered as `FiferEq`).
pub struct Fifer {
    equal_division: bool,
}

impl Fifer {
    pub fn proportional() -> Fifer {
        Fifer {
            equal_division: false,
        }
    }

    pub fn equal_division() -> Fifer {
        Fifer {
            equal_division: true,
        }
    }
}

impl SchedulerPolicy for Fifer {
    fn name(&self) -> &'static str {
        if self.equal_division {
            "FiferEq"
        } else {
            "Fifer"
        }
    }

    fn queue_order(&self) -> QueueOrdering {
        if self.equal_division {
            QueueOrdering::Fifo
        } else {
            QueueOrdering::LeastSlackFirst
        }
    }

    fn batching(&self) -> bool {
        true
    }

    fn proactive(&self) -> bool {
        true
    }

    fn slack_policy(&self) -> Option<SlackPolicy> {
        self.equal_division.then_some(SlackPolicy::EqualDivision)
    }

    fn make_predictor(&self, cfg: &SystemConfig) -> Option<Box<dyn Predictor>> {
        let wp = std::path::Path::new(&cfg.artifacts_dir).join("predictor_weights.json");
        let p: Box<dyn Predictor> = match nn::LstmPredictor::load(&wp) {
            Ok(l) => Box::new(l),
            // graceful degradation pre-`make artifacts`: EWMA
            Err(_) => Box::new(classic::Ewma::new(cfg.rm.ewma_alpha)),
        };
        Some(p)
    }

    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        // Reactive first, proactive second — proactive counts the
        // reactive spawns as live-to-be so one tick never provisions a
        // stage twice for the same backlog.
        let mut spawns = reactive_spawns(view);
        let proactive = proactive_spawns(view, &spawns);
        spawns.extend(proactive);
        ScalingPlan {
            spawns,
            stop_on_full: false,
        }
    }
}
