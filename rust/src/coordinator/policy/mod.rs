//! Pluggable scheduler policies: the `SchedulerPolicy` trait API.
//!
//! Fifer's contribution is a *family* of resource-management policies
//! (paper §5.3, Table 6) compared under identical cluster mechanics. This
//! module makes that family an open set: every decision point the engine
//! exposes is one hook on [`SchedulerPolicy`], and there is exactly one
//! engine — [`crate::coordinator::engine::EngineCore`] — driven in
//! virtual time by the simulator (`crate::sim::Engine`) and in wall-clock
//! time by the live server (`crate::server::serve`). Both paths exercise
//! the *full* hook surface against the same trait objects: live
//! containers are real executor threads that `on_start`/`on_arrival`/
//! `on_monitor` plans spawn and `on_scan` retires. The effect-side
//! counterpart of this contract (what a `Driver` may and may not do)
//! is documented in [`crate::coordinator::engine`].
//!
//! ## Hooks (one per engine decision point)
//!
//! | hook              | decision                                           |
//! |-------------------|----------------------------------------------------|
//! | `queue_order`     | per-stage global-queue ordering (FIFO vs LSF)      |
//! | `batching`        | request batching on/off (drives Eq. 1 batch sizes) |
//! | `slack_policy`    | preferred slack distribution (config default)      |
//! | `make_predictor`  | load forecaster construction (§4.5.1)              |
//! | `on_start`        | initial provisioning before the first request      |
//! | `on_arrival`      | per-request spawn decision at enqueue time         |
//! | `on_monitor`      | monitor-tick scaling (Algorithm 1)                 |
//! | `on_scan`         | idle-container reclamation                         |
//!
//! ## Hook contract
//!
//! * **Reads go through [`PolicyView`] only.** The view is a read-only
//!   snapshot of the coordinator state (queues, store, slack plan,
//!   clamped forecast). Policies never mutate cluster state directly —
//!   they return a [`ScalingPlan`] (or a retire list) and the engine
//!   executes it, so every policy shares one set of mechanics.
//! * **No clock access.** `PolicyView::now` is the engine's virtual
//!   (simulator) or monotonic (live) time; policies must never read wall
//!   clocks (`std::time`) or host randomness — determinism of a seeded
//!   run depends on it.
//! * **Purity up to internal state.** A policy may keep state across
//!   hooks (`&mut self`, e.g. `Kn`'s request-rate windows), but that
//!   state must be derived from hook inputs alone.
//! * `PolicyView::forecast` is only populated during `on_monitor`, and
//!   only when `make_predictor` returned a predictor; it is pre-clamped
//!   to 2x the recently observed peak (§8 "Design Limitations").
//!
//! ## Registry
//!
//! The closed [`crate::config::Policy`] enum is now a thin facade over
//! this module: [`build`] maps each name to its implementation, and
//! `Policy::ALL` / `Policy::from_name` / CLI error messages derive from
//! the registry. Policies outside the registry (user-defined) plug in
//! through [`crate::sim::run_sim_with`] / `Engine::with_policy` — see
//! `examples/custom_policy.rs` for a complete out-of-crate policy.

pub mod kn;
pub mod paper;
mod view;

pub use view::PolicyView;

use crate::config::{Policy, SlackPolicy, SystemConfig};
use crate::coordinator::queue::Ordering as QueueOrdering;
use crate::model::MsId;
use crate::predictor::Predictor;
use crate::util::secs;

/// Containers to spawn, in execution order. The engine spawns entries
/// front to back; within an entry it stops early when the cluster is
/// full (skipping to the next entry, or aborting the whole plan when
/// `stop_on_full` is set — SBatch's fixed-pool provisioning semantics).
#[derive(Debug, Default, Clone)]
pub struct ScalingPlan {
    /// (stage, container count) in spawn order. A stage may appear more
    /// than once (e.g. Fifer emits reactive entries, then proactive).
    pub spawns: Vec<(MsId, usize)>,
    /// Abort the remaining plan on the first rejected spawn.
    pub stop_on_full: bool,
}

impl ScalingPlan {
    pub fn none() -> ScalingPlan {
        ScalingPlan::default()
    }

    pub fn total(&self) -> usize {
        self.spawns.iter().map(|&(_, n)| n).sum()
    }
}

/// A complete resource-management policy: queue ordering, batching,
/// prediction, and every scaling decision the cluster engine delegates.
///
/// All hooks have conservative defaults (FIFO, no batching, no spawns,
/// reclaim idle containers after the configured timeout), so a minimal
/// policy only overrides the decisions it cares about.
pub trait SchedulerPolicy {
    /// Display / CLI name.
    fn name(&self) -> &'static str;

    /// Ordering of the per-stage global queues (§4.3).
    fn queue_order(&self) -> QueueOrdering {
        QueueOrdering::Fifo
    }

    /// Batch requests per container? Drives Eq. 1 batch sizing in the
    /// slack plan (container local-queue capacity on both drivers).
    fn batching(&self) -> bool {
        false
    }

    /// Scale proactively from a load forecast? Introspection only — the
    /// engine keys proactive behavior off `make_predictor`.
    fn proactive(&self) -> bool {
        false
    }

    /// Preferred slack distribution, or `None` to accept the configured
    /// one. `RmConfig::paper` consults this so e.g. SBatch defaults to
    /// equal division without a config edit.
    fn slack_policy(&self) -> Option<SlackPolicy> {
        None
    }

    /// Construct the load predictor feeding `PolicyView::forecast`. The
    /// engine owns the predictor: it feeds window maxima via
    /// `Predictor::observe` and clamps `forecast()` before each
    /// `on_monitor` call.
    fn make_predictor(&self, _cfg: &SystemConfig) -> Option<Box<dyn Predictor>> {
        None
    }

    /// Initial provisioning at t = 0, before the first request.
    fn on_start(&mut self, _view: &PolicyView) -> ScalingPlan {
        ScalingPlan::none()
    }

    /// A request just landed in stage `ms_id`'s global queue (either a
    /// fresh arrival or a chain advancing a stage). Returns how many
    /// containers to spawn for that stage right now.
    fn on_arrival(&mut self, _ms_id: MsId, _view: &PolicyView) -> usize {
        0
    }

    /// Periodic monitor tick (paper Algorithm 1): reactive + proactive
    /// scaling computed from the snapshot in `view`.
    fn on_monitor(&mut self, _view: &PolicyView) -> ScalingPlan {
        ScalingPlan::none()
    }

    /// Periodic reclamation scan: container ids to retire now. The
    /// default reclaims containers idle past `rm.idle_timeout_s`.
    fn on_scan(&mut self, view: &PolicyView) -> Vec<u64> {
        default_idle_reclaim(view)
    }
}

/// Default idle scale-in: every container unused for longer than the
/// configured idle timeout, stage by stage in catalog order.
pub fn default_idle_reclaim(view: &PolicyView) -> Vec<u64> {
    let cutoff = view.now.saturating_sub(secs(view.cfg.rm.idle_timeout_s));
    let mut out = Vec::new();
    for &ms_id in view.stages {
        out.extend(view.store.idle_since(ms_id, cutoff));
    }
    out
}

/// The policy registry: one constructor per registered name. This is the
/// *only* place that maps `Policy` variants to implementations — the
/// engine never branches on the enum.
pub fn build(p: Policy) -> Box<dyn SchedulerPolicy> {
    match p {
        Policy::Bline => Box::new(paper::Bline),
        Policy::SBatch => Box::new(paper::SBatch),
        Policy::RScale => Box::new(paper::RScale),
        Policy::BPred => Box::new(paper::BPred),
        Policy::Fifer => Box::new(paper::Fifer::proportional()),
        Policy::Kn => Box::new(kn::Kn::new()),
        Policy::FiferEq => Box::new(paper::Fifer::equal_division()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_match_enum() {
        for p in Policy::ALL {
            assert_eq!(p.name(), build(p).name(), "registry/enum name drift");
        }
    }

    #[test]
    fn registry_capabilities_are_consistent() {
        for p in Policy::ALL {
            let b = build(p);
            assert_eq!(p.batching(), b.batching(), "{}", p.name());
            assert_eq!(p.proactive(), b.proactive(), "{}", p.name());
            assert_eq!(
                p.lsf(),
                b.queue_order() == QueueOrdering::LeastSlackFirst,
                "{}",
                p.name()
            );
        }
    }

    #[test]
    fn scaling_plan_totals() {
        let plan = ScalingPlan {
            spawns: vec![(0, 2), (1, 0), (0, 3)],
            stop_on_full: false,
        };
        assert_eq!(plan.total(), 5);
        assert_eq!(ScalingPlan::none().total(), 0);
    }
}
