//! Read-only coordinator snapshot handed to every policy hook.

use crate::coldstart::ColdStartModel;
use crate::config::SystemConfig;
use crate::coordinator::queue::StageQueue;
use crate::coordinator::slack::SlackPlan;
use crate::coordinator::stage_share;
use crate::coordinator::state::StateStore;
use crate::model::{Catalog, ChainId, MsId};
use crate::util::{to_ms, Micros};

/// Everything a [`super::SchedulerPolicy`] may read when deciding. All
/// fields are shared references into the engine — policies cannot mutate
/// cluster state, only return plans for the engine to execute.
pub struct PolicyView<'a> {
    pub cat: &'a Catalog,
    pub cfg: &'a SystemConfig,
    /// Chains of the workload mix.
    pub chains: &'a [ChainId],
    /// The slack plan (Eq. 1 batch sizes, per-stage budgets).
    pub plan: &'a SlackPlan,
    /// Stages of the mix, in first-seen chain order (the engine's
    /// canonical iteration order — iterate this for determinism).
    pub stages: &'a [MsId],
    /// Dense per-stage queue table indexed by `MsId` (stages outside the
    /// workload mix hold empty queues).
    pub queues: &'a [StageQueue],
    pub store: &'a StateStore,
    pub cold: &'a ColdStartModel,
    /// Engine time: virtual µs in the simulator, monotonic µs live.
    /// Never a wall clock — see the module hook contract.
    pub now: Micros,
    /// Clamped max-arrival-rate forecast (req/s). Only populated during
    /// `on_monitor`, and only when the policy built a predictor.
    pub forecast: Option<f64>,
    /// Long-run average arrival rate of the driving workload (req/s):
    /// the trace average in simulation, the generator rate live. SBatch
    /// sizes its fixed pool from this (§5.3).
    pub avg_rate_hint: f64,
}

impl PolicyView<'_> {
    /// Requests waiting in the stage's global queue.
    pub fn pending(&self, ms_id: MsId) -> usize {
        self.queues.get(ms_id).map(|q| q.len()).unwrap_or(0)
    }

    /// Live containers of the stage (warm + starting).
    pub fn live(&self, ms_id: MsId) -> usize {
        self.store.stage_containers(ms_id)
    }

    /// Free slots across the stage's warm containers.
    pub fn warm_free_slots(&self, ms_id: MsId) -> usize {
        self.store.warm_free_slots(ms_id)
    }

    /// Slots that will come online from still-starting containers.
    pub fn starting_slots(&self, ms_id: MsId) -> usize {
        self.store.starting_slots(ms_id)
    }

    /// Eq. 1 batch size for the stage's containers.
    pub fn batch(&self, ms_id: MsId) -> usize {
        self.plan.batch_for(ms_id)
    }

    /// Per-stage response budget S_r = slack + exec (ms).
    pub fn s_r_ms(&self, ms_id: MsId) -> f64 {
        self.plan.s_r_for(ms_id)
    }

    /// Mean execution time of the microservice (ms).
    pub fn exec_ms_mean(&self, ms_id: MsId) -> f64 {
        self.cat.microservices[ms_id].exec_ms_mean
    }

    /// Expected cold-start latency for the stage (ms).
    pub fn expected_cold_ms(&self, ms_id: MsId) -> f64 {
        to_ms(self.cold.expected_micros(&self.cat.microservices[ms_id]))
    }

    /// Fraction of arriving jobs that pass through this stage under the
    /// current mix (splits a global forecast per stage).
    pub fn share(&self, ms_id: MsId) -> f64 {
        stage_share(self.cat, self.chains, ms_id)
    }

    /// Marginal batched-execution cost γ (see `RmConfig`).
    pub fn gamma(&self) -> f64 {
        self.cfg.rm.batch_cost_gamma
    }

    /// Idle containers of the stage unused since before `cutoff`,
    /// oldest first. Lazily yielded — `extend` a reclaim list with it,
    /// or `collect` when a Vec is genuinely needed.
    pub fn idle_since(&self, ms_id: MsId, cutoff: Micros) -> impl Iterator<Item = u64> + '_ {
        self.store.idle_since(ms_id, cutoff)
    }

    /// Requests currently occupying warm slots of the stage (dispatched
    /// or executing): capacity minus free minus still-starting slots.
    pub fn in_flight_slots(&self, ms_id: MsId) -> usize {
        let capacity = self.live(ms_id) * self.batch(ms_id).max(1);
        capacity
            .saturating_sub(self.warm_free_slots(ms_id))
            .saturating_sub(self.starting_slots(ms_id))
    }
}
