//! The driver-agnostic coordinator engine: one control loop, two drivers.
//!
//! [`EngineCore`] is the single implementation of the Fifer coordinator
//! state machine: per-stage global queues, the indexed
//! [`StateStore`], slack-plan batching, predictor sampling windows, and
//! every [`SchedulerPolicy`] hook. It contains **no notion of where time
//! or execution comes from** — that is the [`Driver`]'s job. The
//! event-driven simulator (`crate::sim`) plugs in a *virtual-time*
//! driver (modeled cold starts and execution latencies, scheduled on the
//! core's event heap), and the live server (`crate::server`) plugs in a
//! *real-time* driver (executor threads running actual inference, with
//! completions injected as they happen). Both make exactly the same
//! scheduling decisions, because the decisions live here.
//!
//! # Driver contract
//!
//! This is the effect-side counterpart of the `SchedulerPolicy` hook
//! contract (see [`crate::coordinator::policy`]):
//!
//! * **The core owns decisions; the driver owns effects.** A driver
//!   never touches the queues, the store, or the policy — it only
//!   realizes the spawns and batch executions the core asks for, so the
//!   two drivers cannot drift apart on scheduling behavior.
//! * **Engine time is driver-defined µs, advancing monotonically.**
//!   Virtual heap time in the simulator, monotonic elapsed wall time in
//!   the live server. The core never reads a clock itself.
//! * **Effects are either virtual or asynchronous.** `begin_spawn`
//!   returns [`SpawnEffect::Ready`] (the core schedules warm-up after
//!   the returned virtual latency) or [`SpawnEffect::Pending`] (the
//!   driver delivers readiness later via [`EngineCore::spawn_completed`]).
//!   `exec_batch` returns `Some(duration)` for a virtual completion or
//!   `None` when the driver will call [`EngineCore::batch_completed`].
//! * **All modeled randomness draws from the core's seeded PCG**, handed
//!   to the driver through [`EffectCtx`] in a fixed call order. A
//!   virtual driver that performs the same draws in the same order
//!   reproduces a run bit-for-bit from its seed (this is what pins the
//!   simulator byte-identical across refactors); a real-time driver is
//!   free to ignore the RNG — its nondeterminism is physical, not
//!   sampled.
//! * **Host-time probes are opt-in.** The §6.1.5 dispatch-decision
//!   latency probe reads `std::time::Instant` and is disabled unless
//!   [`EngineCore::set_decision_probe`] (or the `FIFER_DECISION_PROBE`
//!   environment variable) turns it on, so deterministic runs perform no
//!   wall-clock reads at all.
//!
//! Metrics flow through one [`Recorder`] regardless of driver, so live
//! runs and simulations summarize identically
//! (`crate::metrics::Summary`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::coldstart::ColdStartModel;
use crate::config::SystemConfig;
use crate::coordinator::policy::{PolicyView, ScalingPlan, SchedulerPolicy};
use crate::coordinator::queue::{QueueEntry, StageQueue};
use crate::coordinator::state::{BatchStart, CState, StateStore};
use crate::coordinator::{lsf_key, scaling, slack::SlackPlan};
use crate::energy::ClusterEnergy;
use crate::estimator::{Invocation, InvocationLog};
use crate::metrics::{JobRecord, Recorder, StageRecord};
use crate::model::{Catalog, ChainId, MsId};
use crate::obs::{Collector, Gauges, ObsConfig, ObsReport, StageSpan};
use crate::predictor::Predictor;
use crate::util::rng::Pcg;
use crate::util::{ms, secs, to_ms, Micros, MICROS_PER_S};

/// Core events. Ord is required by the heap; ordering beyond the
/// (time, seq) key is irrelevant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// A request for `chain` arrives.
    Arrival { chain: ChainId },
    /// Container finished cold-starting.
    SpawnDone { cid: u64 },
    /// Container finished executing its current batch.
    BatchDone { cid: u64 },
    /// Close one W_s arrival-sampling window (predictor input).
    WindowClose,
    /// Periodic monitoring: the policy's `on_monitor` hook (Algorithm 1).
    Monitor,
    /// Periodic `on_scan` reclamation + energy sampling.
    Scan,
}

/// Per-job state; stage records accumulate in place and move into the
/// [`Recorder`] at completion.
#[derive(Debug)]
struct JobState {
    chain: ChainId,
    arrival: Micros,
    stage_idx: usize,
    stages: Vec<StageRecord>,
    cur_enqueued: Micros,
    cur_exec_start: Micros,
    cur_cold_wait: Micros,
    done: bool,
}

/// Read-only slice of the core handed to [`Driver`] effect hooks: the
/// catalog/config/cold-start model plus the engine clock and the seeded
/// RNG every modeled latency must draw from.
pub struct EffectCtx<'a> {
    pub cat: &'a Catalog,
    pub cfg: &'a SystemConfig,
    pub coldstart: &'a ColdStartModel,
    /// Engine time (virtual or monotonic µs — never a wall clock).
    pub now: Micros,
    /// The core's seeded PCG. Virtual drivers sample modeled latencies
    /// from it (draw order defines reproducibility); real-time drivers
    /// may fork per-container streams or ignore it.
    pub rng: &'a mut Pcg,
}

impl EffectCtx<'_> {
    /// Sample the modeled cold-start latency (spawn + image pull +
    /// runtime init) for one container of `ms_id`. THE single
    /// definition of the modeled cold start: the simulator's virtual
    /// driver and the live server's synthetic backend both call this,
    /// so the two cannot drift apart.
    pub fn sample_cold_start(&mut self, ms_id: MsId) -> Micros {
        self.coldstart
            .sample(&self.cat.microservices[ms_id], self.rng)
            .total()
    }

    /// Sample the modeled batched-execution duration for a captured
    /// batch: exec(B) = exec(1)·(1 + γ·(B−1)) plus the warm scheduling
    /// overhead. Shared by the virtual driver and the synthetic live
    /// backend — one exec model, two drivers.
    pub fn sample_batch_exec(&mut self, b: &BatchStart) -> Micros {
        let base_ms = self.cat.microservices[b.ms_id].sample_exec_ms(self.rng);
        let gamma = self.cfg.rm.batch_cost_gamma;
        let exec_ms = base_ms * (1.0 + gamma * (b.len as f64 - 1.0));
        self.coldstart.warm_overhead() + ms(exec_ms)
    }
}

/// How a requested container spawn materializes.
#[derive(Debug, Clone, Copy)]
pub enum SpawnEffect {
    /// The container becomes warm after this much engine time (0 =
    /// instantly warm); the core schedules the warm-up itself.
    Ready(Micros),
    /// The driver brings the container up asynchronously and will call
    /// [`EngineCore::spawn_completed`]; the payload is the *expected*
    /// cold-start latency, used for `ready_at`/cold-wait attribution
    /// until the real warm-up arrives.
    Pending(Micros),
}

impl SpawnEffect {
    /// The (expected) cold-start latency carried by either variant.
    pub fn latency(self) -> Micros {
        match self {
            SpawnEffect::Ready(l) | SpawnEffect::Pending(l) => l,
        }
    }
}

/// The effect side of the engine: how spawns and batch executions
/// actually happen. See the module docs for the full contract.
pub trait Driver {
    /// A container for `ms_id` is about to spawn. Decide (and, for
    /// virtual drivers, sample) its cold-start latency. Called before
    /// placement — the spawn may still be rejected by a full cluster, in
    /// which case no matching [`Driver::container_spawned`] follows.
    fn begin_spawn(&mut self, ms_id: MsId, cold: bool, ctx: EffectCtx<'_>) -> SpawnEffect;

    /// Execute a captured batch on container `cid`. Return
    /// `Some(duration)` to complete virtually after that much engine
    /// time, or `None` when completion is delivered asynchronously via
    /// [`EngineCore::batch_completed`].
    fn exec_batch(&mut self, cid: u64, batch: &BatchStart, ctx: EffectCtx<'_>) -> Option<Micros>;

    /// A container was admitted to the store (real-time drivers launch
    /// the executor here). `effect` is the value this driver returned
    /// from the matching [`Driver::begin_spawn`] — passed back so
    /// drivers need no call-pairing side state.
    fn container_spawned(
        &mut self,
        _cid: u64,
        _ms_id: MsId,
        _batch: usize,
        _effect: SpawnEffect,
        _ctx: EffectCtx<'_>,
    ) {
    }

    /// A container was retired or evicted (real-time drivers tear the
    /// executor down here). Only ever called for idle containers.
    fn container_retired(&mut self, _cid: u64) {}
}

/// The coordinator state machine, generic over its [`Driver`].
///
/// Use `crate::sim::Engine` (= `EngineCore<VirtualDriver>`) for
/// simulations and `crate::server::serve` for live runs; drive the core
/// directly only when building a new driver.
pub struct EngineCore<D: Driver> {
    pub(crate) cat: Catalog,
    pub(crate) cfg: SystemConfig,
    pub(crate) chains: Vec<ChainId>,
    pub(crate) plan: SlackPlan,
    /// Dense per-stage queue table indexed by `MsId` (stage ids are small
    /// integers from `Catalog::ms_id`, so a Vec beats a hash map on the
    /// dispatch path). Stages outside the workload mix hold empty queues.
    pub(crate) queues: Vec<StageQueue>,
    pub(crate) store: StateStore,
    pub(crate) cold: ColdStartModel,
    /// The scheduler policy. Held in an Option so hooks can borrow the
    /// engine immutably (for the `PolicyView`) while the trait object is
    /// temporarily taken out; always `Some` between events.
    pub(crate) policy: Option<Box<dyn SchedulerPolicy>>,
    pub(crate) predictor: Option<Box<dyn Predictor>>,
    pub(crate) rng: Pcg,
    events: BinaryHeap<Reverse<(Micros, u64, Event)>>,
    pub(crate) seq: u64,
    pub(crate) now: Micros,
    jobs: Vec<JobState>,
    jobs_done: usize,
    pub recorder: Recorder,
    pub(crate) energy: ClusterEnergy,
    /// Per-second arrival counts inside the current sampling window.
    window_counts: Vec<u64>,
    window_start: Micros,
    /// Trailing window maxima used to sanity-clamp out-of-distribution
    /// forecasts; retention = history_s / sample_window_s windows.
    recent_maxima: VecDeque<f64>,
    maxima_keep: usize,
    pub(crate) stages: Vec<MsId>,
    /// Average workload rate, exposed to policies (SBatch pool sizing).
    pub(crate) avg_rate: f64,
    /// End of the workload window (arrivals + monitor scaling).
    pub(crate) horizon: Micros,
    /// End of the run (drain included); periodic scans stop here.
    pub(crate) end: Micros,
    /// Opt-in host-time sampling of dispatch decisions (§6.1.5).
    probe_decisions: bool,
    decision_probe: u64,
    /// Reusable batch-capture buffer for `start_exec` (taken/restored
    /// around each kickoff, so steady-state batching never allocates).
    scratch_batch: Vec<u64>,
    /// Reusable drained-jobs buffer for `handle_batch_done`. Separate
    /// from `scratch_batch`: job advancement inside the completion loop
    /// can recursively kick off new batches.
    scratch_done: Vec<u64>,
    /// Opt-in observability collector (`None` by default, so the
    /// telemetry taps cost one branch each and cannot perturb the
    /// zero-alloc pin or byte-identity of runs that don't ask for it).
    obs: Option<Box<Collector>>,
    /// Opt-in invocation log for the offline optimality-gap estimators
    /// (`None` by default — same contract as `obs`: one branch on the
    /// completion path, zero effect on runs that don't ask for it).
    inv_log: Option<Box<InvocationLog>>,
    pub(crate) driver: D,
}

impl<D: Driver> EngineCore<D> {
    /// Assemble a core around a policy and a driver. Drivers supply the
    /// workload separately (the simulator seeds arrivals from a trace;
    /// the live server injects them from a generator thread), so the
    /// core only needs the long-run `avg_rate` hint here.
    pub fn build(
        cfg: SystemConfig,
        chains: Vec<ChainId>,
        avg_rate: f64,
        pol: Box<dyn SchedulerPolicy>,
        driver: D,
    ) -> EngineCore<D> {
        let cat = Catalog::paper();
        let plan = SlackPlan::build(&cat, &chains, &cfg.rm, pol.batching());
        let order = pol.queue_order();
        let mut stages: Vec<MsId> = Vec::new();
        for &c in &chains {
            for &s in &cat.chains[c].stages {
                if !stages.contains(&s) {
                    stages.push(s);
                }
            }
        }
        let nstages = stages.iter().copied().max().map_or(0, |m| m + 1);
        let queues: Vec<StageQueue> = (0..nstages).map(|_| StageQueue::new(order)).collect();
        let store = StateStore::new(
            cfg.cluster.nodes,
            cfg.cluster.cores_per_node,
            cfg.cluster.cpu_per_container,
        );
        let energy = ClusterEnergy::new(cfg.cluster.nodes);
        let predictor = pol.make_predictor(&cfg);
        let nwin = cfg.rm.sample_window_s.max(1.0) as usize;
        let maxima_keep = (cfg.rm.history_s / cfg.rm.sample_window_s.max(1e-9))
            .ceil()
            .max(1.0) as usize;
        let rng = Pcg::new(cfg.seed);
        EngineCore {
            cat,
            cfg,
            chains,
            plan,
            queues,
            store,
            cold: ColdStartModel::default(),
            policy: Some(pol),
            predictor,
            rng,
            events: BinaryHeap::with_capacity(64),
            seq: 0,
            now: 0,
            jobs: Vec::new(),
            jobs_done: 0,
            recorder: Recorder::new(),
            energy,
            window_counts: vec![0; nwin],
            window_start: 0,
            recent_maxima: VecDeque::with_capacity(maxima_keep),
            maxima_keep,
            stages,
            avg_rate,
            horizon: 0,
            end: Micros::MAX,
            probe_decisions: std::env::var_os("FIFER_DECISION_PROBE").is_some(),
            decision_probe: 0,
            scratch_batch: Vec::with_capacity(16),
            scratch_done: Vec::with_capacity(16),
            obs: None,
            inv_log: None,
            driver,
        }
    }

    /// Attach an observability collector. The default `e2e_p95_ms`
    /// contract target is the strictest end-to-end SLO among the active
    /// chains (the same per-chain SLO the slack plan budgets against).
    pub fn enable_obs(&mut self, cfg: ObsConfig) {
        let slo_ms = self
            .chains
            .iter()
            .map(|&c| self.cat.chains[c].slo_ms)
            .fold(f64::INFINITY, f64::min);
        let slo_ms = if slo_ms.is_finite() { slo_ms } else { 1000.0 };
        // the trace sampler hashes the engine seed so sampled job sets —
        // and therefore --trace-out bytes — are reproducible; the policy
        // name is stamped on every span
        let seed = self.cfg.seed;
        let policy = self.policy.as_ref().map_or("?", |p| p.name());
        self.obs = Some(Box::new(Collector::new(cfg, slo_ms, seed, policy)));
    }

    /// Attach the invocation log feeding the offline optimality-gap
    /// estimators ([`crate::estimator`]). Captures the exec-model
    /// constants (batch cost slope, warm overhead, slack-plan batch
    /// capacities) up front; per-invocation entries are recorded as
    /// batches complete. Off by default, same contract as [`Self::enable_obs`].
    pub fn enable_invocation_log(&mut self) {
        let mut batch_cap = std::collections::BTreeMap::new();
        for &ms in &self.stages {
            batch_cap.insert(ms, self.plan.batch_for(ms));
        }
        self.inv_log = Some(Box::new(InvocationLog {
            entries: Vec::new(),
            gamma: self.cfg.rm.batch_cost_gamma,
            overhead: self.cold.warm_overhead(),
            batch_cap,
        }));
    }

    /// Snapshot the collector at the current engine time (`None` when
    /// observability is off). Real-time drivers publish these to the
    /// metrics endpoint.
    pub fn obs_report(&mut self) -> Option<ObsReport> {
        let now = self.now;
        self.obs.as_deref_mut().map(|o| {
            o.roll_to(now);
            o.report(now)
        })
    }

    /// Pre-size the event heap and job table for a known workload (the
    /// simulator calls this with the trace's arrival count, so the heap
    /// and job storage never grow during the run).
    pub fn reserve_workload(&mut self, arrivals: usize) {
        self.events.reserve(arrivals);
        self.jobs.reserve(arrivals);
    }

    pub fn catalog(&self) -> &Catalog {
        &self.cat
    }

    /// Enable/disable the host-time dispatch-decision probe (§6.1.5).
    /// Off by default so deterministic runs never read a wall clock; the
    /// `perf_hotpath` bench opts in.
    pub fn set_decision_probe(&mut self, on: bool) {
        self.probe_decisions = on;
    }

    /// Requests that have entered the system.
    pub fn jobs_arrived(&self) -> usize {
        self.jobs.len()
    }

    /// Requests that have completed their whole chain.
    pub fn jobs_completed(&self) -> usize {
        self.jobs_done
    }

    fn push(&mut self, t: Micros, ev: Event) {
        self.seq += 1;
        self.events.push(Reverse((t, self.seq, ev)));
    }

    /// Seed a future arrival (virtual-time drivers preload the whole
    /// trace; real-time drivers use [`EngineCore::arrival_at`] instead).
    pub fn schedule_arrival(&mut self, t: Micros, chain: ChainId) {
        self.push(t, Event::Arrival { chain });
    }

    /// Read-only snapshot for policy hooks.
    fn view(&self, forecast: Option<f64>) -> PolicyView<'_> {
        PolicyView {
            cat: &self.cat,
            cfg: &self.cfg,
            chains: &self.chains,
            plan: &self.plan,
            stages: &self.stages,
            queues: &self.queues,
            store: &self.store,
            cold: &self.cold,
            now: self.now,
            forecast,
            avg_rate_hint: self.avg_rate,
        }
    }

    /// Run the policy's initial provisioning and arm the periodic
    /// events. `horizon` bounds arrivals/monitor scaling, `end` bounds
    /// the whole run (drain included). Call once, at engine time 0,
    /// after any virtual arrivals have been seeded.
    pub fn bootstrap(&mut self, horizon: Micros, end: Micros) {
        self.horizon = horizon;
        self.end = end;
        // initial provisioning at t = 0 (e.g. SBatch's fixed pool)
        let mut pol = self.policy.take().expect("policy present");
        let start_plan = pol.on_start(&self.view(None));
        self.policy = Some(pol);
        self.execute_plan(start_plan);
        // periodic events
        self.push(secs(self.cfg.rm.sample_window_s), Event::WindowClose);
        self.push(secs(self.cfg.rm.monitor_interval_s), Event::Monitor);
        self.push(secs(self.cfg.rm.monitor_interval_s), Event::Scan);
    }

    /// Spawn the plan's containers in order. Within an entry, a rejected
    /// spawn skips to the next entry — or aborts the whole plan when the
    /// policy asked for `stop_on_full` (fixed-pool provisioning).
    fn execute_plan(&mut self, plan: ScalingPlan) {
        let ScalingPlan {
            spawns,
            stop_on_full,
        } = plan;
        'spawning: for (ms_id, n) in spawns {
            for _ in 0..n {
                if self.spawn_container(ms_id, true).is_none() {
                    if stop_on_full {
                        break 'spawning;
                    }
                    break;
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // event dispatch
    // ------------------------------------------------------------------

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Arrival { chain } => self.handle_arrival(chain),
            Event::SpawnDone { cid } => self.handle_spawn_done(cid),
            Event::BatchDone { cid } => self.handle_batch_done(cid),
            Event::WindowClose => self.handle_window_close(),
            Event::Monitor => {
                if self.now <= self.horizon {
                    self.run_monitor();
                    let next = self.now + secs(self.cfg.rm.monitor_interval_s);
                    self.push(next, Event::Monitor);
                }
            }
            Event::Scan => {
                self.run_scan();
                if self.now <= self.end {
                    let next = self.now + secs(self.cfg.rm.monitor_interval_s);
                    self.push(next, Event::Scan);
                }
            }
        }
    }

    /// Drain the event heap in time order until it is empty or the next
    /// event lies beyond `end` (virtual-time run loop), verifying
    /// invariants every `check_every` events (0 = never).
    pub(crate) fn run_events(&mut self, check_every: u64) -> Result<(), String> {
        let mut steps = 0u64;
        while let Some(Reverse((t, _, ev))) = self.events.pop() {
            if t > self.end {
                break;
            }
            self.now = t;
            self.handle(ev);
            steps += 1;
            if check_every > 0 && steps % check_every == 0 {
                self.check_conservation()?;
                self.check_store()?;
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // real-time ingress (the live driver's API)
    // ------------------------------------------------------------------

    /// Advance engine time to `t`, firing every internal event (window
    /// closes, monitor ticks, scans, virtual completions) due on the
    /// way. Real-time drivers call this from their tickers and before
    /// every injection; time never moves backwards.
    pub fn advance_to(&mut self, t: Micros) {
        let t = t.max(self.now);
        loop {
            let due = self
                .events
                .peek()
                .is_some_and(|&Reverse((et, _, _))| et <= t && et <= self.end);
            if !due {
                break;
            }
            let Reverse((et, _, ev)) = self.events.pop().expect("peeked event");
            self.now = et;
            self.handle(ev);
        }
        self.now = t;
    }

    /// Inject a live arrival at engine time `t`.
    pub fn arrival_at(&mut self, chain: ChainId, t: Micros) {
        self.advance_to(t);
        self.handle_arrival(chain);
    }

    /// A [`SpawnEffect::Pending`] container came up for real.
    pub fn spawn_completed(&mut self, cid: u64, t: Micros) {
        self.advance_to(t);
        self.handle_spawn_done(cid);
    }

    /// An asynchronously executed batch (`exec_batch` returned `None`)
    /// finished. Ignored unless the container is still in the store and
    /// executing (a failed spawn's fallback may have completed the batch
    /// virtually in the meantime).
    pub fn batch_completed(&mut self, cid: u64, t: Micros) {
        self.advance_to(t);
        if self.store.get(cid).map(|c| c.state) != Some(CState::Busy) {
            return;
        }
        self.handle_batch_done(cid);
    }

    /// Final settlement: retire whatever is still running (accounting
    /// only), settle energy, stamp the horizon. Returns the recorder and
    /// the driver (so real-time drivers can join their executors).
    pub fn into_parts(self) -> (Recorder, D) {
        let (recorder, driver, _) = self.into_parts_obs();
        (recorder, driver)
    }

    /// [`EngineCore::into_parts`] plus a final observability snapshot
    /// (rolled to the settlement time; `None` when observability was
    /// never enabled).
    pub fn into_parts_obs(mut self) -> (Recorder, D, Option<ObsReport>) {
        let retire_t = self.now.min(self.end);
        for c in self.store.iter() {
            self.recorder.container_retired(c.id, retire_t);
        }
        self.settle_energy(self.end.min(self.now.max(self.horizon)));
        self.recorder.horizon = self.horizon;
        self.recorder.energy_wh = self.energy.total_wh();
        let now = self.now;
        let obs = self.obs.take().map(|mut o| {
            o.roll_to(now);
            o.report(now)
        });
        (self.recorder, self.driver, obs)
    }

    /// [`EngineCore::into_parts_obs`] plus the captured invocation log
    /// (`None` when [`Self::enable_invocation_log`] was never called).
    pub fn into_parts_full(
        mut self,
    ) -> (Recorder, D, Option<ObsReport>, Option<InvocationLog>) {
        let log = self.inv_log.take().map(|b| *b);
        let (recorder, driver, obs) = self.into_parts_obs();
        (recorder, driver, obs, log)
    }

    // ------------------------------------------------------------------
    // event handlers
    // ------------------------------------------------------------------

    fn handle_arrival(&mut self, chain: ChainId) {
        let job_id = self.jobs.len() as u64;
        self.jobs.push(JobState {
            chain,
            arrival: self.now,
            stage_idx: 0,
            stages: Vec::with_capacity(self.cat.chains[chain].stages.len()),
            cur_enqueued: 0,
            cur_exec_start: 0,
            cur_cold_wait: 0,
            done: false,
        });
        // arrival-rate sampling for the predictor; an arrival delivered
        // exactly at a window boundary (before the WindowClose event
        // fires) still counts — clamp into the final bucket instead of
        // silently dropping it from the predictor input.
        let sec_in_window = ((self.now - self.window_start) / MICROS_PER_S) as usize;
        let bucket = sec_in_window.min(self.window_counts.len() - 1);
        self.window_counts[bucket] += 1;
        let chain_name = self.cat.chains[chain].name;
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_arrival(self.now);
            // head-based sampling decision happens here, once per job
            o.on_trace_start(job_id, self.now, chain_name);
        }
        self.enqueue_stage(job_id, self.now);
    }

    fn enqueue_stage(&mut self, job_id: u64, t: Micros) {
        let (chain, stage_idx, arrival) = {
            let j = &mut self.jobs[job_id as usize];
            j.cur_enqueued = t;
            j.cur_cold_wait = 0;
            (j.chain, j.stage_idx, j.arrival)
        };
        let ms_id = self.cat.chains[chain].stages[stage_idx];
        let key = lsf_key(&self.cat, chain, stage_idx, arrival);
        self.seq += 1;
        let entry = QueueEntry {
            job_id,
            lsf_key: key,
            enqueued: t,
            seq: self.seq,
        };
        self.queues[ms_id].push(entry);

        // event-driven per-request spawning is the policy's call (e.g.
        // Bline/BPred spawn the uncovered deficit, §3)
        let mut pol = self.policy.take().expect("policy present");
        let n = pol.on_arrival(ms_id, &self.view(None));
        self.policy = Some(pol);
        for _ in 0..n {
            if self.spawn_container(ms_id, true).is_none() {
                break; // cluster full
            }
        }
        self.try_dispatch(ms_id);
    }

    /// Move queued requests into warm container slots (greedy §4.4.1).
    fn try_dispatch(&mut self, ms_id: MsId) {
        let probe = self.probe_decisions && self.decision_probe % 512 == 0;
        let t0 = probe.then(std::time::Instant::now);
        loop {
            if self.queues[ms_id].is_empty() {
                break;
            }
            let Some(cid) = self.store.pick_container(ms_id) else {
                break;
            };
            let entry = self.queues[ms_id].pop().unwrap();
            if self.store.dispatch(cid, entry.job_id, self.now) {
                if let Some(o) = self.obs.as_deref_mut() {
                    o.on_dispatch(self.now);
                }
                self.start_exec(cid);
            }
        }
        self.decision_probe += 1;
        if let Some(t0) = t0 {
            let ns = t0.elapsed().as_nanos() as u64;
            self.recorder.decision_ns.push(ns);
            // same sample feeds the collector's decision-latency
            // histogram (p50/p95/p99 in /metrics/summary)
            if let Some(o) = self.obs.as_deref_mut() {
                o.on_decision_latency(ns);
            }
        }
    }

    /// Begin executing the container's queued requests as ONE batched
    /// inference pass (continuous batching: everything queued locally at
    /// kick-off time runs together). The driver realizes the execution —
    /// the virtual driver samples exec(B) = exec(1)·(1 + γ·(B−1)), the
    /// real-time driver hands the batch to the container's executor.
    fn start_exec(&mut self, cid: u64) {
        // reusable capture buffer: taken out so the store can fill it
        // while the driver borrows the rest of the engine, restored below
        let mut batch = std::mem::take(&mut self.scratch_batch);
        let b = self.store.begin_batch(cid, &mut batch);
        let dur = self.driver.exec_batch(
            cid,
            &b,
            EffectCtx {
                cat: &self.cat,
                cfg: &self.cfg,
                coldstart: &self.cold,
                now: self.now,
                rng: &mut self.rng,
            },
        );
        for &job_id in &batch {
            let j = &mut self.jobs[job_id as usize];
            j.cur_exec_start = self.now;
            // cold-start attribution: the job waited on this container's
            // spawn if it was enqueued before the container came up.
            j.cur_cold_wait = if b.started_cold && j.cur_enqueued < b.ready_at {
                (self.now - j.cur_enqueued).min(b.spawn_latency)
            } else {
                0
            };
        }
        self.scratch_batch = batch;
        if let Some(d) = dur {
            self.push(self.now + d, Event::BatchDone { cid });
        }
    }

    fn handle_batch_done(&mut self, cid: u64) {
        // reusable drained-jobs buffer (distinct from `scratch_batch`:
        // the advancement loop below can nest a `start_exec`)
        let mut batch_jobs = std::mem::take(&mut self.scratch_done);
        let ms_id = self.store.finish_batch(cid, self.now, &mut batch_jobs);
        self.recorder.container_executed(cid, batch_jobs.len() as u64);
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_batch(self.now, batch_jobs.len() as u64);
        }
        // span tags shared by every job of the finished batch: the
        // container's placement and cold/warm identity (captured now,
        // while the container is certainly still alive) plus the stage
        // name. `None` keeps the whole per-job emission branch-free
        // when tracing is off.
        let span_src = match self.obs.as_deref() {
            Some(o) if o.tracing() => self
                .store
                .get(cid)
                .map(|c| (c.node, c.started_cold, self.cat.microservices[ms_id].name)),
            _ => None,
        };

        // Kick off the next batch immediately: the container must be Busy
        // again *before* job advancement below can trigger spawns (which
        // may evict idle containers — including this one otherwise).
        if !self
            .store
            .get(cid)
            .expect("container alive after finish_batch")
            .local
            .is_empty()
        {
            self.start_exec(cid);
        }

        // per-stage response budget for the optimality log, resolved
        // once per batch (all members share the stage)
        let inv_budget = self
            .inv_log
            .is_some()
            .then(|| ms(self.plan.s_r_for(ms_id)));

        // finalize stage records and advance every job of the batch
        for &job_id in &batch_jobs {
            if let Some(budget) = inv_budget {
                let (enqueued, exec_start) = {
                    let j = &self.jobs[job_id as usize];
                    (j.cur_enqueued, j.cur_exec_start)
                };
                if let Some(log) = self.inv_log.as_deref_mut() {
                    log.entries.push(Invocation {
                        ms_id,
                        enqueued,
                        exec_start,
                        exec_end: self.now,
                        batch: batch_jobs.len() as u32,
                        budget,
                    });
                }
            }
            if let Some((node, cold, stage)) = span_src {
                let (enqueued, exec_start, cold_wait) = {
                    let j = &self.jobs[job_id as usize];
                    (j.cur_enqueued, j.cur_exec_start, j.cur_cold_wait)
                };
                if let Some(o) = self.obs.as_deref_mut() {
                    o.on_stage_span(
                        job_id,
                        StageSpan {
                            stage,
                            enqueued,
                            exec_start,
                            exec_end: self.now,
                            cold_wait,
                            container: cid,
                            node,
                            batch: batch_jobs.len(),
                            cold,
                        },
                    );
                }
            }
            let advance = {
                let j = &mut self.jobs[job_id as usize];
                j.stages.push(StageRecord {
                    ms_id,
                    enqueued: j.cur_enqueued,
                    exec_start: j.cur_exec_start,
                    exec_end: self.now,
                    cold_wait: j.cur_cold_wait,
                });
                j.stage_idx += 1;
                if j.stage_idx >= self.cat.chains[j.chain].stages.len() {
                    j.done = true;
                    None
                } else {
                    Some(job_id)
                }
            };
            match advance {
                None => {
                    self.jobs_done += 1;
                    let j = &mut self.jobs[job_id as usize];
                    let rec = JobRecord {
                        chain: j.chain,
                        arrival: j.arrival,
                        completion: self.now,
                        stages: std::mem::take(&mut j.stages),
                    };
                    if let Some(o) = self.obs.as_deref_mut() {
                        let slo_ok = to_ms(rec.response()) <= self.cat.chains[rec.chain].slo_ms;
                        o.on_job_complete(self.now, job_id, &rec, slo_ok);
                    }
                    self.recorder.job(rec);
                }
                Some(jid) => self.enqueue_stage(jid, self.now),
            }
        }
        self.scratch_done = batch_jobs;

        // refill from the global queue (cid itself may have been evicted
        // by a capacity-pressure spawn during job advancement — fine, the
        // dispatcher picks any warm container of this stage)
        self.try_dispatch(ms_id);
    }

    fn handle_spawn_done(&mut self, cid: u64) {
        // None when the container was already reclaimed — or was never
        // Starting (a zero-latency Pending spawn is warm from birth);
        // in the latter case still offer it queued work
        let ms_id = match self.store.warm_up(cid, self.now) {
            Some(ms_id) => Some(ms_id),
            None => self.store.get(cid).map(|c| c.ms_id),
        };
        if let Some(ms_id) = ms_id {
            self.try_dispatch(ms_id);
        }
    }

    fn handle_window_close(&mut self) {
        // max per-second arrival rate inside the window (paper §4.5)
        let max_rate = self.window_counts.iter().copied().max().unwrap_or(0) as f64;
        if let Some(p) = self.predictor.as_mut() {
            p.observe(max_rate);
        }
        if self.recent_maxima.len() >= self.maxima_keep {
            self.recent_maxima.pop_front();
        }
        self.recent_maxima.push_back(max_rate);
        self.window_counts.iter_mut().for_each(|c| *c = 0);
        self.window_start = self.now;
        self.push(
            self.now + secs(self.cfg.rm.sample_window_s),
            Event::WindowClose,
        );
    }

    /// Forecast for this monitor tick, sanity-clamped: a pre-trained
    /// model queried far out of its training distribution must not
    /// over-provision more than 2x the recently observed peak (§8
    /// "Design Limitations"). `None` when the policy built no predictor.
    fn clamped_forecast(&mut self) -> Option<f64> {
        let recent_max = self
            .recent_maxima
            .iter()
            .copied()
            .fold(0.0f64, f64::max);
        self.predictor
            .as_mut()
            .map(|p| p.forecast().min((2.0 * recent_max).max(1.0)))
    }

    fn run_monitor(&mut self) {
        // host-time the scaling decision only when the probe is armed,
        // so deterministic runs record deterministic zero durations
        let t0 = self.probe_decisions.then(std::time::Instant::now);
        let forecast = self.clamped_forecast();
        let mut pol = self.policy.take().expect("policy present");
        let plan = pol.on_monitor(&self.view(forecast));
        self.policy = Some(pol);
        let spawns_planned = plan.total() as u64;
        self.execute_plan(plan);
        if let Some(o) = self.obs.as_deref_mut() {
            let dur_ns = t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
            o.on_monitor_decision(self.now, spawns_planned, dur_ns);
        }
    }

    fn run_scan(&mut self) {
        let mut pol = self.policy.take().expect("policy present");
        let retire = pol.on_scan(&self.view(None));
        self.policy = Some(pol);
        for cid in retire {
            if self.store.remove(cid).is_some() {
                self.recorder.container_retired(cid, self.now);
                self.recorder.reclaimed += 1;
                if let Some(o) = self.obs.as_deref_mut() {
                    o.on_retire(self.now);
                }
                self.driver.container_retired(cid);
            }
        }
        self.settle_energy(self.now);
        self.recorder
            .energy_series
            .push((self.now, self.energy.total_wh()));
        self.sample_gauges();
    }

    /// One observability gauge sample per scan tick: summed node load,
    /// warm/starting slots across the workload's stages, and global
    /// queue depth. A no-op when observability is off.
    fn sample_gauges(&mut self) {
        if self.obs.is_none() {
            return;
        }
        let mut busy = 0.0;
        let mut alloc = 0.0;
        for i in 0..self.energy.nodes.len() {
            let (b, a) = self.store.node_load(i);
            busy += b;
            alloc += a;
        }
        let mut warm_free = 0usize;
        let mut starting = 0usize;
        for &ms_id in &self.stages {
            warm_free += self.store.warm_free_slots(ms_id);
            starting += self.store.starting_slots(ms_id);
        }
        let queued: usize = self.queues.iter().map(|q| q.len()).sum();
        let g = Gauges {
            containers: self.store.total_containers() as u64,
            warm_free_slots: warm_free as u64,
            starting_slots: starting as u64,
            queue_depth: queued as u64,
            busy_cores: busy,
            alloc_cores: alloc,
        };
        let now = self.now;
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_tick(now, g);
        }
    }

    fn settle_energy(&mut self, t: Micros) {
        for i in 0..self.energy.nodes.len() {
            let (busy, alloc) = self.store.node_load(i);
            self.energy.nodes[i].update(t, busy, alloc, &self.cfg.cluster);
        }
    }

    fn spawn_container(&mut self, ms_id: MsId, cold: bool) -> Option<u64> {
        // capacity guard: one stage may hold at most max_stage_fraction of
        // the cluster's container slots (see RmConfig docs)
        let cap = scaling::stage_cap(
            self.cfg.cluster.max_containers(),
            self.cfg.rm.max_stage_fraction,
        );
        if self.store.stage_containers(ms_id) >= cap {
            return None;
        }
        let batch = self.plan.batch_for(ms_id);
        let effect = self.driver.begin_spawn(
            ms_id,
            cold,
            EffectCtx {
                cat: &self.cat,
                cfg: &self.cfg,
                coldstart: &self.cold,
                now: self.now,
                rng: &mut self.rng,
            },
        );
        let latency = effect.latency();
        let cid = match self.store.spawn(ms_id, batch, self.now, latency, cold) {
            Some(cid) => cid,
            None => {
                // Cluster full. Rebalance by evicting the globally
                // longest-idle container, but only when this stage is
                // genuinely underwater — containerless (startup
                // starvation), or its whole warm pool saturated with
                // nothing starting — and only a victim that has been idle
                // past a grace period (an over-provisioned pool member,
                // not a hot-pool straggler). Otherwise fail: requests
                // queue on the stage's warm pool, as on a full
                // Kubernetes cluster (pods pend, running pods serve).
                let starved = self.store.stage_containers(ms_id) == 0
                    || (self.store.warm_free_slots(ms_id) == 0
                        && self.store.starting_slots(ms_id) == 0);
                if !starved {
                    return None;
                }
                let grace = secs((self.cfg.rm.idle_timeout_s / 2.0).min(30.0));
                let victim = self.store.lru_idle_since(self.now.saturating_sub(grace))?;
                if self.store.get(victim).map(|c| c.ms_id) == Some(ms_id) {
                    return None;
                }
                self.store.remove(victim);
                self.recorder.container_retired(victim, self.now);
                self.recorder.reclaimed += 1;
                if let Some(o) = self.obs.as_deref_mut() {
                    o.on_retire(self.now);
                }
                self.driver.container_retired(victim);
                self.store.spawn(ms_id, batch, self.now, latency, cold)?
            }
        };
        self.recorder.container_spawned(cid, ms_id, self.now, cold);
        if let Some(o) = self.obs.as_deref_mut() {
            o.on_spawn(self.now, cold);
        }
        self.driver.container_spawned(
            cid,
            ms_id,
            batch,
            effect,
            EffectCtx {
                cat: &self.cat,
                cfg: &self.cfg,
                coldstart: &self.cold,
                now: self.now,
                rng: &mut self.rng,
            },
        );
        match effect {
            SpawnEffect::Ready(0) => self.try_dispatch(ms_id),
            SpawnEffect::Ready(l) => self.push(self.now + l, Event::SpawnDone { cid }),
            SpawnEffect::Pending(_) => {}
        }
        Some(cid)
    }

    // ------------------------------------------------------------------
    // sharding support (see crate::coordinator::sharded)
    // ------------------------------------------------------------------

    /// Requests waiting in this engine's global stage queues — the
    /// sharded rebalancer's load signal. O(stages).
    pub fn backlog(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Total provisioned cores this engine currently owns (migrated-away
    /// tombstone nodes contribute 0) — the rebalancer's capacity signal.
    pub fn capacity_cores(&self) -> f64 {
        self.store.capacity_cores()
    }

    /// Give up one empty node's capacity for migration to another shard.
    ///
    /// Picks the highest-id capacity-bearing node that hosts no
    /// containers (warm, starting, or busy) — capacity holding running
    /// containers is never migrated — and only when at least two
    /// capacity-bearing nodes remain, so a shard is never drained to
    /// zero. Returns the cores taken, or `None` when no node is
    /// eligible. The drained node stays as a zero-capacity tombstone
    /// (store indices stay dense; its energy ledger keeps its
    /// accumulated Wh and powers off naturally).
    pub fn donate_node_capacity(&mut self) -> Option<f64> {
        let bearing = self
            .store
            .nodes
            .iter()
            .filter(|n| n.total_cores > 0.0)
            .count();
        if bearing < 2 {
            return None;
        }
        let victim = self
            .store
            .nodes
            .iter()
            .rev()
            .find(|n| n.total_cores > 0.0 && self.store.node_is_empty(n.id))
            .map(|n| n.id)?;
        // settle the victim's energy up to now before its capacity
        // leaves, so the migration boundary is accounted exactly
        let (busy, alloc) = self.store.node_load(victim);
        self.energy.nodes[victim].update(self.now, busy, alloc, &self.cfg.cluster);
        self.store.drain_node(victim).ok()
    }

    /// Accept node capacity migrated from another shard: appends a fresh
    /// node to the store and a matching ledger entry to the energy
    /// model, keeping the two dense per-node lists in lockstep.
    pub fn accept_node_capacity(&mut self, cores: f64) {
        let id = self.store.add_node(cores);
        debug_assert_eq!(id, self.energy.nodes.len());
        self.energy.nodes.push(crate::energy::NodeEnergy::new());
    }

    // ------------------------------------------------------------------
    // invariant checks (used by property tests)
    // ------------------------------------------------------------------

    /// Total requests conserved: every arrival is queued, in-flight, or done.
    pub fn check_conservation(&self) -> Result<(), String> {
        let queued: usize = self.queues.iter().map(|q| q.len()).sum();
        let in_flight: usize = self.store.iter().map(|c| c.local.len()).sum();
        let done = self.jobs.iter().filter(|j| j.done).count();
        // jobs between stages are accounted at enqueue, so:
        let total = self.jobs.len();
        let accounted = queued + in_flight + done;
        if accounted != total {
            return Err(format!(
                "conservation violated: queued {queued} + in-flight {in_flight} + done {done} != {total}"
            ));
        }
        Ok(())
    }

    /// No node over capacity; all store indexes and aggregates consistent.
    pub fn check_store(&self) -> Result<(), String> {
        for n in &self.store.nodes {
            if n.alloc_cores > n.total_cores + 1e-9 {
                return Err(format!("node {} over capacity", n.id));
            }
        }
        self.store.check_consistency()
    }
}
