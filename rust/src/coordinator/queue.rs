//! Per-stage global request queues with LSF or FIFO ordering (§4.3).
//!
//! One queue per *microservice* — chains that share a stage share its
//! queue (the case LSF exists for: queries from different applications
//! with different remaining slack meet in one queue). Fifer pops the entry
//! with the least remaining slack; baselines pop FIFO.
//!
//! The LSF key is time-invariant: at any instant t, remaining slack =
//! (arrival + SLO) − t − remaining_exec; the `t` term is common to all
//! entries, so ordering by `arrival + SLO − remaining_exec` (computed once
//! at enqueue) is equivalent and keeps the heap stable.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::util::Micros;

/// One queued (job, stage) awaiting a container slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    pub job_id: u64,
    /// LSF priority key in µs (smaller = less slack = first).
    pub lsf_key: Micros,
    pub enqueued: Micros,
    /// FIFO tiebreaker / sequence.
    pub seq: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ordering {
    Fifo,
    LeastSlackFirst,
}

/// A single stage's global queue.
#[derive(Debug)]
pub struct StageQueue {
    order: Ordering,
    fifo: VecDeque<QueueEntry>,
    heap: BinaryHeap<Reverse<(Micros, u64, QueueEntryBits)>>,
    /// LSF-mode mirror of waiting (enqueued, seq) keys (multiset, since
    /// the LSF pop order is unrelated to enqueue order). Keeps the
    /// running minimum so `oldest_enqueued` is O(log n) instead of an
    /// O(n) heap scan — the queuing-delay monitor calls it per stage per
    /// tick.
    times: BTreeMap<(Micros, u64), u32>,
    pushed: u64,
    popped: u64,
}

/// BinaryHeap needs Ord; pack the payload alongside the key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct QueueEntryBits {
    job_id: u64,
    enqueued: Micros,
}

impl StageQueue {
    pub fn new(order: Ordering) -> StageQueue {
        StageQueue {
            order,
            fifo: VecDeque::new(),
            heap: BinaryHeap::new(),
            times: BTreeMap::new(),
            pushed: 0,
            popped: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, e: QueueEntry) {
        self.pushed += 1;
        match self.order {
            Ordering::Fifo => self.fifo.push_back(e),
            Ordering::LeastSlackFirst => {
                self.heap.push(Reverse((
                    e.lsf_key,
                    e.seq,
                    QueueEntryBits {
                        job_id: e.job_id,
                        enqueued: e.enqueued,
                    },
                )));
                *self.times.entry((e.enqueued, e.seq)).or_insert(0) += 1;
            }
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<QueueEntry> {
        let e = match self.order {
            Ordering::Fifo => self.fifo.pop_front(),
            Ordering::LeastSlackFirst => self.heap.pop().map(|Reverse((key, seq, bits))| {
                if let Some(n) = self.times.get_mut(&(bits.enqueued, seq)) {
                    *n -= 1;
                    if *n == 0 {
                        self.times.remove(&(bits.enqueued, seq));
                    }
                }
                QueueEntry {
                    job_id: bits.job_id,
                    lsf_key: key,
                    enqueued: bits.enqueued,
                    seq,
                }
            }),
        };
        if e.is_some() {
            self.popped += 1;
        }
        e
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self.order {
            Ordering::Fifo => self.fifo.len(),
            Ordering::LeastSlackFirst => self.heap.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        match self.order {
            Ordering::Fifo => self.fifo.is_empty(),
            Ordering::LeastSlackFirst => self.heap.is_empty(),
        }
    }

    /// Oldest enqueue time still waiting (for queuing-delay monitoring).
    /// O(1) for FIFO; O(log n) for LSF via the running-minimum mirror.
    pub fn oldest_enqueued(&self) -> Option<Micros> {
        match self.order {
            Ordering::Fifo => self.fifo.front().map(|e| e.enqueued),
            Ordering::LeastSlackFirst => self.times.keys().next().map(|&(t, _)| t),
        }
    }

    /// Conservation counters: (pushed, popped). pushed - popped == len.
    #[inline]
    pub fn counters(&self) -> (u64, u64) {
        (self.pushed, self.popped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(job: u64, key: Micros, seq: u64) -> QueueEntry {
        QueueEntry {
            job_id: job,
            lsf_key: key,
            enqueued: seq * 10,
            seq,
        }
    }

    #[test]
    fn fifo_order() {
        let mut q = StageQueue::new(Ordering::Fifo);
        q.push(e(1, 500, 0));
        q.push(e(2, 100, 1));
        q.push(e(3, 300, 2));
        assert_eq!(q.pop().unwrap().job_id, 1);
        assert_eq!(q.pop().unwrap().job_id, 2);
        assert_eq!(q.pop().unwrap().job_id, 3);
        assert!(q.pop().is_none());
    }

    #[test]
    fn lsf_pops_least_slack() {
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        q.push(e(1, 500, 0));
        q.push(e(2, 100, 1));
        q.push(e(3, 300, 2));
        assert_eq!(q.pop().unwrap().job_id, 2);
        assert_eq!(q.pop().unwrap().job_id, 3);
        assert_eq!(q.pop().unwrap().job_id, 1);
    }

    #[test]
    fn lsf_ties_broken_by_arrival_order() {
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        q.push(e(1, 100, 0));
        q.push(e(2, 100, 1));
        q.push(e(3, 100, 2));
        assert_eq!(q.pop().unwrap().job_id, 1);
        assert_eq!(q.pop().unwrap().job_id, 2);
        assert_eq!(q.pop().unwrap().job_id, 3);
    }

    #[test]
    fn conservation_counters() {
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        for i in 0..10 {
            q.push(e(i, i * 7 % 5, i));
        }
        for _ in 0..4 {
            q.pop();
        }
        let (pushed, popped) = q.counters();
        assert_eq!(pushed, 10);
        assert_eq!(popped, 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn oldest_enqueued_tracks_interleaved_pops() {
        // the LSF pop order is unrelated to enqueue order, so the
        // running-minimum mirror must survive arbitrary interleavings
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        for i in 0..50u64 {
            q.push(e(i, (i * 37) % 11, i)); // enqueued = 10*i
        }
        let mut waiting: Vec<Micros> = (0..50).map(|i| i * 10).collect();
        while let Some(popped) = {
            let oldest = q.oldest_enqueued();
            assert_eq!(oldest, waiting.iter().copied().min());
            q.pop()
        } {
            let pos = waiting.iter().position(|&t| t == popped.enqueued).unwrap();
            waiting.swap_remove(pos);
        }
        assert_eq!(q.oldest_enqueued(), None);
    }

    #[test]
    fn fifo_interleaved_equal_keys_keeps_arrival_order() {
        // FIFO ignores the LSF key entirely: pops under interleaved
        // push/pop at one shared key must come out in push order, and the
        // conservation counters must balance at every step
        let mut q = StageQueue::new(Ordering::Fifo);
        q.push(e(1, 100, 0));
        q.push(e(2, 100, 1));
        assert_eq!(q.pop().unwrap().job_id, 1);
        q.push(e(3, 100, 2));
        q.push(e(4, 100, 3));
        assert_eq!(q.pop().unwrap().job_id, 2);
        assert_eq!(q.pop().unwrap().job_id, 3);
        q.push(e(5, 100, 4));
        assert_eq!(q.pop().unwrap().job_id, 4);
        assert_eq!(q.pop().unwrap().job_id, 5);
        let (pushed, popped) = q.counters();
        assert_eq!((pushed, popped), (5, 5));
        assert_eq!(pushed - popped, q.len() as u64);
        assert!(q.pop().is_none());
        assert_eq!(q.counters(), (5, 5), "empty pop must not count");
    }

    #[test]
    fn lsf_interleaved_equal_keys_tie_break_by_seq() {
        // all entries share one lsf key; pops interleaved with pushes
        // must always yield the lowest outstanding seq (heap stability is
        // guaranteed by the (key, seq) tuple, not the heap itself)
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        q.push(e(10, 100, 5));
        q.push(e(11, 100, 2));
        assert_eq!(q.pop().unwrap().job_id, 11); // seq 2 < 5
        q.push(e(12, 100, 1));
        q.push(e(13, 100, 9));
        assert_eq!(q.pop().unwrap().job_id, 12); // seq 1
        assert_eq!(q.pop().unwrap().job_id, 10); // seq 5
        q.push(e(14, 100, 7));
        assert_eq!(q.pop().unwrap().job_id, 14); // seq 7 < 9
        assert_eq!(q.pop().unwrap().job_id, 13);
        let (pushed, popped) = q.counters();
        assert_eq!((pushed, popped), (5, 5));
        assert!(q.is_empty());
    }

    #[test]
    fn counters_balance_under_interleaving() {
        // pushed - popped == len holds at every point of a mixed
        // push/pop sequence, for both orderings
        for order in [Ordering::Fifo, Ordering::LeastSlackFirst] {
            let mut q = StageQueue::new(order);
            for i in 0..30u64 {
                q.push(e(i, 100, i)); // equal keys: worst case for LSF
                if i % 3 == 0 {
                    q.pop();
                }
                let (pushed, popped) = q.counters();
                assert_eq!(pushed - popped, q.len() as u64, "{order:?} at {i}");
            }
        }
    }

    #[test]
    fn oldest_enqueued() {
        let mut q = StageQueue::new(Ordering::LeastSlackFirst);
        assert_eq!(q.oldest_enqueued(), None);
        q.push(e(1, 900, 3)); // enqueued 30
        q.push(e(2, 100, 1)); // enqueued 10
        assert_eq!(q.oldest_enqueued(), Some(10));
        q.pop(); // pops job 2 (least slack) -> oldest now 30
        assert_eq!(q.oldest_enqueued(), Some(30));
    }
}
