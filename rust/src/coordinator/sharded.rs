//! Sharded coordinator: N independent [`EngineCore`] shards behind a
//! stable chain-hash router, with conservative capacity rebalancing.
//!
//! The paper's §6.1.5 probe shows a single coordinator's decision
//! latency becoming the bottleneck at millions-of-users arrival rates.
//! Sharding splits the cluster into N engines, each owning a slice of
//! node capacity and serving the chains that hash to it. Three pieces:
//!
//! * [`ShardRouter`] — maps a `ChainId` to a shard with splitmix64 over
//!   `chain ⊕ seed`. No `DefaultHasher`: the assignment is
//!   bit-reproducible across platforms and rustc versions, which the
//!   byte-determinism contract (DESIGN.md §Sharding) depends on.
//! * [`Rebalancer`] — watches per-shard pressure (backlog per
//!   provisioned core) on monitor ticks and migrates one empty node's
//!   capacity from the least- to the most-pressured shard, with
//!   hysteresis (K consecutive imbalanced ticks) and a cooldown so
//!   capacity doesn't thrash. Capacity holding running containers is
//!   never migrated ([`EngineCore::donate_node_capacity`] refuses).
//! * [`ShardedCoordinator`] — owns the shards and wires the two
//!   together. Drivers (sim lockstep loop, live shard threads) decide
//!   *when* to advance shards and call [`ShardedCoordinator::rebalance_once`];
//!   this module decides *where* work and capacity go.

use crate::coordinator::engine::{Driver, EngineCore};
use crate::model::ChainId;

/// splitmix64 finalizer (Steele et al., "Fast splittable pseudorandom
/// number generators"). Same constants as `util::rng`, duplicated here
/// so the router has no dependency on the PRNG module's internals.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Stable chain → shard map. Stateless and `Copy`: routing 1M arrivals
/// is a hash and a modulo, no allocation (pinned by `perf_hotpath`).
#[derive(Clone, Copy, Debug)]
pub struct ShardRouter {
    seed: u64,
    shards: usize,
}

impl ShardRouter {
    pub fn new(seed: u64, shards: usize) -> Self {
        Self { seed, shards: shards.max(1) }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard index for a chain. All invocations of a chain land on the
    /// same shard, so per-chain state (warm pools, batching queues)
    /// never splits.
    #[inline]
    pub fn route(&self, chain: ChainId) -> usize {
        (splitmix64(chain as u64 ^ self.seed) % self.shards as u64) as usize
    }
}

/// Split `total` items across `shards` as evenly as possible; returns
/// the count for shard `k` (the first `total % shards` shards get one
/// extra). Used to partition nodes and live executor threads.
pub fn partition_count(total: usize, shards: usize, k: usize) -> usize {
    let shards = shards.max(1);
    total / shards + usize::from(k < total % shards)
}

/// Rebalancer tuning. Defaults are deliberately conservative: a shard
/// must look overloaded for `hysteresis_ticks` consecutive monitor
/// ticks before one node moves, and after a migration the rebalancer
/// sits out `cooldown_ticks` so the move can take effect.
#[derive(Clone, Copy, Debug)]
pub struct RebalancerConfig {
    /// Fire when max pressure exceeds `pressure_ratio` × min pressure…
    pub pressure_ratio: f64,
    /// …by at least this absolute gap (filters noise near zero load).
    pub min_gap: f64,
    /// Consecutive imbalanced ticks required before migrating.
    pub hysteresis_ticks: u32,
    /// Ticks to sit out after a migration.
    pub cooldown_ticks: u32,
}

impl Default for RebalancerConfig {
    fn default() -> Self {
        Self { pressure_ratio: 2.0, min_gap: 0.25, hysteresis_ticks: 3, cooldown_ticks: 3 }
    }
}

/// Deterministic pressure-based capacity rebalancer. Pure arithmetic
/// over the pressure vector — no RNG, no clock — so sim runs stay
/// byte-reproducible.
#[derive(Clone, Debug, Default)]
pub struct Rebalancer {
    pub cfg: RebalancerConfig,
    streak: u32,
    cooldown: u32,
    migrations: u64,
}

impl Rebalancer {
    pub fn new(cfg: RebalancerConfig) -> Self {
        Self { cfg, streak: 0, cooldown: 0, migrations: 0 }
    }

    /// Total migrations performed so far.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// One monitor tick: given per-shard pressures (backlog per core),
    /// decide whether to migrate and from/to whom. Returns
    /// `Some((donor, receiver))` when a move should happen; the caller
    /// performs it and must report back via [`Rebalancer::record`].
    pub fn plan(&mut self, pressures: &[f64]) -> Option<(usize, usize)> {
        if pressures.len() < 2 {
            return None;
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        let mut lo = 0usize;
        let mut hi = 0usize;
        for (i, &p) in pressures.iter().enumerate() {
            if p < pressures[lo] {
                lo = i;
            }
            if p > pressures[hi] {
                hi = i;
            }
        }
        let imbalanced = pressures[hi] > pressures[lo] * self.cfg.pressure_ratio + self.cfg.min_gap;
        if !imbalanced {
            self.streak = 0;
            return None;
        }
        self.streak += 1;
        if self.streak < self.cfg.hysteresis_ticks {
            return None;
        }
        self.streak = 0;
        Some((lo, hi))
    }

    /// Record a completed migration and arm the cooldown.
    pub fn record(&mut self) {
        self.migrations += 1;
        self.cooldown = self.cfg.cooldown_ticks;
    }
}

/// N engines + router + rebalancer. Generic over the driver like
/// [`EngineCore`] itself: the sim wraps `EngineCore<VirtualDriver>`
/// shards and advances them in lockstep epochs; the live server runs
/// one `EngineCore<RealTimeDriver>` per thread and uses the router and
/// rebalancer standalone (see `server::serve_sharded`).
pub struct ShardedCoordinator<D: Driver> {
    shards: Vec<EngineCore<D>>,
    router: ShardRouter,
    rebalancer: Rebalancer,
}

impl<D: Driver> ShardedCoordinator<D> {
    pub fn new(shards: Vec<EngineCore<D>>, router_seed: u64, rcfg: RebalancerConfig) -> Self {
        let n = shards.len().max(1);
        Self {
            shards,
            router: ShardRouter::new(router_seed, n),
            rebalancer: Rebalancer::new(rcfg),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router(&self) -> ShardRouter {
        self.router
    }

    /// Shard index an arriving chain invocation belongs to.
    #[inline]
    pub fn route(&self, chain: ChainId) -> usize {
        self.router.route(chain)
    }

    pub fn shards(&self) -> &[EngineCore<D>] {
        &self.shards
    }

    pub fn shards_mut(&mut self) -> &mut [EngineCore<D>] {
        &mut self.shards
    }

    pub fn shard_mut(&mut self, k: usize) -> &mut EngineCore<D> {
        &mut self.shards[k]
    }

    pub fn migrations(&self) -> u64 {
        self.rebalancer.migrations()
    }

    /// Per-shard pressure vector: queued requests per provisioned core.
    pub fn pressures(&self) -> Vec<f64> {
        self.shards
            .iter()
            .map(|s| s.backlog() as f64 / s.capacity_cores().max(1e-9))
            .collect()
    }

    /// One rebalance tick: compute pressures, ask the rebalancer for a
    /// plan, and migrate one empty node's capacity donor → receiver.
    /// Returns `Some((donor, receiver, cores))` when capacity moved.
    /// No-op (and allocation-free on the engine side) when balanced,
    /// cooling down, or when the donor has no eligible empty node.
    pub fn rebalance_once(&mut self) -> Option<(usize, usize, f64)> {
        let pressures = self.pressures();
        let (donor, receiver) = self.rebalancer.plan(&pressures)?;
        let cores = self.shards[donor].donate_node_capacity()?;
        self.shards[receiver].accept_node_capacity(cores);
        self.rebalancer.record();
        Some((donor, receiver, cores))
    }

    /// Tear down into the per-shard engines (for result extraction).
    pub fn into_shards(self) -> Vec<EngineCore<D>> {
        self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_matches_reference_vectors() {
        // First value is the canonical seed-0 splitmix64 output; the
        // second pins the finalizer applied to its own output.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(splitmix64(0)), 0xA706_DD2F_4D19_7E6F);
    }

    #[test]
    fn router_is_stable_and_in_range() {
        let r = ShardRouter::new(42, 4);
        for chain in 0..64usize {
            let a = r.route(chain);
            assert!(a < 4);
            assert_eq!(a, r.route(chain), "routing must be stable");
        }
        // one shard ⇒ everything routes to 0
        let one = ShardRouter::new(42, 1);
        assert!((0..64).all(|c| one.route(c) == 0));
    }

    #[test]
    fn router_spreads_chains_across_shards() {
        // With many chains, no shard should be starved. (The built-in
        // catalog only has 4 chains — see DESIGN.md §Sharding for the
        // degeneracy note — so test the hash itself over a wider id
        // space.)
        let r = ShardRouter::new(7, 4);
        let mut counts = [0usize; 4];
        for chain in 0..4096usize {
            counts[r.route(chain)] += 1;
        }
        for (k, &c) in counts.iter().enumerate() {
            assert!(c > 512, "shard {k} starved: {c}/4096 chains");
        }
    }

    #[test]
    fn partition_count_is_exhaustive_and_even() {
        for total in 0..20usize {
            for shards in 1..6usize {
                let parts: Vec<usize> =
                    (0..shards).map(|k| partition_count(total, shards, k)).collect();
                assert_eq!(parts.iter().sum::<usize>(), total);
                let (min, max) = (parts.iter().min().unwrap(), parts.iter().max().unwrap());
                assert!(max - min <= 1, "uneven split {parts:?}");
            }
        }
    }

    #[test]
    fn rebalancer_requires_sustained_imbalance() {
        let mut rb = Rebalancer::new(RebalancerConfig {
            pressure_ratio: 2.0,
            min_gap: 0.25,
            hysteresis_ticks: 3,
            cooldown_ticks: 2,
        });
        let hot = [0.1, 5.0];
        // two imbalanced ticks: hysteresis not yet met
        assert_eq!(rb.plan(&hot), None);
        assert_eq!(rb.plan(&hot), None);
        // a balanced tick resets the streak
        assert_eq!(rb.plan(&[1.0, 1.0]), None);
        assert_eq!(rb.plan(&hot), None);
        assert_eq!(rb.plan(&hot), None);
        // third consecutive imbalanced tick fires, least → most pressured
        assert_eq!(rb.plan(&hot), Some((0, 1)));
        rb.record();
        assert_eq!(rb.migrations(), 1);
        // cooldown: next two ticks sit out even though still imbalanced
        assert_eq!(rb.plan(&hot), None);
        assert_eq!(rb.plan(&hot), None);
        // then the streak must build up again from zero
        assert_eq!(rb.plan(&hot), None);
        assert_eq!(rb.plan(&hot), None);
        assert_eq!(rb.plan(&hot), Some((0, 1)));
    }

    #[test]
    fn rebalancer_ignores_single_shard_and_balanced_load() {
        let mut rb = Rebalancer::new(RebalancerConfig::default());
        assert_eq!(rb.plan(&[9.0]), None, "one shard: nothing to do");
        for _ in 0..10 {
            assert_eq!(rb.plan(&[1.0, 1.1, 0.9]), None);
        }
        assert_eq!(rb.migrations(), 0);
    }
}
