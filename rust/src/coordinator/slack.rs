//! Slack estimation, distribution, and batch-size calculation (§3, §4.1).
//!
//! * **Slack** of a chain = response-latency SLO minus end-to-end
//!   execution time (the paper uses the measured Table 4 values, which
//!   also fold in measured framework overheads; we support both).
//! * **Distribution** splits chain slack across stages — proportional to
//!   stage execution time (Fifer) or equal division (SBatch).
//! * **Batch size** per stage is Eq. 1: `B_size = stage_slack / exec`.
//!   Queuing at most `B_size` requests back-to-back on one container keeps
//!   the worst queued request within its stage's slack budget.

use std::collections::HashMap;

use crate::config::{RmConfig, SlackPolicy};
use crate::model::{Catalog, ChainId, MsId};

/// Per-stage plan derived from the catalog for one workload mix.
#[derive(Debug, Clone)]
pub struct SlackPlan {
    /// (chain, stage_idx) -> allocated slack in ms.
    pub stage_slack_ms: HashMap<(ChainId, usize), f64>,
    /// Per microservice: batch size for its containers. Shared stages take
    /// the *minimum* across chains (conservative: strictest SLO wins).
    pub batch: HashMap<MsId, usize>,
    /// Per microservice: per-stage response budget S_r = slack + exec (ms),
    /// again minimized across sharing chains.
    pub s_r_ms: HashMap<MsId, f64>,
    /// Per microservice: mean remaining exec from each chain position is
    /// needed for LSF keys; this caches mean exec per ms.
    pub exec_ms: HashMap<MsId, f64>,
}

/// Distribute a chain's slack across its stages.
pub fn distribute_slack(
    cat: &Catalog,
    chain: ChainId,
    policy: SlackPolicy,
    use_table4_slack: bool,
) -> Vec<f64> {
    let c = &cat.chains[chain];
    let total_slack = if use_table4_slack {
        c.slack_ms
    } else {
        (c.slo_ms - c.total_exec_ms(cat)).max(0.0)
    };
    let n = c.stages.len();
    match policy {
        SlackPolicy::EqualDivision => vec![total_slack / n as f64; n],
        SlackPolicy::Proportional => {
            let total_exec: f64 = c.total_exec_ms(cat);
            c.stages
                .iter()
                .map(|&s| total_slack * cat.microservices[s].exec_ms_mean / total_exec)
                .collect()
        }
    }
}

/// Eq. 1, clamped to [1, max_batch].
pub fn batch_size(stage_slack_ms: f64, exec_ms: f64, max_batch: usize) -> usize {
    if exec_ms <= 0.0 {
        return max_batch.max(1);
    }
    ((stage_slack_ms / exec_ms).floor() as usize).clamp(1, max_batch.max(1))
}

impl SlackPlan {
    /// Build the plan for the chains of a workload mix.
    ///
    /// `batching = false` (Bline/BPred) forces batch size 1 at every stage
    /// and a per-stage budget equal to the exec time only.
    pub fn build(cat: &Catalog, chains: &[ChainId], rm: &RmConfig, batching: bool) -> SlackPlan {
        let mut plan = SlackPlan {
            stage_slack_ms: HashMap::new(),
            batch: HashMap::new(),
            s_r_ms: HashMap::new(),
            exec_ms: HashMap::new(),
        };
        for &cid in chains {
            let slacks = distribute_slack(cat, cid, rm.slack_policy, true);
            for (idx, &ms_id) in cat.chains[cid].stages.iter().enumerate() {
                let exec = cat.microservices[ms_id].exec_ms_mean;
                plan.exec_ms.insert(ms_id, exec);
                let sl = if batching { slacks[idx] } else { 0.0 };
                plan.stage_slack_ms.insert((cid, idx), sl);
                let b = if batching {
                    batch_size(sl, exec, rm.max_batch)
                } else {
                    1
                };
                let s_r = sl + exec;
                plan.batch
                    .entry(ms_id)
                    .and_modify(|e| *e = (*e).min(b))
                    .or_insert(b);
                plan.s_r_ms
                    .entry(ms_id)
                    .and_modify(|e: &mut f64| *e = e.min(s_r))
                    .or_insert(s_r);
            }
        }
        plan
    }

    pub fn batch_for(&self, ms_id: MsId) -> usize {
        self.batch.get(&ms_id).copied().unwrap_or(1)
    }

    pub fn s_r_for(&self, ms_id: MsId) -> f64 {
        self.s_r_ms
            .get(&ms_id)
            .copied()
            .unwrap_or_else(|| self.exec_ms.get(&ms_id).copied().unwrap_or(1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    #[test]
    fn proportional_sums_to_total() {
        let cat = Catalog::paper();
        for cid in 0..cat.chains.len() {
            let s = distribute_slack(&cat, cid, SlackPolicy::Proportional, true);
            let total: f64 = s.iter().sum();
            assert!((total - cat.chains[cid].slack_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn equal_division_uniform() {
        let cat = Catalog::paper();
        let cid = cat.chain_id("IPA").unwrap();
        let s = distribute_slack(&cat, cid, SlackPolicy::EqualDivision, true);
        assert_eq!(s.len(), 3);
        assert!((s[0] - s[1]).abs() < 1e-9 && (s[1] - s[2]).abs() < 1e-9);
        assert!((s.iter().sum::<f64>() - 697.0).abs() < 1e-9);
    }

    #[test]
    fn proportional_tracks_exec_time() {
        // DetectFatigue: HS (151.2ms) gets the lion's share of 572ms slack
        let cat = Catalog::paper();
        let cid = cat.chain_id("DetectFatigue").unwrap();
        let s = distribute_slack(&cat, cid, SlackPolicy::Proportional, true);
        assert!(s[0] > 0.75 * 572.0, "{:?}", s);
    }

    #[test]
    fn computed_slack_mode() {
        let cat = Catalog::paper();
        let cid = cat.chain_id("FaceSecurity").unwrap();
        let s = distribute_slack(&cat, cid, SlackPolicy::Proportional, false);
        // SLO 1000 - exec (11.6) = 988.4 total
        assert!((s.iter().sum::<f64>() - 988.4).abs() < 1e-6);
    }

    #[test]
    fn batch_size_eq1() {
        assert_eq!(batch_size(100.0, 10.0, 32), 10);
        assert_eq!(batch_size(5.0, 10.0, 32), 1); // floor 0 -> clamp 1
        assert_eq!(batch_size(10_000.0, 0.1, 32), 32); // cap
        assert_eq!(batch_size(10.0, 0.0, 32), 32); // degenerate exec
    }

    #[test]
    fn plan_shared_stage_takes_min_batch() {
        let cat = Catalog::paper();
        let rm = RmConfig::paper(Policy::Fifer);
        // IPA and IMG share NLP and QA with different slacks
        let chains = vec![
            cat.chain_id("IPA").unwrap(),
            cat.chain_id("IMG").unwrap(),
        ];
        let plan = SlackPlan::build(&cat, &chains, &rm, true);
        let qa = cat.ms_id("QA").unwrap();
        let b_ipa = {
            let sl = plan.stage_slack_ms[&(chains[0], 2)];
            batch_size(sl, 56.1, rm.max_batch)
        };
        let b_img = {
            let sl = plan.stage_slack_ms[&(chains[1], 2)];
            batch_size(sl, 56.1, rm.max_batch)
        };
        assert_eq!(plan.batch_for(qa), b_ipa.min(b_img));
    }

    #[test]
    fn plan_non_batching_forces_one() {
        let cat = Catalog::paper();
        let rm = RmConfig::paper(Policy::Bline);
        let chains: Vec<ChainId> = (0..cat.chains.len()).collect();
        let plan = SlackPlan::build(&cat, &chains, &rm, false);
        for (&ms, &b) in &plan.batch {
            assert_eq!(b, 1, "ms {ms}");
            // S_r reduces to exec time
            assert!((plan.s_r_for(ms) - plan.exec_ms[&ms]).abs() < 1e-9);
        }
    }

    #[test]
    fn plan_batch_sizes_sane_for_paper_mixes() {
        let cat = Catalog::paper();
        let rm = RmConfig::paper(Policy::Fifer);
        let mix = cat.mix("Heavy").unwrap().clone();
        let plan = SlackPlan::build(&cat, &mix.chains, &rm, true);
        // every stage of the heavy mix gets a batch in [1, 32]
        for &ms in &cat.mix_stages(&mix) {
            let b = plan.batch_for(ms);
            assert!((1..=32).contains(&b), "ms {ms} batch {b}");
        }
        // the bottleneck ASR stage gets a meaningful batch (> 1)
        assert!(plan.batch_for(cat.ms_id("ASR").unwrap()) > 1);
    }
}
