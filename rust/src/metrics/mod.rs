//! Metrics: per-request timelines, container accounting, summaries.
//!
//! Everything the paper's evaluation reports is computed here (§5.3):
//! (i) % SLO violations, (ii) average containers spawned, (iii) median and
//! P99 tail latency with its breakdown (exec / cold-start / batching),
//! (iv) container utilization as requests-per-container (RPC),
//! (v) cluster energy — plus the time series for Figs. 10/12/16.

use std::collections::{BTreeMap, HashMap};

use crate::model::{Catalog, ChainId, MsId};
use crate::util::json::Json;
use crate::util::{stats, to_ms, Micros, MICROS_PER_S};

/// Timeline of one stage of one job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageRecord {
    pub ms_id: MsId,
    /// When the request entered the stage's global queue.
    pub enqueued: Micros,
    /// When execution began in a container.
    pub exec_start: Micros,
    /// When execution finished.
    pub exec_end: Micros,
    /// Portion of the wait caused by a container cold start.
    pub cold_wait: Micros,
}

impl StageRecord {
    pub fn queue_wait(&self) -> Micros {
        self.exec_start.saturating_sub(self.enqueued)
    }

    pub fn exec(&self) -> Micros {
        self.exec_end.saturating_sub(self.exec_start)
    }

    /// Wait not attributable to cold start = batching/queuing delay.
    pub fn batch_wait(&self) -> Micros {
        self.queue_wait().saturating_sub(self.cold_wait)
    }
}

/// Timeline of one job (one request through a whole chain).
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub chain: ChainId,
    pub arrival: Micros,
    pub completion: Micros,
    pub stages: Vec<StageRecord>,
}

impl JobRecord {
    pub fn response(&self) -> Micros {
        self.completion.saturating_sub(self.arrival)
    }

    pub fn exec_total(&self) -> Micros {
        self.stages.iter().map(|s| s.exec()).sum()
    }

    pub fn cold_total(&self) -> Micros {
        self.stages.iter().map(|s| s.cold_wait).sum()
    }

    pub fn batch_total(&self) -> Micros {
        self.stages.iter().map(|s| s.batch_wait()).sum()
    }
}

/// Per-container usage record (for RPC / Fig. 12a).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ContainerRecord {
    pub ms_id: MsId,
    pub spawned_at: Micros,
    pub retired_at: Option<Micros>,
    pub jobs_executed: u64,
    pub was_cold: bool,
}

/// Event log + aggregation for one run.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    pub jobs: Vec<JobRecord>,
    pub containers: Vec<ContainerRecord>,
    container_index: HashMap<u64, usize>,
    pub cold_starts: u64,
    /// Batched execution passes kicked off (one per `container_executed`
    /// call) — the denominator of the realized average batch size.
    pub batches: u64,
    /// Containers retired by policy reclamation or capacity eviction
    /// *during* the run (end-of-run accounting retirement excluded).
    pub reclaimed: u64,
    pub energy_wh: f64,
    /// Cumulative cluster energy sampled over time (µs, Wh) — lets
    /// summaries exclude the warm-up transient consistently.
    pub energy_series: Vec<(Micros, f64)>,
    /// Wall-clock (sim) duration of the run.
    pub horizon: Micros,
    /// Per-scheduling-decision latencies (µs of *host* time), §6.1.5.
    pub decision_ns: Vec<u64>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn job(&mut self, rec: JobRecord) {
        self.jobs.push(rec);
    }

    pub fn container_spawned(&mut self, cid: u64, ms_id: MsId, t: Micros, cold: bool) {
        if cold {
            self.cold_starts += 1;
        }
        self.container_index.insert(cid, self.containers.len());
        self.containers.push(ContainerRecord {
            ms_id,
            spawned_at: t,
            retired_at: None,
            jobs_executed: 0,
            was_cold: cold,
        });
    }

    pub fn container_executed(&mut self, cid: u64, jobs: u64) {
        self.batches += 1;
        if let Some(&i) = self.container_index.get(&cid) {
            self.containers[i].jobs_executed += jobs;
        }
    }

    pub fn container_retired(&mut self, cid: u64, t: Micros) {
        if let Some(&i) = self.container_index.get(&cid) {
            self.containers[i].retired_at = Some(t);
        }
    }

    /// Containers alive at each `bin_s`-second boundary (Fig. 12b).
    pub fn containers_over_time(&self, bin_s: u64) -> Vec<(f64, usize)> {
        if self.horizon == 0 {
            return Vec::new();
        }
        let bins = (self.horizon / (bin_s * MICROS_PER_S)) as usize + 1;
        let mut out = Vec::with_capacity(bins);
        for b in 0..bins {
            let t = b as u64 * bin_s * MICROS_PER_S;
            let alive = self
                .containers
                .iter()
                .filter(|c| c.spawned_at <= t && c.retired_at.map(|r| r > t).unwrap_or(true))
                .count();
            out.push((t as f64 / MICROS_PER_S as f64, alive));
        }
        out
    }

    /// Time-averaged number of live containers (the paper's "average
    /// number of containers spawned" metric).
    pub fn avg_containers(&self) -> f64 {
        self.avg_containers_after(0)
    }

    /// Time-averaged live containers over [from, horizon].
    pub fn avg_containers_after(&self, from: Micros) -> f64 {
        if self.horizon <= from {
            return 0.0;
        }
        let mut area = 0.0f64;
        for c in &self.containers {
            let start = c.spawned_at.max(from);
            let end = c.retired_at.unwrap_or(self.horizon).min(self.horizon);
            area += end.saturating_sub(start) as f64;
        }
        area / (self.horizon - from) as f64
    }

    /// Cold starts binned over time (Fig. 16).
    pub fn coldstarts_over_time(&self, bin_s: u64) -> Vec<(f64, u64)> {
        if self.horizon == 0 {
            return Vec::new();
        }
        let nbins = (self.horizon / (bin_s * MICROS_PER_S)) as usize + 1;
        let mut bins = vec![0u64; nbins];
        for c in self.containers.iter().filter(|c| c.was_cold) {
            let b = (c.spawned_at / (bin_s * MICROS_PER_S)) as usize;
            if b < nbins {
                bins[b] += 1;
            }
        }
        bins.iter()
            .enumerate()
            .map(|(i, &n)| (i as f64 * bin_s as f64, n))
            .collect()
    }

    pub fn summarize(&self, cat: &Catalog) -> Summary {
        self.summarize_after(cat, 0)
    }

    /// Summarize jobs arriving at or after `warmup` (µs). Experiments use
    /// this to exclude the initial cold-start transient, matching the
    /// paper's steady-state measurements on long-running clusters.
    pub fn summarize_after(&self, cat: &Catalog, warmup: Micros) -> Summary {
        let mut responses: Vec<f64> = Vec::with_capacity(self.jobs.len());
        let mut violations = 0u64;
        let mut queue_waits: Vec<f64> = Vec::new();
        let mut cold_jobs = 0u64;
        let (mut exec_sum, mut cold_sum, mut batch_sum) = (0.0f64, 0.0f64, 0.0f64);
        let jobs: Vec<&JobRecord> = self.jobs.iter().filter(|j| j.arrival >= warmup).collect();
        for j in &jobs {
            let resp = to_ms(j.response());
            responses.push(resp);
            if resp > cat.chains[j.chain].slo_ms {
                violations += 1;
            }
            if j.cold_total() > 0 {
                cold_jobs += 1;
            }
            exec_sum += to_ms(j.exec_total());
            cold_sum += to_ms(j.cold_total());
            batch_sum += to_ms(j.batch_total());
            for s in &j.stages {
                queue_waits.push(to_ms(s.queue_wait()));
            }
        }
        responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        queue_waits.sort_by(|a, b| a.partial_cmp(b).unwrap());

        // P99 breakdown: average composition of the top 1% of jobs by
        // response time (strict top-k avoids dilution by ties at P99).
        let p99 = stats::percentile_sorted(&responses, 99.0);
        let mut tail = Breakdown::default();
        if !jobs.is_empty() {
            let k = (jobs.len() / 100).max(1);
            let mut by_resp: Vec<&JobRecord> = jobs.clone();
            by_resp.sort_by_key(|j| std::cmp::Reverse(j.response()));
            for j in &by_resp[..k] {
                tail.exec_ms += to_ms(j.exec_total());
                tail.cold_ms += to_ms(j.cold_total());
                tail.batch_ms += to_ms(j.batch_total());
            }
            tail.exec_ms /= k as f64;
            tail.cold_ms /= k as f64;
            tail.batch_ms /= k as f64;
        }

        // RPC per stage (containers still alive in the measurement window)
        let mut per_stage: HashMap<MsId, StageStats> = HashMap::new();
        for c in &self.containers {
            if c.retired_at.map(|r| r < warmup).unwrap_or(false) {
                continue;
            }
            let e = per_stage.entry(c.ms_id).or_default();
            e.containers += 1;
            e.jobs += c.jobs_executed;
            if c.was_cold {
                e.cold_starts += 1;
            }
        }

        // energy over the measurement window [warmup, horizon]
        let energy_wh = if warmup == 0 || self.energy_series.is_empty() {
            self.energy_wh
        } else {
            let at_warmup = self
                .energy_series
                .iter()
                .take_while(|(t, _)| *t <= warmup)
                .last()
                .map(|(_, e)| *e)
                .unwrap_or(0.0);
            (self.energy_wh - at_warmup).max(0.0)
        };

        let n = jobs.len().max(1) as f64;
        Summary {
            jobs: jobs.len() as u64,
            slo_violation_pct: 100.0 * violations as f64 / n,
            slo_attainment: 1.0 - violations as f64 / n,
            cold_start_ratio: cold_jobs as f64 / n,
            median_ms: stats::percentile_sorted(&responses, 50.0),
            p95_ms: stats::percentile_sorted(&responses, 95.0),
            p99_ms: p99,
            mean_ms: stats::mean(&responses),
            avg_containers: self.avg_containers_after(warmup),
            total_spawned: self.containers.len() as u64,
            cold_starts: self.cold_starts,
            energy_wh,
            tail_breakdown: tail,
            avg_breakdown: Breakdown {
                exec_ms: exec_sum / n,
                cold_ms: cold_sum / n,
                batch_ms: batch_sum / n,
            },
            queue_wait_median_ms: stats::percentile_sorted(&queue_waits, 50.0),
            queue_wait_p99_ms: stats::percentile_sorted(&queue_waits, 99.0),
            per_stage,
            optimality: None,
        }
    }

    /// Fold per-shard recorders into one cluster-wide recorder (used by
    /// the sharded sim driver). A single part is returned unchanged, so
    /// the shards=1 path stays byte-identical to the unsharded engine.
    ///
    /// Jobs, containers, and decision samples concatenate in shard
    /// order (summaries sort internally, so order never leaks into
    /// results); counters and energy sum; the cumulative energy series
    /// is rebuilt over the union of sample times with each shard
    /// contributing its last value at or before each time.
    pub fn merge(mut parts: Vec<Recorder>) -> Recorder {
        if parts.len() <= 1 {
            return parts.pop().unwrap_or_default();
        }
        let mut out = Recorder::new();
        let mut times: Vec<Micros> = parts
            .iter()
            .flat_map(|p| p.energy_series.iter().map(|(t, _)| *t))
            .collect();
        times.sort_unstable();
        times.dedup();
        let mut idx = vec![0usize; parts.len()];
        let mut last = vec![0.0f64; parts.len()];
        for t in times {
            for (k, p) in parts.iter().enumerate() {
                while idx[k] < p.energy_series.len() && p.energy_series[idx[k]].0 <= t {
                    last[k] = p.energy_series[idx[k]].1;
                    idx[k] += 1;
                }
            }
            out.energy_series.push((t, last.iter().sum()));
        }
        for p in parts {
            out.jobs.extend(p.jobs);
            out.containers.extend(p.containers);
            out.cold_starts += p.cold_starts;
            out.batches += p.batches;
            out.reclaimed += p.reclaimed;
            out.energy_wh += p.energy_wh;
            out.horizon = out.horizon.max(p.horizon);
            out.decision_ns.extend(p.decision_ns);
        }
        out
    }

    /// Response-latency CDF in ms (Fig. 10a).
    pub fn latency_cdf(&self, points: usize) -> Vec<(f64, f64)> {
        let r: Vec<f64> = self.jobs.iter().map(|j| to_ms(j.response())).collect();
        stats::cdf_points(&r, points)
    }

    /// Queuing-time CDF in ms across all stages (Fig. 10b).
    pub fn queue_cdf(&self, points: usize) -> Vec<(f64, f64)> {
        let q: Vec<f64> = self
            .jobs
            .iter()
            .flat_map(|j| j.stages.iter().map(|s| to_ms(s.queue_wait())))
            .collect();
        stats::cdf_points(&q, points)
    }
}

/// Latency composition (exec vs cold-start vs batching delay) — Fig. 9.
#[derive(Debug, Default, Clone, Copy)]
pub struct Breakdown {
    pub exec_ms: f64,
    pub cold_ms: f64,
    pub batch_ms: f64,
}

impl Breakdown {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("exec_ms", Json::Num(self.exec_ms)),
            ("cold_ms", Json::Num(self.cold_ms)),
            ("batch_ms", Json::Num(self.batch_ms)),
        ])
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct StageStats {
    pub containers: u64,
    pub jobs: u64,
    pub cold_starts: u64,
}

impl StageStats {
    /// Requests executed per container (paper's container-utilization
    /// metric, Fig. 12a).
    pub fn rpc(&self) -> f64 {
        if self.containers == 0 {
            0.0
        } else {
            self.jobs as f64 / self.containers as f64
        }
    }
}

/// Aggregated results of one run — one row of the paper's figures.
#[derive(Debug, Clone)]
pub struct Summary {
    pub jobs: u64,
    pub slo_violation_pct: f64,
    /// Fraction of jobs meeting their chain SLO (`1 − violations/jobs`)
    /// — the same quantity the obs plane's `request_success_rate` SLO
    /// tracks, here over the summarized (post-warm-up) window.
    pub slo_attainment: f64,
    /// Fraction of jobs that absorbed any cold-start wait — the obs
    /// plane's `cold_start_ratio`, over the summarized window.
    pub cold_start_ratio: f64,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub avg_containers: f64,
    pub total_spawned: u64,
    pub cold_starts: u64,
    pub energy_wh: f64,
    pub tail_breakdown: Breakdown,
    pub avg_breakdown: Breakdown,
    pub queue_wait_median_ms: f64,
    pub queue_wait_p99_ms: f64,
    pub per_stage: HashMap<MsId, StageStats>,
    /// Offline lower bounds vs achieved cost (see [`crate::estimator`]).
    /// `None` unless the run was asked for `--optimality`; deliberately
    /// absent from the CSV row so the sweep column set stays fixed.
    pub optimality: Option<crate::estimator::OptimalityReport>,
}

impl Summary {
    /// Column names of one CSV row, matching [`Summary::csv_row`].
    pub const CSV_FIELDS: [&'static str; 14] = [
        "jobs",
        "slo_violation_pct",
        "slo_attainment",
        "cold_start_ratio",
        "mean_ms",
        "median_ms",
        "p95_ms",
        "p99_ms",
        "avg_containers",
        "total_spawned",
        "cold_starts",
        "energy_wh",
        "queue_wait_median_ms",
        "queue_wait_p99_ms",
    ];

    /// One CSV row (no trailing newline), columns per
    /// [`Summary::CSV_FIELDS`]. Values use the default float rendering,
    /// which is deterministic — sweep outputs are byte-reproducible.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.jobs,
            self.slo_violation_pct,
            self.slo_attainment,
            self.cold_start_ratio,
            self.mean_ms,
            self.median_ms,
            self.p95_ms,
            self.p99_ms,
            self.avg_containers,
            self.total_spawned,
            self.cold_starts,
            self.energy_wh,
            self.queue_wait_median_ms,
            self.queue_wait_p99_ms,
        )
    }

    /// Full JSON rendering, including the latency breakdowns and the
    /// per-stage container stats. Object keys are sorted (the writer is
    /// BTreeMap-backed), so the output is byte-deterministic even though
    /// `per_stage` itself is a `HashMap`.
    pub fn to_json(&self) -> Json {
        let per_stage: BTreeMap<String, Json> = self
            .per_stage
            .iter()
            .map(|(ms_id, st)| {
                (
                    ms_id.to_string(),
                    Json::obj(vec![
                        ("containers", Json::Num(st.containers as f64)),
                        ("jobs", Json::Num(st.jobs as f64)),
                        ("cold_starts", Json::Num(st.cold_starts as f64)),
                        ("rpc", Json::Num(st.rpc())),
                    ]),
                )
            })
            .collect();
        let mut fields = vec![
            ("jobs", Json::Num(self.jobs as f64)),
            ("slo_violation_pct", Json::Num(self.slo_violation_pct)),
            ("slo_attainment", Json::Num(self.slo_attainment)),
            ("cold_start_ratio", Json::Num(self.cold_start_ratio)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("median_ms", Json::Num(self.median_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("avg_containers", Json::Num(self.avg_containers)),
            ("total_spawned", Json::Num(self.total_spawned as f64)),
            ("cold_starts", Json::Num(self.cold_starts as f64)),
            ("energy_wh", Json::Num(self.energy_wh)),
            ("queue_wait_median_ms", Json::Num(self.queue_wait_median_ms)),
            ("queue_wait_p99_ms", Json::Num(self.queue_wait_p99_ms)),
            ("tail_breakdown", self.tail_breakdown.to_json()),
            ("avg_breakdown", self.avg_breakdown.to_json()),
            ("per_stage", Json::Obj(per_stage)),
        ];
        // only present when the estimators ran, so outputs of runs that
        // never asked for --optimality are byte-for-byte unchanged
        if let Some(opt) = &self.optimality {
            fields.push(("optimality", opt.to_json()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Catalog;
    use crate::util::ms;

    fn job(chain: ChainId, arrival_ms: f64, resp_ms: f64, stages: Vec<StageRecord>) -> JobRecord {
        JobRecord {
            chain,
            arrival: ms(arrival_ms),
            completion: ms(arrival_ms + resp_ms),
            stages,
        }
    }

    fn stage(msid: MsId, enq: f64, start: f64, end: f64, cold: f64) -> StageRecord {
        StageRecord {
            ms_id: msid,
            enqueued: ms(enq),
            exec_start: ms(start),
            exec_end: ms(end),
            cold_wait: ms(cold),
        }
    }

    #[test]
    fn stage_record_decomposition() {
        let s = stage(0, 0.0, 100.0, 150.0, 30.0);
        assert_eq!(s.queue_wait(), ms(100.0));
        assert_eq!(s.exec(), ms(50.0));
        assert_eq!(s.batch_wait(), ms(70.0));
    }

    #[test]
    fn slo_violations_counted() {
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        r.job(job(0, 0.0, 500.0, vec![]));
        r.job(job(0, 0.0, 1500.0, vec![])); // violates 1000ms SLO
        r.job(job(0, 0.0, 900.0, vec![]));
        let s = r.summarize(&cat);
        assert!((s.slo_violation_pct - 33.333).abs() < 0.01);
        assert!((s.slo_attainment - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.cold_start_ratio, 0.0); // no stages -> no cold waits
        assert_eq!(s.jobs, 3);
    }

    #[test]
    fn cold_start_ratio_counts_jobs_with_cold_wait() {
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        r.job(job(0, 0.0, 500.0, vec![stage(0, 0.0, 100.0, 200.0, 80.0)]));
        r.job(job(0, 0.0, 500.0, vec![stage(0, 0.0, 10.0, 110.0, 0.0)]));
        let s = r.summarize(&cat);
        assert!((s.cold_start_ratio - 0.5).abs() < 1e-9);
    }

    #[test]
    fn container_accounting_and_rpc() {
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(100_000.0);
        r.container_spawned(1, 0, ms(0.0), true);
        r.container_spawned(2, 0, ms(0.0), false);
        r.container_executed(1, 30);
        r.container_executed(2, 10);
        r.container_retired(2, ms(50_000.0));
        let s = r.summarize(&cat);
        assert_eq!(s.cold_starts, 1);
        assert_eq!(s.total_spawned, 2);
        let st = s.per_stage.get(&0).unwrap();
        assert_eq!(st.rpc(), 20.0);
        // container 1 alive 100s, container 2 alive 50s -> avg 1.5
        assert!((s.avg_containers - 1.5).abs() < 1e-9);
    }

    #[test]
    fn containers_over_time_series() {
        let mut r = Recorder::new();
        r.horizon = ms(30_000.0);
        r.container_spawned(1, 0, ms(0.0), true);
        r.container_spawned(2, 0, ms(12_000.0), true);
        r.container_retired(1, ms(25_000.0));
        let ts = r.containers_over_time(10);
        assert_eq!(ts.len(), 4); // t = 0, 10, 20, 30
        assert_eq!(ts[0].1, 1);
        assert_eq!(ts[2].1, 2);
        assert_eq!(ts[3].1, 1);
    }

    #[test]
    fn tail_breakdown_composition() {
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        // 100 fast jobs + 1 huge tail job dominated by cold start
        for i in 0..100 {
            r.job(job(
                0,
                i as f64,
                100.0,
                vec![stage(0, i as f64, i as f64 + 50.0, i as f64 + 100.0, 0.0)],
            ));
        }
        r.job(job(
            0,
            0.0,
            5000.0,
            vec![stage(0, 0.0, 4900.0, 5000.0, 4500.0)],
        ));
        let s = r.summarize(&cat);
        assert!(s.tail_breakdown.cold_ms > 1000.0);
        assert!(s.p99_ms >= 100.0);
    }

    #[test]
    fn cdf_shapes() {
        let mut r = Recorder::new();
        r.horizon = ms(1000.0);
        for i in 1..=100 {
            r.job(job(0, 0.0, i as f64, vec![]));
        }
        let cdf = r.latency_cdf(20);
        assert_eq!(cdf.len(), 20);
        assert!((cdf.last().unwrap().0 - 100.0).abs() < 1e-9);
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn avg_containers_empty() {
        let r = Recorder::new();
        assert_eq!(r.avg_containers(), 0.0);
    }

    #[test]
    fn summary_serialization_shape() {
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        r.container_spawned(1, 0, ms(0.0), true);
        r.container_spawned(2, 3, ms(0.0), false);
        r.container_executed(1, 4);
        r.job(job(0, 0.0, 500.0, vec![]));
        let s = r.summarize(&cat);
        let row = s.csv_row();
        assert_eq!(row.split(',').count(), Summary::CSV_FIELDS.len());
        let js = s.to_json().to_string();
        // two renders are byte-identical despite the HashMap field
        assert_eq!(js, s.to_json().to_string());
        assert!(js.contains("\"per_stage\"") && js.contains("\"tail_breakdown\""));
        assert!(js.contains("\"jobs\":1"));
    }

    #[test]
    fn summary_csv_json_round_trip() {
        // every CSV column exists in the JSON under the same name with
        // the identical value (default float rendering round-trips), so
        // a field added to one serialization but not the other — or a
        // column reorder — fails here instead of skewing sweep output
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        r.container_spawned(1, 0, ms(0.0), true);
        r.container_executed(1, 2);
        r.job(job(0, 0.0, 500.0, vec![stage(0, 0.0, 100.0, 400.0, 50.0)]));
        r.job(job(0, 100.0, 1200.0, vec![stage(0, 100.0, 400.0, 1300.0, 0.0)]));
        let s = r.summarize(&cat);
        let cells: Vec<&str> = s.csv_row().split(',').collect();
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(cells.len(), Summary::CSV_FIELDS.len());
        for (i, field) in Summary::CSV_FIELDS.iter().enumerate() {
            let cell: f64 = cells[i].parse().unwrap_or_else(|_| {
                panic!("CSV cell {field}={} is not numeric", cells[i])
            });
            let jval = parsed
                .get(field)
                .unwrap_or_else(|_| panic!("JSON missing CSV field {field}"))
                .as_f64()
                .unwrap_or_else(|_| panic!("JSON field {field} not a number"));
            assert!(
                cell.to_bits() == jval.to_bits(),
                "field {field}: csv {cell} != json {jval}"
            );
        }
    }

    #[test]
    fn optimality_block_is_json_only() {
        // attaching an optimality report must not change the CSV column
        // set (the block is JSON-only by design) and must appear in the
        // JSON exactly once it is set
        let cat = Catalog::paper();
        let mut r = Recorder::new();
        r.horizon = ms(10_000.0);
        r.container_spawned(1, 0, ms(0.0), true);
        r.job(job(0, 0.0, 500.0, vec![]));
        let mut s = r.summarize(&cat);
        assert!(!s.to_json().to_string().contains("\"optimality\""));
        let log = crate::estimator::InvocationLog::default();
        s.optimality = Some(crate::estimator::analyze(&log, &r));
        assert_eq!(s.csv_row().split(',').count(), Summary::CSV_FIELDS.len());
        let js = s.to_json().to_string();
        assert!(js.contains("\"optimality\"") && js.contains("\"bound_container_s\""));
    }
}
