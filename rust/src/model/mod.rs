//! Domain model: microservices, function chains, workload mixes.
//!
//! This is the runtime-side catalog of the paper's workload (Tables 3–5):
//! nine Djinn&Tonic-style ML microservices, four chains with their measured
//! slack, three workload mixes. Execution-time sampling follows the paper's
//! characterization (§2.2.2): near-deterministic per-stage times with a
//! standard deviation well under 20 ms across runs.

use crate::util::rng::Pcg;

/// Index into [`Catalog::microservices`].
pub type MsId = usize;
/// Index into [`Catalog::chains`].
pub type ChainId = usize;

/// One microservice ("function", "stage", "task") — paper Table 3.
#[derive(Debug, Clone)]
pub struct Microservice {
    pub name: &'static str,
    pub long_name: &'static str,
    pub ml_model: &'static str,
    /// Mean execution time (MET) in ms, from offline profiling (Table 3).
    pub exec_ms_mean: f64,
    /// Std-dev of execution time (paper Fig. 3b: within 20 ms).
    pub exec_ms_std: f64,
    /// Container image size (MB) — drives image-pull time in cold starts.
    pub image_mb: f64,
    /// CPU share per container (paper §5.1: 0.5 core).
    pub cpu_share: f64,
    /// Memory per container (paper §5.1: within 1 GB).
    pub mem_mb: f64,
}

impl Microservice {
    /// Sample an execution time in ms (truncated normal, never < 5% mean).
    #[inline]
    pub fn sample_exec_ms(&self, rng: &mut Pcg) -> f64 {
        let x = rng.normal_ms(self.exec_ms_mean, self.exec_ms_std);
        x.max(self.exec_ms_mean * 0.05).max(0.01)
    }
}

/// One application = a linear chain of microservices — paper Table 4.
#[derive(Debug, Clone)]
pub struct Chain {
    pub name: &'static str,
    pub stages: Vec<MsId>,
    /// Measured average slack in ms (Table 4).
    pub slack_ms: f64,
    /// End-to-end response-latency SLO in ms (paper §4.1: 1000 ms).
    pub slo_ms: f64,
}

impl Chain {
    /// Total mean execution time across stages.
    pub fn total_exec_ms(&self, cat: &Catalog) -> f64 {
        self.stages
            .iter()
            .map(|&s| cat.microservices[s].exec_ms_mean)
            .sum()
    }
}

/// A workload mix of two applications — paper Table 5.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    pub name: &'static str,
    pub chains: Vec<ChainId>,
}

/// The full workload catalog.
#[derive(Debug, Clone)]
pub struct Catalog {
    pub microservices: Vec<Microservice>,
    pub chains: Vec<Chain>,
    pub mixes: Vec<WorkloadMix>,
}

/// Std-dev model: grows with MET but capped at the paper's 20 ms bound.
fn std_for(mean_ms: f64) -> f64 {
    (0.5 + 0.08 * mean_ms).min(18.0)
}

impl Catalog {
    /// The paper's workload: Tables 3, 4 and 5 verbatim.
    pub fn paper() -> Catalog {
        let m = |name, long_name, ml_model, exec, image_mb| Microservice {
            name,
            long_name,
            ml_model,
            exec_ms_mean: exec,
            exec_ms_std: std_for(exec),
            image_mb,
            cpu_share: 0.5,
            mem_mb: 1024.0,
        };
        // Table 3. Image sizes scale with model size (drives cold starts).
        let microservices = vec![
            m("IMC", "Image Classification", "Alexnet", 43.5, 850.0),
            m("AP", "Human Activity Pose", "DeepPose", 30.3, 700.0),
            m("HS", "Human Segmentation", "VGG16", 151.2, 1400.0),
            m("FACER", "Facial Recognition", "VGGNET", 5.5, 500.0),
            m("FACED", "Face Detection", "Xception", 6.1, 520.0),
            m("ASR", "Auto Speech Recognition", "NNet3", 46.1, 900.0),
            m("POS", "Parts of Speech Tagging", "SENNA", 0.100, 180.0),
            m("NER", "Name Entity Recognition", "SENNA", 0.090, 180.0),
            m("NLP", "POS+NER composite stage", "SENNA", 0.190, 200.0),
            m("QA", "Question Answering", "memnet", 56.1, 950.0),
        ];
        let id = |name: &str| {
            microservices
                .iter()
                .position(|ms| ms.name == name)
                .unwrap()
        };
        // Table 4 (slack), chained per the paper.
        let chains = vec![
            Chain {
                name: "FaceSecurity",
                stages: vec![id("FACED"), id("FACER")],
                slack_ms: 788.0,
                slo_ms: 1000.0,
            },
            Chain {
                name: "IMG",
                stages: vec![id("IMC"), id("NLP"), id("QA")],
                slack_ms: 700.0,
                slo_ms: 1000.0,
            },
            Chain {
                name: "IPA",
                stages: vec![id("ASR"), id("NLP"), id("QA")],
                slack_ms: 697.0,
                slo_ms: 1000.0,
            },
            Chain {
                name: "DetectFatigue",
                stages: vec![id("HS"), id("AP"), id("FACED"), id("FACER")],
                slack_ms: 572.0,
                slo_ms: 1000.0,
            },
        ];
        let cid = |name: &str| chains.iter().position(|c| c.name == name).unwrap();
        // Table 5, ordered by increasing total available slack.
        let mixes = vec![
            WorkloadMix {
                name: "Heavy",
                chains: vec![cid("IPA"), cid("DetectFatigue")],
            },
            WorkloadMix {
                name: "Medium",
                chains: vec![cid("IPA"), cid("IMG")],
            },
            WorkloadMix {
                name: "Light",
                chains: vec![cid("IMG"), cid("FaceSecurity")],
            },
        ];
        Catalog {
            microservices,
            chains,
            mixes,
        }
    }

    pub fn ms_id(&self, name: &str) -> Option<MsId> {
        self.microservices.iter().position(|m| m.name == name)
    }

    pub fn chain_id(&self, name: &str) -> Option<ChainId> {
        self.chains.iter().position(|c| c.name == name)
    }

    pub fn mix(&self, name: &str) -> Option<&WorkloadMix> {
        self.mixes
            .iter()
            .find(|m| m.name.eq_ignore_ascii_case(name))
    }

    /// All distinct stages used by a mix (shared stages appear once).
    pub fn mix_stages(&self, mix: &WorkloadMix) -> Vec<MsId> {
        let mut out: Vec<MsId> = Vec::new();
        for &cid in &mix.chains {
            for &s in &self.chains[cid].stages {
                if !out.contains(&s) {
                    out.push(s);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values() {
        let cat = Catalog::paper();
        assert_eq!(cat.microservices.len(), 10);
        let hs = &cat.microservices[cat.ms_id("HS").unwrap()];
        assert_eq!(hs.exec_ms_mean, 151.2);
        let ner = &cat.microservices[cat.ms_id("NER").unwrap()];
        assert_eq!(ner.exec_ms_mean, 0.090);
        for ms in &cat.microservices {
            assert!(ms.exec_ms_std <= 20.0, "{} std too large", ms.name);
            assert!(ms.cpu_share == 0.5 && ms.mem_mb <= 1024.0);
        }
    }

    #[test]
    fn table4_chains() {
        let cat = Catalog::paper();
        assert_eq!(cat.chains.len(), 4);
        let df = &cat.chains[cat.chain_id("DetectFatigue").unwrap()];
        assert_eq!(df.stages.len(), 4);
        assert_eq!(df.slack_ms, 572.0);
        // every chain fits its SLO
        for c in &cat.chains {
            assert!(c.total_exec_ms(&cat) < c.slo_ms);
            assert!(c.slack_ms < c.slo_ms);
        }
        // paper Fig 3a: HS dominates DetectFatigue (~81%)
        let hs_frac =
            cat.microservices[df.stages[0]].exec_ms_mean / df.total_exec_ms(&cat);
        assert!(hs_frac > 0.75, "{hs_frac}");
    }

    #[test]
    fn table5_mixes_ordered_by_slack() {
        let cat = Catalog::paper();
        assert_eq!(cat.mixes.len(), 3);
        let avg_slack = |mix: &WorkloadMix| {
            mix.chains
                .iter()
                .map(|&c| cat.chains[c].slack_ms)
                .sum::<f64>()
                / mix.chains.len() as f64
        };
        let heavy = avg_slack(cat.mix("Heavy").unwrap());
        let medium = avg_slack(cat.mix("Medium").unwrap());
        let light = avg_slack(cat.mix("Light").unwrap());
        assert!(heavy < medium && medium < light, "{heavy} {medium} {light}");
    }

    #[test]
    fn exec_sampling_bounds() {
        let cat = Catalog::paper();
        let mut rng = Pcg::new(5);
        for ms in &cat.microservices {
            let mut vals = Vec::new();
            for _ in 0..100 {
                let v = ms.sample_exec_ms(&mut rng);
                assert!(v > 0.0);
                vals.push(v);
            }
            let mean = crate::util::stats::mean(&vals);
            assert!(
                (mean - ms.exec_ms_mean).abs() < ms.exec_ms_mean.max(1.0) * 0.5,
                "{}: {mean} vs {}",
                ms.name,
                ms.exec_ms_mean
            );
        }
    }

    #[test]
    fn shared_stages_deduplicated() {
        let cat = Catalog::paper();
        // Medium = IPA + IMG share NLP and QA
        let stages = cat.mix_stages(cat.mix("Medium").unwrap());
        assert_eq!(stages.len(), 4); // ASR, NLP, QA, IMC
    }
}
