//! Micro-benchmark harness + table printer (criterion substitute).
//!
//! Every `rust/benches/*.rs` target is `harness = false` and uses this
//! module to time closures and print paper-style tables (the same rows the
//! paper's figures plot). Results can also be dumped as JSON for
//! docs/EXPERIMENTS.md bookkeeping.

use std::time::Instant;

use crate::util::stats;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Timing {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` adaptively: warm up, then run batches until `target_ms` of
/// samples or `max_iters` is reached. Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> Timing {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    let target = std::time::Duration::from_millis(target_ms);
    let max_iters = 1_000_000u64;
    let mut iters = 0u64;
    while start.elapsed() < target && iters < max_iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        iters += 1;
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples_ns),
        p50_ns: stats::percentile_sorted(&samples_ns, 50.0),
        p99_ns: stats::percentile_sorted(&samples_ns, 99.0),
        min_ns: samples_ns.first().copied().unwrap_or(0.0),
    }
}

/// Fixed-width table printer for bench output (the "figure" in text form).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(
            &cells
                .iter()
                .map(|c| format!("{c}"))
                .collect::<Vec<String>>(),
        );
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let line = |s: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}", c, w = widths[i]));
                if i + 1 < ncol {
                    s.push_str("  ");
                }
            }
            s.push('\n');
        };
        line(&mut s, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        s.push_str(&"-".repeat(total));
        s.push('\n');
        for row in &self.rows {
            line(&mut s, row);
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Pretty banner for bench sections, mirroring the paper's figure ids.
pub fn section(fig: &str, caption: &str) {
    println!("\n=== {fig} — {caption} ===");
}

/// Heap-allocation counting for the zero-alloc dispatch pin (compiled
/// only with `--features bench-alloc`).
///
/// [`CountingAlloc`] wraps the system allocator and counts every
/// `alloc`/`realloc` (frees are counted separately). Register it as the
/// `#[global_allocator]` in a bench target, then bracket the measured
/// region with [`alloc_count::reset`] / [`alloc_count::allocs`]:
///
/// ```ignore
/// #[cfg(feature = "bench-alloc")]
/// #[global_allocator]
/// static ALLOC: fifer::bench::alloc_count::CountingAlloc =
///     fifer::bench::alloc_count::CountingAlloc;
///
/// fifer::bench::alloc_count::reset();
/// hot_path();
/// let n = fifer::bench::alloc_count::allocs();
/// ```
///
/// Counters are relaxed atomics: exact under single-threaded measurement
/// (the bench loop), merely approximate if other threads allocate.
#[cfg(feature = "bench-alloc")]
pub mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    static FREES: AtomicU64 = AtomicU64::new(0);

    /// A [`System`]-backed global allocator that counts calls.
    pub struct CountingAlloc;

    // SAFETY: defers every operation to `System`; the counters do not
    // affect allocation behavior.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            FREES.fetch_add(1, Ordering::Relaxed);
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    /// Zero both counters (start of a measured region).
    pub fn reset() {
        ALLOCS.store(0, Ordering::Relaxed);
        FREES.store(0, Ordering::Relaxed);
    }

    /// Heap allocations (alloc + realloc) since the last [`reset`].
    pub fn allocs() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// Deallocations since the last [`reset`].
    pub fn frees() -> u64 {
        FREES.load(Ordering::Relaxed)
    }
}

/// Format a normalized value as the paper plots it ("x.xx" of baseline).
pub fn norm(v: f64, base: f64) -> String {
    if base == 0.0 {
        "n/a".to_string()
    } else {
        format!("{:.2}", v / base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let t = bench("noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(t.iters > 0);
        assert!(t.mean_ns >= 0.0);
        assert!(t.p99_ns >= t.p50_ns);
        assert!(t.p50_ns >= t.min_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["policy", "value"]);
        t.row(&["Bline".into(), "1.00".into()]);
        t.row(&["Fifer".into(), "0.20".into()]);
        let out = t.render();
        assert!(out.contains("policy"));
        assert_eq!(out.lines().count(), 4);
        // columns aligned: both data rows have the same prefix width
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(
            lines[2].find("1.00").unwrap(),
            lines[3].find("0.20").unwrap()
        );
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn norm_formats() {
        assert_eq!(norm(2.0, 4.0), "0.50");
        assert_eq!(norm(1.0, 0.0), "n/a");
    }
}
