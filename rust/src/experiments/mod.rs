//! Experiment drivers — one per paper figure/table (docs/DESIGN.md §4).
//!
//! Each driver builds the exact workload the paper's evaluation uses and
//! returns structured results; the `rust/benches/*` targets print them as
//! the same rows/series the paper plots, and `docs/EXPERIMENTS.md`
//! records paper-vs-measured values. Shared entry points:
//!
//! * [`run_prototype`] — §6.1 real-system experiments: Poisson λ=50 on the
//!   80-core prototype cluster (Figs. 8–13).
//! * [`run_macro`] — §6.2 trace-driven simulation: Wiki/WITS on the
//!   2500-core cluster (Figs. 14–16, Table 6).
//! * [`fig2_coldstart`], [`fig3a_breakdown`] / [`fig3b_variation`],
//!   [`fig6_predictors`] — the motivation/characterization figures.

use std::collections::HashMap;
use std::path::Path;

use crate::coldstart::ColdStartModel;
use crate::config::{Policy, SystemConfig};
use crate::metrics::{Recorder, Summary};
use crate::model::{Catalog, MsId};
use crate::predictor::{all_predictors, evaluate, EvalResult};
use crate::sim::SimParams;
use crate::trace::Trace;
use crate::util::rng::Pcg;
use crate::util::stats;

/// Which arrival trace drives an experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Synthetic Poisson λ=50 (the prototype experiments).
    Poisson,
    /// Wiki-like diurnal trace (avg ~1500 req/s).
    Wiki,
    /// WITS-like bursty trace (avg ~300 req/s, spikes to 1200).
    Wits,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Poisson => "poisson",
            TraceKind::Wiki => "wiki",
            TraceKind::Wits => "wits",
        }
    }

    /// Build the trace, preferring the Python-exported artifact (so the
    /// LSTM sees its training distribution) and tiling to `duration_s`.
    pub fn build(&self, duration_s: usize, artifacts_dir: &str) -> Trace {
        match self {
            TraceKind::Poisson => Trace::poisson(50.0, duration_s),
            TraceKind::Wiki => load_or_gen("wiki", duration_s, artifacts_dir, Trace::wiki),
            TraceKind::Wits => load_or_gen("wits", duration_s, artifacts_dir, Trace::wits),
        }
    }
}

fn load_or_gen(
    name: &str,
    duration_s: usize,
    artifacts_dir: &str,
    gen: fn(usize, u64) -> Trace,
) -> Trace {
    let p = Path::new(artifacts_dir).join("traces").join(format!("{name}.json"));
    match Trace::load_json(&p) {
        Ok(t) => t.resized(duration_s),
        Err(_) => gen(duration_s, if name == "wiki" { 2025 } else { 1316 }),
    }
}

/// One (policy, summary) result row plus the detailed recorder.
pub struct PolicyRun {
    pub policy: Policy,
    pub summary: Summary,
    pub recorder: Recorder,
}

/// Run one simulation for one policy.
pub fn run_policy(
    policy: Policy,
    mix_name: &str,
    kind: TraceKind,
    duration_s: usize,
    prototype_cluster: bool,
    seed: u64,
) -> PolicyRun {
    run_policy_inner(policy, mix_name, kind, duration_s, prototype_cluster, seed, false)
}

/// [`run_policy`] with the offline optimality-gap analysis: the
/// summary's `optimality` block reports how far the run's
/// container-seconds and cold starts sit from the lower bounds of
/// [`crate::estimator`] — the plumbing behind `fifer simulate
/// --optimality`.
pub fn run_policy_opt(
    policy: Policy,
    mix_name: &str,
    kind: TraceKind,
    duration_s: usize,
    prototype_cluster: bool,
    seed: u64,
) -> PolicyRun {
    run_policy_inner(policy, mix_name, kind, duration_s, prototype_cluster, seed, true)
}

fn run_policy_inner(
    policy: Policy,
    mix_name: &str,
    kind: TraceKind,
    duration_s: usize,
    prototype_cluster: bool,
    seed: u64,
    optimality: bool,
) -> PolicyRun {
    let cat = Catalog::paper();
    let mut cfg = if prototype_cluster {
        SystemConfig::prototype(policy)
    } else {
        SystemConfig::simulation(policy)
    };
    cfg.seed = seed;
    let trace = kind.build(duration_s, &cfg.artifacts_dir);
    let chains = cat
        .mix(mix_name)
        .unwrap_or_else(|| panic!("unknown mix {mix_name}"))
        .chains
        .clone();
    let params = SimParams {
        cfg,
        chains,
        trace,
        drain_s: 60.0,
    };
    // Exclude the initial cold-start transient (~2 min of cluster warm-up)
    // from the steady-state metrics, as on a long-running real cluster.
    let warmup = crate::util::secs((duration_s as f64 * 0.5).min(700.0));
    let (recorder, summary, _) = crate::sim::run_summarized_full(params, warmup, None, optimality);
    PolicyRun {
        policy,
        summary,
        recorder,
    }
}

/// Run one simulation per policy in `policies`, in order. Drivers that
/// reproduce a paper figure pass `&Policy::PAPER`; registry-wide sweeps
/// pass `&Policy::ALL`.
pub fn run_policies(
    policies: &[Policy],
    mix_name: &str,
    kind: TraceKind,
    duration_s: usize,
    prototype_cluster: bool,
    seed: u64,
) -> Vec<PolicyRun> {
    policies
        .iter()
        .map(|&p| run_policy(p, mix_name, kind, duration_s, prototype_cluster, seed))
        .collect()
}

/// §6.1 prototype experiments: every registered RM on one workload mix.
/// Iterates the policy registry (`Policy::ALL`), so newly registered
/// policies (e.g. `Kn`, `FiferEq`) appear automatically; the paper's
/// five RMs come first, so positional lookups against them stay valid.
pub fn run_prototype(mix_name: &str, duration_s: usize, seed: u64) -> Vec<PolicyRun> {
    run_policies(&Policy::ALL, mix_name, TraceKind::Poisson, duration_s, true, seed)
}

/// §6.2 macro simulations: every registered RM on a real-trace workload
/// (registry-ordered, like [`run_prototype`]).
///
/// Both grid drivers are special cases of the scenario subsystem: the
/// built-in `prototype-grid` / `macro-grid` scenario files express the
/// same matrices declaratively (see [`crate::scenario`]), and
/// `rust/tests/test_scenario.rs` pins their cells byte-identical to
/// these functions.
pub fn run_macro(
    kind: TraceKind,
    mix_name: &str,
    duration_s: usize,
    seed: u64,
) -> Vec<PolicyRun> {
    run_policies(&Policy::ALL, mix_name, kind, duration_s, false, seed)
}

// ---------------------------------------------------------------------
// Fig. 2 — cold vs warm start characterization
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ColdStartRow {
    pub name: &'static str,
    pub exec_ms: f64,
    pub spawn_ms: f64,
    pub pull_ms: f64,
    pub init_ms: f64,
    pub cold_total_ms: f64,
    pub warm_total_ms: f64,
}

/// Reproduce Fig. 2: cold/warm start breakdown per model, averaged over
/// `samples` trials of the calibrated cold-start model.
pub fn fig2_coldstart(samples: usize, seed: u64) -> Vec<ColdStartRow> {
    let cat = Catalog::paper();
    let model = ColdStartModel::default();
    let mut rng = Pcg::new(seed);
    let mut rows = Vec::new();
    // order by model size, mirroring the paper's squeezenet..resnet-200 axis
    let mut order: Vec<&crate::model::Microservice> = cat.microservices.iter().collect();
    order.sort_by(|a, b| a.image_mb.partial_cmp(&b.image_mb).unwrap());
    for ms in order {
        let (mut sp, mut pu, mut ini) = (0.0, 0.0, 0.0);
        for _ in 0..samples.max(1) {
            let s = model.sample(ms, &mut rng);
            sp += crate::util::to_ms(s.spawn);
            pu += crate::util::to_ms(s.pull);
            ini += crate::util::to_ms(s.init);
        }
        let n = samples.max(1) as f64;
        let (sp, pu, ini) = (sp / n, pu / n, ini / n);
        rows.push(ColdStartRow {
            name: ms.name,
            exec_ms: ms.exec_ms_mean,
            spawn_ms: sp,
            pull_ms: pu,
            init_ms: ini,
            cold_total_ms: sp + pu + ini + ms.exec_ms_mean,
            warm_total_ms: crate::util::to_ms(model.warm_overhead()) + ms.exec_ms_mean,
        });
    }
    rows
}

// ---------------------------------------------------------------------
// Fig. 3 — per-stage breakdown + execution-time variation
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct StageBreakdown {
    pub chain: &'static str,
    /// (stage name, exec ms, % of chain total)
    pub stages: Vec<(&'static str, f64, f64)>,
}

pub fn fig3a_breakdown() -> Vec<StageBreakdown> {
    let cat = Catalog::paper();
    cat.chains
        .iter()
        .map(|c| {
            let total = c.total_exec_ms(&cat);
            StageBreakdown {
                chain: c.name,
                stages: c
                    .stages
                    .iter()
                    .map(|&s| {
                        let ms = &cat.microservices[s];
                        (ms.name, ms.exec_ms_mean, 100.0 * ms.exec_ms_mean / total)
                    })
                    .collect(),
            }
        })
        .collect()
}

/// Fig. 3b: std-dev of execution time over `runs` sampled executions —
/// the paper's claim is < 20 ms for every microservice.
pub fn fig3b_variation(runs: usize, seed: u64) -> Vec<(&'static str, f64, f64)> {
    let cat = Catalog::paper();
    let mut rng = Pcg::new(seed);
    cat.microservices
        .iter()
        .map(|ms| {
            let xs: Vec<f64> = (0..runs).map(|_| ms.sample_exec_ms(&mut rng)).collect();
            (ms.name, stats::mean(&xs), stats::std_dev(&xs))
        })
        .collect()
}

// ---------------------------------------------------------------------
// Fig. 6 — predictor comparison
// ---------------------------------------------------------------------

/// Evaluate all Fig. 6 predictors on the WITS trace (same series the LSTM
/// trained on — last 40% is out-of-sample, matching the paper's split).
pub fn fig6_predictors(artifacts_dir: &str, accuracy_band: f64) -> Vec<EvalResult> {
    let trace = TraceKind::Wits.build(4000, artifacts_dir);
    let w = trace.window_maxima(5);
    let weights = Path::new(artifacts_dir).join("predictor_weights.json");
    let wpath = weights.exists().then_some(weights);
    all_predictors(wpath.as_deref())
        .iter_mut()
        .map(|p| evaluate(p.as_mut(), &w, 2, accuracy_band))
        .collect()
}

// ---------------------------------------------------------------------
// Fig. 11 — stage-wise container distribution (IPA)
// ---------------------------------------------------------------------

/// Fraction of containers per IPA stage for one run.
pub fn stage_distribution(run: &PolicyRun, chain_name: &str) -> Vec<(String, f64)> {
    let cat = Catalog::paper();
    let chain = &cat.chains[cat.chain_id(chain_name).unwrap()];
    let per_stage: HashMap<MsId, u64> = run
        .summary
        .per_stage
        .iter()
        .map(|(&k, v)| (k, v.containers))
        .collect();
    let total: u64 = chain
        .stages
        .iter()
        .map(|s| per_stage.get(s).copied().unwrap_or(0))
        .sum();
    chain
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n = per_stage.get(s).copied().unwrap_or(0);
            (
                format!("S{}:{}", i + 1, cat.microservices[*s].name),
                if total == 0 {
                    0.0
                } else {
                    100.0 * n as f64 / total as f64
                },
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_rows_ordered_and_calibrated() {
        let rows = fig2_coldstart(50, 1);
        assert_eq!(rows.len(), 10);
        // cold totals in the paper's 2-9s band (+exec), increasing with size
        for r in &rows {
            assert!(r.cold_total_ms > 1500.0, "{}: {}", r.name, r.cold_total_ms);
            assert!(r.cold_total_ms < 12_000.0);
            assert!(r.warm_total_ms < 400.0);
            assert!(r.cold_total_ms > 5.0 * r.warm_total_ms);
        }
        assert!(rows.last().unwrap().cold_total_ms > rows[0].cold_total_ms);
    }

    #[test]
    fn fig3a_percentages_sum_to_100() {
        for b in fig3a_breakdown() {
            let total: f64 = b.stages.iter().map(|s| s.2).sum();
            assert!((total - 100.0).abs() < 1e-9, "{}", b.chain);
        }
    }

    #[test]
    fn fig3b_stddev_under_20ms() {
        for (name, _, std) in fig3b_variation(100, 2) {
            assert!(std < 20.0, "{name}: {std}");
        }
    }

    #[test]
    fn fig6_runs_all_predictors() {
        let results = fig6_predictors("artifacts", 0.15);
        assert!(results.len() >= 6);
        for r in &results {
            assert!(r.rmse.is_finite() && r.rmse > 0.0, "{}", r.name);
            assert!(!r.forecasts.is_empty());
        }
    }

    #[test]
    fn prototype_driver_smoke() {
        // tiny run: one policy, short duration
        let run = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 30, true, 7);
        assert!(run.summary.jobs > 100);
        let dist = stage_distribution(&run, "IPA");
        assert_eq!(dist.len(), 3);
    }
}
