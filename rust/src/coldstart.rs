//! Cold-start latency model (paper §2.2.1 / Fig. 2 substitute).
//!
//! The paper characterizes AWS Lambda cold starts for ML inference and
//! finds they add ~2000–7500 ms on top of execution time, dominated by
//! application/runtime initialization and image fetch, and that container
//! spawn (including remote image pull) takes 2–9 s in their prototype
//! (§6.1.5). We model cold start as three additive components:
//!
//! ```text
//! cold = spawn (pod create)           ~ U[0.5, 1.5] s
//!      + image pull                   ~ image_mb / pull_bw (± jitter)
//!      + runtime/framework init       ~ U[0.4, 1.2] s + model-load term
//! ```
//!
//! Calibrated so the catalog's smallest image lands near 2 s and the
//! largest near 9 s — reproducing the paper's range and, crucially for the
//! RM comparison, the *cold-start ≫ exec-time* disparity (Fig. 2a).

use crate::model::Microservice;
use crate::util::rng::Pcg;
use crate::util::{secs, Micros};

#[derive(Debug, Clone)]
pub struct ColdStartModel {
    /// Pod/container creation time bounds (s).
    pub spawn_min_s: f64,
    pub spawn_max_s: f64,
    /// Image pull bandwidth (MB/s) — remote registry (dockerhub-like).
    pub pull_bw_mbps: f64,
    /// Multiplicative jitter sigma on pull time (lognormal).
    pub pull_jitter_sigma: f64,
    /// Runtime/framework initialization bounds (s).
    pub init_min_s: f64,
    pub init_max_s: f64,
    /// Model-load seconds per MB of image (proxy for model size).
    pub model_load_s_per_mb: f64,
    /// Warm-start overhead (scheduling + IPC) in ms.
    pub warm_overhead_ms: f64,
}

impl Default for ColdStartModel {
    fn default() -> Self {
        ColdStartModel {
            spawn_min_s: 0.5,
            spawn_max_s: 1.5,
            pull_bw_mbps: 400.0,
            pull_jitter_sigma: 0.15,
            init_min_s: 0.4,
            init_max_s: 1.2,
            model_load_s_per_mb: 0.0025,
            warm_overhead_ms: 2.0,
        }
    }
}

/// Breakdown of one sampled cold start (for Fig. 2's stacked bars).
#[derive(Debug, Clone, Copy)]
pub struct ColdStartSample {
    pub spawn: Micros,
    pub pull: Micros,
    pub init: Micros,
}

impl ColdStartSample {
    pub fn total(&self) -> Micros {
        self.spawn + self.pull + self.init
    }
}

impl ColdStartModel {
    /// Sample a full cold start for a microservice's container image.
    pub fn sample(&self, ms: &Microservice, rng: &mut Pcg) -> ColdStartSample {
        let spawn = rng.range(self.spawn_min_s, self.spawn_max_s);
        let pull = (ms.image_mb / self.pull_bw_mbps)
            * rng.lognormal(0.0, self.pull_jitter_sigma);
        let init = rng.range(self.init_min_s, self.init_max_s)
            + ms.image_mb * self.model_load_s_per_mb;
        ColdStartSample {
            spawn: secs(spawn),
            pull: secs(pull),
            init: secs(init),
        }
    }

    /// Deterministic expected cold-start total — what the RScale policy
    /// compares the queuing-delay threshold D_f against (C_d, §4.2).
    pub fn expected_micros(&self, ms: &Microservice) -> Micros {
        let spawn = (self.spawn_min_s + self.spawn_max_s) / 2.0;
        let pull = ms.image_mb / self.pull_bw_mbps;
        let init =
            (self.init_min_s + self.init_max_s) / 2.0 + ms.image_mb * self.model_load_s_per_mb;
        secs(spawn + pull + init)
    }

    /// Warm-start overhead (scheduling to an existing container).
    pub fn warm_overhead(&self) -> Micros {
        crate::util::ms(self.warm_overhead_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Catalog;
    use crate::util::to_secs;

    #[test]
    fn cold_starts_in_paper_range() {
        let cat = Catalog::paper();
        let model = ColdStartModel::default();
        let mut rng = Pcg::new(1);
        for ms in &cat.microservices {
            for _ in 0..50 {
                let s = model.sample(ms, &mut rng);
                let total = to_secs(s.total());
                assert!(
                    (0.9..=12.0).contains(&total),
                    "{}: cold start {total}s out of range",
                    ms.name
                );
            }
        }
    }

    #[test]
    fn big_images_cost_more() {
        let cat = Catalog::paper();
        let model = ColdStartModel::default();
        let hs = &cat.microservices[cat.ms_id("HS").unwrap()];
        let ner = &cat.microservices[cat.ms_id("NER").unwrap()];
        assert!(model.expected_micros(hs) > 2 * model.expected_micros(ner));
        // largest image lands in the upper half of the 2-9s band
        assert!(to_secs(model.expected_micros(hs)) > 5.0);
        assert!(to_secs(model.expected_micros(ner)) < 3.0);
    }

    #[test]
    fn cold_start_dominates_exec_time() {
        // The Fig. 2a observation that motivates the whole paper.
        let cat = Catalog::paper();
        let model = ColdStartModel::default();
        for ms in &cat.microservices {
            let cold_ms = to_secs(model.expected_micros(ms)) * 1000.0;
            assert!(
                cold_ms > 10.0 * ms.exec_ms_mean,
                "{}: cold {cold_ms}ms vs exec {}ms",
                ms.name,
                ms.exec_ms_mean
            );
        }
    }

    #[test]
    fn expected_close_to_sample_mean() {
        let cat = Catalog::paper();
        let model = ColdStartModel::default();
        let imc = &cat.microservices[cat.ms_id("IMC").unwrap()];
        let mut rng = Pcg::new(9);
        let n = 2000;
        let mean: f64 = (0..n)
            .map(|_| to_secs(model.sample(imc, &mut rng).total()))
            .sum::<f64>()
            / n as f64;
        let expected = to_secs(model.expected_micros(imc));
        assert!((mean - expected).abs() / expected < 0.05, "{mean} vs {expected}");
    }

    #[test]
    fn warm_overhead_small() {
        let model = ColdStartModel::default();
        assert!(to_secs(model.warm_overhead()) < 0.01);
    }
}
