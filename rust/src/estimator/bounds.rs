//! The three lower-bound estimators.
//!
//! All three consume the same prepared per-stage view: each
//! invocation's release `r` (enqueue time), relaxed deadline `d`
//! (slack-plan deadline widened to the realized completion, see
//! [`super::Invocation::deadline`]), minimal pass duration `pmin`
//! (warm overhead + recovered single-job exec), and minimal container
//! occupancy `occ` (its cheapest possible share of a batch pass).
//!
//! Shared conventions (the "clairvoyant over recorded invocations"
//! instance every bound is an optimum-lower-bound *of*):
//!
//! * An invocation's recovered single-job exec `e1` is intrinsic: any
//!   batch serving it takes at least `overhead + e1·(1 + γ·(B−1))`,
//!   and a batch's pass is at least as long as its longest member's.
//! * A container runs one batch at a time, at most `cap` invocations
//!   per batch (`cap` = slack-plan capacity, widened to the largest
//!   batch the run actually formed, so the recorded schedule itself is
//!   always admissible).
//! * Every container spawn is cold (true of both drivers — the engine
//!   has no prewarm path), so "containers that must exist" lower-bounds
//!   cold starts.
//!
//! Numeric discipline: all sweeps run in `f64` microseconds derived
//! from integer `Micros`, iteration order is fixed (BTreeMap stages,
//! completion-order entries, fully-keyed sorts), and density ceilings
//! subtract a 1e-9 epsilon before `ceil` so float noise can only ever
//! *weaken* a bound, never overshoot it. This keeps `--optimality`
//! output byte-identical across runs and `--threads`.

use std::collections::BTreeMap;

use super::InvocationLog;
use crate::model::MsId;
use crate::util::json::Json;
use crate::util::MICROS_PER_S;

/// A lower bound on both objectives, as computed by one estimator.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Bounds {
    pub container_s: f64,
    pub cold_starts: u64,
}

impl Bounds {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("container_s", Json::Num(self.container_s)),
            ("cold_starts", Json::Num(self.cold_starts as f64)),
        ])
    }
}

/// Derived per-invocation window, all fields in f64 µs.
struct Window {
    /// Release (enqueue time).
    r: f64,
    /// Relaxed deadline.
    d: f64,
    /// Minimal pass duration serving this invocation.
    pmin: f64,
    /// Minimal container occupancy attributable to this invocation.
    occ: f64,
}

struct Stage {
    /// Batch capacity a container offers (plan cap widened to the
    /// largest observed batch).
    cap: usize,
    /// Windows in completion order.
    wins: Vec<Window>,
}

/// Candidate segment starts are subsampled to this many quantiles in
/// [`segment_bound`]; subsampling can only weaken the bound.
const MAX_SEGMENT_STARTS: usize = 64;

fn prepare(log: &InvocationLog) -> BTreeMap<MsId, Stage> {
    let mut observed_cap: BTreeMap<MsId, usize> = BTreeMap::new();
    for e in &log.entries {
        let c = observed_cap.entry(e.ms_id).or_insert(1);
        *c = (*c).max(e.batch.max(1) as usize);
    }
    let gamma = log.gamma.max(0.0);
    let oh = log.overhead as f64;
    let mut stages: BTreeMap<MsId, Stage> = BTreeMap::new();
    for (&ms, &obs) in &observed_cap {
        let cap = log.batch_cap.get(&ms).copied().unwrap_or(1).max(obs);
        stages.insert(ms, Stage { cap, wins: Vec::new() });
    }
    for e in &log.entries {
        let st = stages.get_mut(&e.ms_id).expect("stage seeded above");
        let b = e.batch.max(1) as f64;
        let dur = e.exec_end.saturating_sub(e.exec_start) as f64;
        // invert exec(B) = exec(1)·(1 + γ·(B−1)) + overhead
        let e1 = (dur - oh).max(0.0) / (1.0 + gamma * (b - 1.0));
        let occ_at = |bb: f64| (e1 * (1.0 + gamma * (bb - 1.0)) + oh) / bb;
        // occ_at is monotone in B (hyperbola + constant), so the min
        // over integer B ∈ [1, cap] sits at an endpoint; the realized
        // share dur/b caps it so the bound can never exceed what the
        // recorded schedule itself paid for this invocation
        let occ = occ_at(1.0).min(occ_at(st.cap as f64)).min(dur / b);
        let pmin = (oh + e1).min(dur);
        st.wins.push(Window {
            r: e.enqueued as f64,
            d: e.deadline() as f64,
            pmin,
            occ,
        });
    }
    stages
}

fn to_seconds(us: f64) -> f64 {
    us / MICROS_PER_S as f64
}

fn ceil_div(n: usize, d: usize) -> usize {
    if d == 0 {
        n
    } else {
        n.div_ceil(d)
    }
}

/// Greedy interval-packing bound.
///
/// Packs every invocation into its cheapest hypothetical batch slot:
/// container-seconds ≥ Σ occ (work / shared-capacity bound), and every
/// stage that served at least one invocation needed at least one
/// (cold-started) container.
pub fn greedy_bound(log: &InvocationLog) -> Bounds {
    let stages = prepare(log);
    let mut work_us = 0.0;
    let mut cold = 0u64;
    for st in stages.values() {
        if st.wins.is_empty() {
            continue;
        }
        cold += 1;
        for w in &st.wins {
            work_us += w.occ;
        }
    }
    Bounds {
        container_s: to_seconds(work_us),
        cold_starts: cold,
    }
}

/// Path-cover bound over the idle-gap graph.
///
/// Two invocations can share a container warm iff one's earliest
/// finish precedes the other's latest start (the idle-gap DAG); by
/// Dilworth, the minimum chain cover equals the maximum antichain,
/// which for interval orders is the peak overlap of the mandatory
/// windows `(d − pmin, r + pmin)` — any pass serving an invocation
/// must cover its whole mandatory window. A sweep over those windows
/// yields per stage:
///
/// * cold starts ≥ ⌈peak overlap / cap⌉ (simultaneous passes, ≤ cap
///   antichain members per container);
/// * container-seconds ≥ ∫ ⌈overlap(t) / cap⌉ dt (containers that must
///   be mid-pass at time t).
pub fn path_cover_bound(log: &InvocationLog) -> Bounds {
    let stages = prepare(log);
    let mut area_us = 0.0;
    let mut cold = 0u64;
    for st in stages.values() {
        if st.wins.is_empty() {
            continue;
        }
        let mut events: Vec<(f64, i32)> = Vec::new();
        for w in &st.wins {
            let ls = (w.d - w.pmin).max(w.r);
            let ef = w.r + w.pmin;
            if ef > ls {
                events.push((ls, 1));
                events.push((ef, -1));
            }
        }
        if events.is_empty() {
            // enough slack that no invocation has a mandatory part —
            // the stage still needed one container
            cold += 1;
            continue;
        }
        // open intervals: at a tie, close (-1) before open (+1), so
        // touching windows never count as overlapping (undercounts,
        // i.e. stays a lower bound)
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite").then(a.1.cmp(&b.1)));
        let mut live: i64 = 0;
        let mut peak: i64 = 0;
        let mut prev_t = events[0].0;
        for &(t, delta) in &events {
            if t > prev_t && live > 0 {
                area_us += (t - prev_t) * ceil_div(live as usize, st.cap) as f64;
            }
            prev_t = t;
            live += i64::from(delta);
            peak = peak.max(live);
        }
        cold += ceil_div(peak as usize, st.cap).max(1) as u64;
    }
    Bounds {
        container_s: to_seconds(area_us),
        cold_starts: cold,
    }
}

/// Segmented LP-relaxation-style bound.
///
/// For any segment `[a, b]`, the work `W(a, b)` of invocations whose
/// whole window fits inside it must execute inside it, and each
/// container contributes at most `b − a` of occupancy there — so the
/// stage needed at least `⌈W(a, b) / (b − a)⌉` containers (all cold).
/// Candidate `a`s are release-time quantiles (≤ 64); for each, a
/// deadline-ordered prefix sweep evaluates every candidate `b` in
/// O(n). Container-seconds from this family degenerate to the
/// whole-window work bound (W is monotone in the segment), which is
/// what it reports for that objective.
pub fn segment_bound(log: &InvocationLog) -> Bounds {
    let stages = prepare(log);
    let mut work_us = 0.0;
    let mut cold = 0u64;
    for st in stages.values() {
        if st.wins.is_empty() {
            continue;
        }
        for w in &st.wins {
            work_us += w.occ;
        }
        let mut starts: Vec<f64> = st.wins.iter().map(|w| w.r).collect();
        starts.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        starts.dedup();
        let starts = subsample(&starts);
        let mut best: u64 = 1;
        for &a in &starts {
            let mut items: Vec<(f64, f64)> = st
                .wins
                .iter()
                .filter(|w| w.r >= a)
                .map(|w| (w.d, w.occ))
                .collect();
            items.sort_by(|x, y| {
                x.0.partial_cmp(&y.0)
                    .expect("finite")
                    .then(x.1.partial_cmp(&y.1).expect("finite"))
            });
            let mut acc = 0.0;
            for &(d, occ) in &items {
                acc += occ;
                let len = d - a;
                if len > 0.0 {
                    let k = (acc / len - 1e-9).ceil();
                    if k > best as f64 {
                        best = k as u64;
                    }
                }
            }
        }
        cold += best;
    }
    Bounds {
        container_s: to_seconds(work_us),
        cold_starts: cold,
    }
}

/// Deterministic quantile subsample: keeps first and last, evenly
/// spaced indices in between.
fn subsample(starts: &[f64]) -> Vec<f64> {
    if starts.len() <= MAX_SEGMENT_STARTS {
        return starts.to_vec();
    }
    (0..MAX_SEGMENT_STARTS)
        .map(|i| starts[i * (starts.len() - 1) / (MAX_SEGMENT_STARTS - 1)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Invocation, InvocationLog};
    use super::*;
    use crate::util::secs;

    fn log_of(cap: usize, entries: Vec<Invocation>) -> InvocationLog {
        let mut batch_cap = BTreeMap::new();
        for e in &entries {
            batch_cap.insert(e.ms_id, cap);
        }
        InvocationLog {
            entries,
            gamma: 0.0,
            overhead: 0,
            batch_cap,
        }
    }

    fn unit_inv(enq_s: f64, end_s: f64, budget_s: f64) -> Invocation {
        Invocation {
            ms_id: 0,
            enqueued: secs(enq_s),
            exec_start: secs(enq_s),
            exec_end: secs(end_s),
            batch: 1,
            budget: secs(budget_s),
        }
    }

    #[test]
    fn path_cover_touching_windows_do_not_overlap() {
        // two tight jobs back to back: mandatory windows touch at t=1s
        // and must not be counted as simultaneous
        let log = log_of(
            1,
            vec![unit_inv(0.0, 1.0, 1.0), unit_inv(1.0, 2.0, 1.0)],
        );
        let b = path_cover_bound(&log);
        assert_eq!(b.cold_starts, 1);
        assert!((b.container_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn path_cover_concurrent_tight_jobs_need_two_containers() {
        let log = log_of(
            1,
            vec![unit_inv(0.0, 1.0, 1.0), unit_inv(0.0, 1.0, 1.0)],
        );
        let b = path_cover_bound(&log);
        assert_eq!(b.cold_starts, 2);
        assert!((b.container_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn path_cover_batch_capacity_divides_peak() {
        // four concurrent tight jobs, capacity 4: one shared pass does
        let log = log_of(4, (0..4).map(|_| unit_inv(0.0, 1.0, 1.0)).collect());
        let b = path_cover_bound(&log);
        assert_eq!(b.cold_starts, 1);
        assert!((b.container_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn segment_density_forces_container_count() {
        // 3s of work with releases and deadlines inside one 1s segment
        // -> at least 3 containers, despite the work bound alone only
        // implying "some container ran 3 container-seconds"
        let log = log_of(
            1,
            (0..3).map(|_| unit_inv(10.0, 11.0, 1.0)).collect(),
        );
        let b = segment_bound(&log);
        assert_eq!(b.cold_starts, 3);
        assert!((b.container_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn segment_exact_density_does_not_overshoot() {
        // density exactly 1.0: the epsilon guard must keep ceil at 1
        let log = log_of(1, vec![unit_inv(0.0, 10.0, 10.0)]);
        assert_eq!(segment_bound(&log).cold_starts, 1);
    }

    #[test]
    fn subsample_is_deterministic_and_keeps_endpoints() {
        let starts: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s1 = subsample(&starts);
        let s2 = subsample(&starts);
        assert_eq!(s1, s2);
        assert_eq!(s1.len(), MAX_SEGMENT_STARTS);
        assert_eq!(s1[0], 0.0);
        assert_eq!(*s1.last().unwrap(), 999.0);
    }

    #[test]
    fn slack_collapses_path_cover_but_not_work() {
        // generous budgets: every pass can be deferred, so no mandatory
        // parts exist — path-cover container_s drops to 0 while the
        // greedy work bound holds the floor
        let log = log_of(
            1,
            vec![unit_inv(0.0, 1.0, 100.0), unit_inv(0.5, 1.5, 100.0)],
        );
        let pc = path_cover_bound(&log);
        assert_eq!(pc.container_s, 0.0);
        assert_eq!(pc.cold_starts, 1);
        let g = greedy_bound(&log);
        assert!((g.container_s - 2.0).abs() < 1e-9);
    }
}
