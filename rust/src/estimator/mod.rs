//! Optimality gap: offline lower-bound estimators over a completed run's
//! recorded invocation set.
//!
//! Every policy comparison elsewhere in the repo is *relative* ("Fifer
//! spawns 4x fewer containers than Bline", paper §6). This module turns
//! that into an absolute yardstick: given the invocations a finished run
//! actually served — when each stage request became runnable, how long
//! it executed, what per-stage response budget the slack plan gave it —
//! compute **lower bounds** on the container-seconds and cold starts
//! *any* schedule could have achieved on the same invocation set, and
//! report each run's gap to those bounds. The estimator trio follows the
//! local-search / path-cover / segment family of FaaS scheduling bounds
//! (dslab-faas-estimators; NOAH, arxiv 1809.06100, frames serverless
//! scheduling as the job-scheduling problem these bounds come from):
//!
//! * [`bounds::greedy_bound`] — greedy interval-packing: every
//!   invocation packed into a maximally shared batch slot (work /
//!   capacity bound).
//! * [`bounds::path_cover_bound`] — path cover over the idle-gap graph:
//!   Dilworth's theorem turns the minimum chain cover of the
//!   "same container can serve i then j" DAG into the peak of the
//!   mandatory-execution profile.
//! * [`bounds::segment_bound`] — segmented LP-relaxation: work that must
//!   complete inside a time segment, divided by segment capacity.
//!
//! # Soundness
//!
//! The headline invariant (pinned by `rust/tests/test_estimator.rs` for
//! every registered policy): **a bound never exceeds the recorded run's
//! achieved cost**. Two constructions make this hold unconditionally:
//!
//! * Deadlines are *relaxed to realized completions*: an invocation's
//!   window is `[enqueued, max(enqueued + budget, exec_end)]`, so the
//!   observed schedule is always feasible for the instance being
//!   bounded — even when the run violated SLOs — and the optimum of the
//!   relaxed instance is ≤ the observed cost.
//! * Per-invocation service times are recovered from the recorded batch
//!   pass by inverting the exec model
//!   (`exec(B) = exec(1)·(1 + γ·(B−1)) + overhead`), so the minimal
//!   occupancy credited to an invocation never exceeds its realized
//!   share of container time.
//!
//! Two caveats, documented in `docs/EXPERIMENTS.md`: bounds are
//! **per-objective, not joint** (the container-second optimum may retire
//! eagerly while the cold-start optimum keeps containers alive forever —
//! no single schedule need attain both), and they are *clairvoyant over
//! the recorded invocation set* (stage release times are taken from the
//! run being analyzed, the standard offline-estimator convention).
//!
//! Capture is an opt-in [`EngineCore`](crate::coordinator::engine)
//! tap like the obs collector: `None` by default, so runs that don't ask
//! for it keep the zero-alloc pin and byte-identity untouched. Enable
//! via `fifer scenario run <spec> --optimality` (byte-deterministic
//! across `--threads`), `fifer simulate --optimality`, or
//! [`crate::sim::run_summarized_full`].

pub mod bounds;

use std::collections::BTreeMap;

use crate::metrics::Recorder;
use crate::model::MsId;
use crate::util::json::Json;
use crate::util::{Micros, MICROS_PER_S};

pub use bounds::{greedy_bound, path_cover_bound, segment_bound, Bounds};

/// One recorded stage invocation: everything the estimators need to
/// reconstruct its feasible execution window and minimal footprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Invocation {
    pub ms_id: MsId,
    /// When the request entered the stage's global queue (its release).
    pub enqueued: Micros,
    /// When its batch pass began executing.
    pub exec_start: Micros,
    /// When its batch pass finished.
    pub exec_end: Micros,
    /// Size of the batch pass that served it.
    pub batch: u32,
    /// Per-stage response budget S_r (slack + exec, µs) from the slack
    /// plan — the deadline is `enqueued + budget`, relaxed to the
    /// realized completion when the run overshot it.
    pub budget: Micros,
}

impl Invocation {
    /// Relaxed deadline: the slack-plan deadline, widened to the
    /// realized completion so the recorded schedule is always feasible
    /// for the instance being bounded.
    pub fn deadline(&self) -> Micros {
        (self.enqueued + self.budget).max(self.exec_end)
    }
}

/// The invocation set of one completed run, captured by the
/// `EngineCore` tap, plus the exec-model constants needed to invert
/// recorded batch passes into per-invocation service times.
#[derive(Debug, Clone, Default)]
pub struct InvocationLog {
    /// Stage invocations in completion order (deterministic per seed).
    pub entries: Vec<Invocation>,
    /// Batch cost slope γ of `exec(B) = exec(1)·(1 + γ·(B−1))`.
    pub gamma: f64,
    /// Warm scheduling overhead added to every batch pass (µs).
    pub overhead: Micros,
    /// Slack-plan batch capacity per stage (shared capacity one
    /// container offers a hypothetical schedule).
    pub batch_cap: BTreeMap<MsId, usize>,
}

/// Lower bounds vs achieved cost for one run — the `optimality` block of
/// [`crate::metrics::Summary`].
#[derive(Debug, Clone)]
pub struct OptimalityReport {
    /// Stage invocations analyzed.
    pub invocations: u64,
    pub greedy: Bounds,
    pub path_cover: Bounds,
    pub segment: Bounds,
    /// Best (largest) lower bound on container-seconds across the trio.
    pub bound_container_s: f64,
    /// Best (largest) lower bound on cold starts across the trio.
    pub bound_cold_starts: u64,
    /// The run's realized container-seconds over its full horizon
    /// (drain included — the same window the log covers).
    pub achieved_container_s: f64,
    /// The run's realized cold starts.
    pub achieved_cold_starts: u64,
    /// `100·(achieved − bound)/achieved` per objective; 0 when nothing
    /// was achieved. Negative would mean an unsound bound — the
    /// soundness suite asserts it never is.
    pub gap_container_pct: f64,
    pub gap_cold_start_pct: f64,
}

fn gap_pct(achieved: f64, bound: f64) -> f64 {
    if achieved <= 0.0 {
        0.0
    } else {
        100.0 * (achieved - bound) / achieved
    }
}

/// Realized container-seconds over the run's full lifetime, drain
/// included — the cost the log's invocation set was served at. (The
/// [`crate::metrics::Summary`] `avg_containers` field trims warm-up and
/// clamps at the horizon; the bound must compare against the *whole*
/// window its invocations span, so this is computed separately.)
pub fn achieved_container_seconds(rec: &Recorder) -> f64 {
    let mut area: u64 = 0;
    for c in &rec.containers {
        let end = c.retired_at.unwrap_or_else(|| rec.horizon.max(c.spawned_at));
        area += end.saturating_sub(c.spawned_at);
    }
    area as f64 / MICROS_PER_S as f64
}

/// Run the full estimator trio over a captured log and the recorder of
/// the same run; the combined bound per objective is the max across
/// estimators (each is individually sound, so their max is too).
pub fn analyze(log: &InvocationLog, rec: &Recorder) -> OptimalityReport {
    let greedy = greedy_bound(log);
    let path_cover = path_cover_bound(log);
    let segment = segment_bound(log);
    let bound_container_s = greedy
        .container_s
        .max(path_cover.container_s)
        .max(segment.container_s);
    let bound_cold_starts = greedy
        .cold_starts
        .max(path_cover.cold_starts)
        .max(segment.cold_starts);
    let achieved_container_s = achieved_container_seconds(rec);
    let achieved_cold_starts = rec.cold_starts;
    OptimalityReport {
        invocations: log.entries.len() as u64,
        greedy,
        path_cover,
        segment,
        bound_container_s,
        bound_cold_starts,
        achieved_container_s,
        achieved_cold_starts,
        gap_container_pct: gap_pct(achieved_container_s, bound_container_s),
        gap_cold_start_pct: gap_pct(achieved_cold_starts as f64, bound_cold_starts as f64),
    }
}

impl OptimalityReport {
    /// Byte-deterministic JSON rendering (sorted keys; entry order and
    /// every float are pure functions of the run's seed).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("invocations", Json::Num(self.invocations as f64)),
            ("bound_container_s", Json::Num(self.bound_container_s)),
            (
                "bound_cold_starts",
                Json::Num(self.bound_cold_starts as f64),
            ),
            (
                "achieved",
                Json::obj(vec![
                    ("container_s", Json::Num(self.achieved_container_s)),
                    (
                        "cold_starts",
                        Json::Num(self.achieved_cold_starts as f64),
                    ),
                ]),
            ),
            (
                "gap_pct",
                Json::obj(vec![
                    ("container_s", Json::Num(self.gap_container_pct)),
                    ("cold_starts", Json::Num(self.gap_cold_start_pct)),
                ]),
            ),
            (
                "estimators",
                Json::obj(vec![
                    ("greedy", self.greedy.to_json()),
                    ("path_cover", self.path_cover.to_json()),
                    ("segment", self.segment.to_json()),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ms, secs};

    /// A log with `overhead = 0`, `γ = 0`, unit batches — per-invocation
    /// service time is exactly `exec_end − exec_start`.
    fn plain_log(entries: Vec<Invocation>) -> InvocationLog {
        let mut batch_cap = BTreeMap::new();
        for e in &entries {
            batch_cap.insert(e.ms_id, 1);
        }
        InvocationLog {
            entries,
            gamma: 0.0,
            overhead: 0,
            batch_cap,
        }
    }

    fn inv(ms_id: MsId, enq_s: f64, start_s: f64, end_s: f64, budget_s: f64) -> Invocation {
        Invocation {
            ms_id,
            enqueued: secs(enq_s),
            exec_start: secs(start_s),
            exec_end: secs(end_s),
            batch: 1,
            budget: secs(budget_s),
        }
    }

    fn trivial_recorder(horizon_s: f64, containers: u64) -> Recorder {
        let mut r = Recorder::new();
        r.horizon = secs(horizon_s);
        for i in 0..containers {
            r.container_spawned(i, 0, 0, true);
            r.container_retired(i, secs(horizon_s));
        }
        r
    }

    // --- edge cases: every estimator returns a defined bound ---------

    #[test]
    fn empty_trace_bounds_are_zero() {
        let log = plain_log(Vec::new());
        for b in [greedy_bound(&log), path_cover_bound(&log), segment_bound(&log)] {
            assert_eq!(b.container_s, 0.0);
            assert_eq!(b.cold_starts, 0);
        }
        let rep = analyze(&log, &trivial_recorder(10.0, 2));
        assert_eq!(rep.invocations, 0);
        assert_eq!(rep.bound_container_s, 0.0);
        assert_eq!(rep.bound_cold_starts, 0);
        // idle provisioned containers -> 100% gap, still well defined
        assert!((rep.gap_container_pct - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_job_bounds_are_exact() {
        // 2s of work, 5s budget: slack erases the mandatory window, so
        // path-cover contributes no container-seconds — but the
        // combined bound is held exact by the work-based estimators
        let log = plain_log(vec![inv(3, 0.0, 0.0, 2.0, 5.0)]);
        for b in [greedy_bound(&log), segment_bound(&log)] {
            assert!(
                (b.container_s - 2.0).abs() < 1e-9,
                "container_s {}",
                b.container_s
            );
        }
        for b in [greedy_bound(&log), path_cover_bound(&log), segment_bound(&log)] {
            assert_eq!(b.cold_starts, 1);
        }
        let rep = analyze(&log, &trivial_recorder(5.0, 1));
        assert!((rep.bound_container_s - 2.0).abs() < 1e-9);
        assert_eq!(rep.bound_cold_starts, 1);
    }

    #[test]
    fn all_cold_trace_is_bounded_by_achieved() {
        // three far-apart invocations, budget so tight no container
        // could span two of them warm: the run cold-started each
        let log = plain_log(vec![
            inv(0, 0.0, 0.0, 1.0, 1.0),
            inv(0, 100.0, 100.0, 101.0, 1.0),
            inv(0, 200.0, 200.0, 201.0, 1.0),
        ]);
        let rec = {
            let mut r = Recorder::new();
            r.horizon = secs(201.0);
            for (i, t) in [0.0, 100.0, 200.0].iter().enumerate() {
                r.container_spawned(i as u64, 0, secs(*t), true);
                r.container_retired(i as u64, secs(*t + 1.0));
            }
            r
        };
        let rep = analyze(&log, &rec);
        assert!((rep.achieved_container_s - 3.0).abs() < 1e-9);
        assert!(rep.bound_container_s <= rep.achieved_container_s + 1e-9);
        // keeping one container alive across the gaps is feasible, so
        // the cold-start bound must not exceed 1 per stage... and never
        // the achieved 3
        assert!(rep.bound_cold_starts >= 1);
        assert!(rep.bound_cold_starts <= rep.achieved_cold_starts);
    }

    #[test]
    fn all_slo_violating_trace_returns_defined_bounds() {
        // zero budget: every invocation misses its deadline; the
        // relaxation widens windows to realized completions instead of
        // producing an infeasible (or panicking) instance
        let log = plain_log(vec![
            inv(0, 0.0, 5.0, 7.0, 0.0),
            inv(0, 1.0, 9.0, 12.0, 0.0),
        ]);
        for b in [greedy_bound(&log), path_cover_bound(&log), segment_bound(&log)] {
            assert!(b.container_s.is_finite() && b.container_s >= 0.0);
            assert!(b.cold_starts >= 1);
        }
        assert!(greedy_bound(&log).container_s > 0.0);
        for e in &log.entries {
            assert!(e.deadline() >= e.exec_end);
        }
    }

    #[test]
    fn report_json_shape_and_determinism() {
        let log = plain_log(vec![inv(0, 0.0, 0.0, 1.0, 2.0), inv(1, 0.5, 1.0, 2.0, 2.0)]);
        let rep = analyze(&log, &trivial_recorder(5.0, 2));
        let js = rep.to_json().to_string();
        assert_eq!(js, rep.to_json().to_string());
        for key in [
            "\"bound_container_s\"",
            "\"bound_cold_starts\"",
            "\"achieved\"",
            "\"gap_pct\"",
            "\"greedy\"",
            "\"path_cover\"",
            "\"segment\"",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
    }

    #[test]
    fn batch_inversion_never_credits_more_than_realized_share() {
        // a batch of 4 at γ=0.25 with 10ms overhead: occupancy credited
        // per invocation must stay below its realized share of the pass
        let e = Invocation {
            ms_id: 0,
            enqueued: 0,
            exec_start: ms(5.0),
            exec_end: ms(5.0) + ms(10.0) + ms(100.0 * 1.75),
            batch: 4,
            budget: ms(1000.0),
        };
        let mut batch_cap = BTreeMap::new();
        batch_cap.insert(0, 4);
        let log = InvocationLog {
            entries: vec![e],
            gamma: 0.25,
            overhead: ms(10.0),
            batch_cap,
        };
        let b = greedy_bound(&log);
        let realized_share_s = (e.exec_end - e.exec_start) as f64 / 4.0 / 1e6;
        assert!(b.container_s <= realized_share_s + 1e-12);
        assert!(b.container_s > 0.0);
    }
}
