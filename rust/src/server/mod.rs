//! Live serving runtime: real batched inference behind Fifer batching.
//!
//! This is the end-to-end validation layer (docs/DESIGN.md §1): a load
//! generator produces requests for the paper's function chains; the
//! coordinator applies the *same* slack-based batching plan as the
//! simulator; executor threads run the actual AOT-compiled XLA artifacts
//! through PJRT. Python is never involved — the binary is self-contained
//! after `make artifacts`.
//!
//! Threading model (std threads + channels; no async runtime needed for
//! this workload shape):
//!
//! ```text
//! [generator] --Arrival--> [coordinator loop] --ExecJob--> [executor 0..N]
//!      ^                        |   ^                            |
//!      |                        v   +---------StageDone----------+
//!   Poisson              per-stage queues,
//!   arrivals             batch flush on full-or-deadline
//! ```
//!
//! Cold starts in live mode are *real*: the first batch hitting a
//! (microservice, batch-size) pair pays the PJRT compile + weight upload
//! on that executor, mirroring how a fresh container pays image pull +
//! runtime init (the simulator models the latter; the live path measures
//! the former).

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::SystemConfig;
use crate::coordinator::policy::SchedulerPolicy;
use crate::coordinator::slack::SlackPlan;
use crate::model::{Catalog, ChainId, MsId};
use crate::runtime::Runtime;
use crate::util::rng::Pcg;
use crate::util::stats;

/// Work item sent to an executor thread.
struct ExecJob {
    ms_name: &'static str,
    /// job ids in this batch (batch size = len)
    jobs: Vec<u64>,
    /// row-major (len, input_dim) inputs
    inputs: Vec<f32>,
}

/// Completion message back to the coordinator.
struct StageDone {
    jobs: Vec<u64>,
    ms_id: MsId,
    exec_ms: f64,
    /// executor paid a compile ("cold start") for this batch
    cold: bool,
}

enum Msg {
    Arrival { chain: ChainId, t: Instant },
    Done(StageDone),
    Tick,
    GenDone,
}

/// Per-job live state.
struct LiveJob {
    chain: ChainId,
    arrival: Instant,
    stage_idx: usize,
    enqueued: Instant,
    exec_ms_total: f64,
    cold_hit: bool,
}

/// Results of a live serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub jobs: u64,
    pub duration_s: f64,
    pub throughput_rps: f64,
    pub median_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub slo_violation_pct: f64,
    pub batches: u64,
    /// average realized batch size (requests per PJRT call)
    pub avg_batch: f64,
    pub cold_compiles: u64,
    /// mean per-batch inference wall time by stage name
    pub stage_exec_ms: HashMap<&'static str, f64>,
}

/// Parameters for a live run.
#[derive(Debug, Clone)]
pub struct ServeParams {
    pub cfg: SystemConfig,
    pub chains: Vec<ChainId>,
    /// request rate (req/s) and duration
    pub rate: f64,
    pub duration_s: f64,
    pub executors: usize,
    /// max time a request may wait for its batch to fill, as a fraction
    /// of the stage's allocated slack
    pub flush_frac: f64,
}

impl ServeParams {
    pub fn quick(rate: f64, duration_s: f64) -> ServeParams {
        ServeParams {
            cfg: SystemConfig::prototype(crate::config::Policy::Fifer),
            chains: vec![2, 3], // IPA + DetectFatigue (heavy mix)
            rate,
            duration_s,
            executors: 2,
            flush_frac: 0.5,
        }
    }
}

struct StageBuf {
    jobs: Vec<u64>,
    oldest: Option<Instant>,
}

/// Input dim per microservice — matches python/compile/model.MICROSERVICES.
fn input_dim(cat: &Catalog, ms_id: MsId) -> usize {
    match cat.microservices[ms_id].name {
        "IMC" | "AP" | "QA" => 1024,
        "HS" => 2048,
        "FACER" | "FACED" => 256,
        "ASR" => 1280,
        _ => 64, // POS / NER / NLP
    }
}

/// Flush one stage buffer as a single batched PJRT call.
#[allow(clippy::too_many_arguments)]
fn flush_buf(
    cat: &Catalog,
    exec_txs: &[Sender<ExecJob>],
    ms_id: MsId,
    buf: &mut StageBuf,
    rr: &mut usize,
    rng: &mut Pcg,
    batches: &mut u64,
    batched_jobs: &mut u64,
) {
    if buf.jobs.is_empty() {
        return;
    }
    let dim = input_dim(cat, ms_id);
    let rows = buf.jobs.len();
    let mut inputs = vec![0.0f32; rows * dim];
    for v in inputs.iter_mut() {
        *v = rng.normal() as f32 * 0.5;
    }
    let job = ExecJob {
        ms_name: cat.microservices[ms_id].name,
        jobs: std::mem::take(&mut buf.jobs),
        inputs,
    };
    buf.oldest = None;
    *batches += 1;
    *batched_jobs += rows as u64;
    let _ = exec_txs[*rr % exec_txs.len()].send(job);
    *rr += 1;
}

/// Run the live server; blocks until the run drains.
///
/// The scheduler policy registered under `p.cfg.rm.policy` drives the
/// same trait object as the simulator: batching (and with it the Eq. 1
/// slack plan + deadline flushing) comes from the policy, never from an
/// engine branch. The live path has a fixed executor pool and flushes
/// whole stage buffers, so **only the `batching` hook applies here**;
/// `queue_order` (flushes take the entire buffer, so intra-batch order
/// is moot) and the container-scaling hooks (`on_arrival`, `on_monitor`,
/// `on_scan`) are exercised by the simulator.
pub fn serve(p: ServeParams) -> Result<ServeReport> {
    let cat = Catalog::paper();
    let pol: Box<dyn SchedulerPolicy> = p.cfg.rm.policy.build();
    let batching = pol.batching();
    let plan = SlackPlan::build(&cat, &p.chains, &p.cfg.rm, batching);
    let artifacts = Path::new(&p.cfg.artifacts_dir).to_path_buf();
    // fail fast if artifacts are missing
    crate::runtime::Manifest::load(&artifacts)?;

    let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();

    // --- executor pool -------------------------------------------------
    // Each executor precompiles the (stage, batch) executables it will
    // serve — the moral equivalent of container pre-warming — and signals
    // readiness before the load generator starts.
    let stage_batches: Vec<(&'static str, usize)> = {
        let mut v = Vec::new();
        for &cid in &p.chains {
            for &ms_id in &cat.chains[cid].stages {
                let name = cat.microservices[ms_id].name;
                for b in [1usize, plan.batch_for(ms_id)] {
                    if !v.contains(&(name, b)) {
                        v.push((name, b));
                    }
                }
            }
        }
        v
    };
    let (ready_tx, ready_rx) = channel::<()>();
    let mut exec_txs: Vec<Sender<ExecJob>> = Vec::new();
    let mut exec_handles = Vec::new();
    for _ in 0..p.executors.max(1) {
        let (etx, erx): (Sender<ExecJob>, Receiver<ExecJob>) = channel();
        exec_txs.push(etx);
        let back = tx.clone();
        let art = artifacts.clone();
        let cat2 = Catalog::paper();
        let warm = stage_batches.clone();
        let ready = ready_tx.clone();
        exec_handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rt = Runtime::new(&art)?;
            for (name, b) in warm {
                let batch = rt.manifest.pick_batch(b);
                rt.ensure_model(name, batch)?;
            }
            let _ = ready.send(());
            while let Ok(job) = erx.recv() {
                let before = rt.compiled_count();
                let t0 = Instant::now();
                let rows = job.jobs.len();
                let _out = rt.infer(job.ms_name, rows, &job.inputs)?;
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                let cold = rt.compiled_count() > before;
                let ms_id = cat2.ms_id(job.ms_name).unwrap();
                let _ = back.send(Msg::Done(StageDone {
                    jobs: job.jobs,
                    ms_id,
                    exec_ms,
                    cold,
                }));
            }
            Ok(())
        }));
    }

    // wait for all executors to finish pre-warming
    drop(ready_tx);
    for _ in 0..p.executors.max(1) {
        let _ = ready_rx.recv();
    }

    // --- load generator -------------------------------------------------
    {
        let gtx = tx.clone();
        let chains = p.chains.clone();
        let rate = p.rate;
        let dur = p.duration_s;
        let seed = p.cfg.seed;
        std::thread::spawn(move || {
            let mut rng = Pcg::new(seed ^ 0x9e37);
            let start = Instant::now();
            let mut i = 0usize;
            while start.elapsed().as_secs_f64() < dur {
                let gap = rng.exponential(1.0 / rate.max(0.1));
                std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
                let chain = chains[i % chains.len()];
                i += 1;
                if gtx
                    .send(Msg::Arrival {
                        chain,
                        t: Instant::now(),
                    })
                    .is_err()
                {
                    return;
                }
            }
            let _ = gtx.send(Msg::GenDone);
        });
    }

    // --- ticker ----------------------------------------------------------
    {
        let ttx = tx.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_millis(5));
            if ttx.send(Msg::Tick).is_err() {
                return;
            }
        });
    }
    drop(tx);

    // --- coordinator loop -------------------------------------------------
    // Deadline-based flush threshold per stage, precomputed once: the
    // ticker fires every 5 ms and must not redo slack-plan lookups for
    // every stage on every tick.
    let flush_deadline_ms: HashMap<MsId, f64> = {
        let mut m = HashMap::new();
        for &cid in &p.chains {
            for &ms_id in &cat.chains[cid].stages {
                m.entry(ms_id).or_insert_with(|| {
                    (plan.s_r_for(ms_id) - plan.exec_ms[&ms_id]).max(1.0) * p.flush_frac
                });
            }
        }
        m
    };
    let mut jobs: Vec<LiveJob> = Vec::new();
    let mut bufs: HashMap<MsId, StageBuf> = HashMap::new();
    let mut responses: Vec<f64> = Vec::new();
    let mut violations = 0u64;
    let mut batches = 0u64;
    let mut batched_jobs = 0u64;
    let mut cold_compiles = 0u64;
    let mut stage_exec: HashMap<&'static str, (f64, u64)> = HashMap::new();
    let mut rr = 0usize; // round-robin over executors
    let mut gen_done = false;
    let mut in_flight = 0u64;
    let mut rng = Pcg::new(p.cfg.seed ^ 0x51f3);
    let start = Instant::now();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Arrival { chain, t } => {
                let id = jobs.len() as u64;
                jobs.push(LiveJob {
                    chain,
                    arrival: t,
                    stage_idx: 0,
                    enqueued: t,
                    exec_ms_total: 0.0,
                    cold_hit: false,
                });
                in_flight += 1;
                let ms_id = cat.chains[chain].stages[0];
                let buf = bufs.entry(ms_id).or_insert(StageBuf {
                    jobs: Vec::new(),
                    oldest: None,
                });
                if buf.oldest.is_none() {
                    buf.oldest = Some(t);
                }
                buf.jobs.push(id);
                if buf.jobs.len() >= plan.batch_for(ms_id) {
                    flush_buf(&cat, &exec_txs, ms_id, buf, &mut rr, &mut rng,
                              &mut batches, &mut batched_jobs);
                }
            }
            Msg::Done(done) => {
                let n = done.jobs.len().max(1) as u64;
                let e = stage_exec
                    .entry(cat.microservices[done.ms_id].name)
                    .or_insert((0.0, 0));
                e.0 += done.exec_ms;
                e.1 += 1;
                if done.cold {
                    cold_compiles += 1;
                }
                for jid in done.jobs {
                    let j = &mut jobs[jid as usize];
                    j.exec_ms_total += done.exec_ms / n as f64;
                    j.cold_hit |= done.cold;
                    j.stage_idx += 1;
                    if j.stage_idx >= cat.chains[j.chain].stages.len() {
                        // complete
                        let resp = j.arrival.elapsed().as_secs_f64() * 1e3;
                        responses.push(resp);
                        if resp > cat.chains[j.chain].slo_ms {
                            violations += 1;
                        }
                        in_flight -= 1;
                    } else {
                        let ms_id = cat.chains[j.chain].stages[j.stage_idx];
                        j.enqueued = Instant::now();
                        let buf = bufs.entry(ms_id).or_insert(StageBuf {
                            jobs: Vec::new(),
                            oldest: None,
                        });
                        if buf.oldest.is_none() {
                            buf.oldest = Some(j.enqueued);
                        }
                        buf.jobs.push(jid);
                        if buf.jobs.len() >= plan.batch_for(ms_id) {
                            flush_buf(&cat, &exec_txs, ms_id, buf, &mut rr, &mut rng,
                                      &mut batches, &mut batched_jobs);
                        }
                    }
                }
                if gen_done && in_flight == 0 {
                    break;
                }
            }
            Msg::Tick => {
                // deadline-based flush: don't hold a batch longer than
                // flush_frac x the stage's allocated slack
                let ms_ids: Vec<MsId> = bufs.keys().copied().collect();
                for ms_id in ms_ids {
                    let deadline_ms = flush_deadline_ms[&ms_id];
                    let buf = bufs.get_mut(&ms_id).unwrap();
                    let stale = buf
                        .oldest
                        .map(|o| o.elapsed().as_secs_f64() * 1e3 > deadline_ms)
                        .unwrap_or(false);
                    if stale || (!batching && !buf.jobs.is_empty()) {
                        flush_buf(&cat, &exec_txs, ms_id, buf, &mut rr, &mut rng,
                                  &mut batches, &mut batched_jobs);
                    }
                }
                if gen_done && in_flight == 0 {
                    break;
                }
            }
            Msg::GenDone => {
                gen_done = true;
                if in_flight == 0 {
                    break;
                }
            }
        }
    }
    drop(exec_txs);
    for h in exec_handles {
        let _ = h.join();
    }

    responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let duration_s = start.elapsed().as_secs_f64();
    let n = responses.len().max(1) as f64;
    Ok(ServeReport {
        jobs: responses.len() as u64,
        duration_s,
        throughput_rps: responses.len() as f64 / duration_s.max(1e-9),
        median_ms: stats::percentile_sorted(&responses, 50.0),
        p99_ms: stats::percentile_sorted(&responses, 99.0),
        mean_ms: stats::mean(&responses),
        slo_violation_pct: 100.0 * violations as f64 / n,
        batches,
        avg_batch: if batches == 0 {
            0.0
        } else {
            batched_jobs as f64 / batches as f64
        },
        cold_compiles,
        stage_exec_ms: stage_exec
            .into_iter()
            .map(|(k, (sum, cnt))| (k, sum / cnt.max(1) as f64))
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_params_sane() {
        let p = ServeParams::quick(10.0, 1.0);
        // default policy is Fifer — a batching RM
        assert_eq!(p.cfg.rm.policy, crate::config::Policy::Fifer);
        assert!(p.cfg.rm.policy.build().batching());
        assert_eq!(p.chains.len(), 2);
    }

    // End-to-end serve() tests require artifacts + PJRT and live in
    // rust/tests/test_server_live.rs.
}
