//! Live serving runtime: the **real-time driver** over the shared
//! coordinator engine.
//!
//! This is the end-to-end validation layer (docs/DESIGN.md §1). A load
//! generator produces requests for the paper's function chains and the
//! *same* [`EngineCore`] that powers the simulator schedules them — same
//! stage queues, same indexed state store, same slack-plan batching,
//! same [`SchedulerPolicy`] hook surface (`on_start`, `on_arrival`,
//! `on_monitor`, `on_scan`). The difference is the real-time driver:
//! engine time is monotonic wall time, and every *container* the policy
//! spawns is a real executor thread. Spawning pays a real cold start
//! (PJRT compile + weight upload, or a modeled sleep in synthetic mode),
//! batches execute as actual batched inference, and idle reclamation
//! tears the threads down — the live path gets full policy-driven
//! autoscaling, not just batching.
//!
//! Threading model (std threads + channels; no async runtime needed for
//! this workload shape):
//!
//! ```text
//! [generator] --Arrival--> [coordinator loop: EngineCore] --exec_batch--> [container threads]
//!      ^                        |   ^                                          |
//!      |                        v   +-------- SpawnReady / ExecDone -----------+
//!   Poisson              policy hooks spawn/batch/retire
//!   arrivals             containers; 5 ms ticker advances
//!                        monitor/scan/window events
//! ```
//!
//! Two executor backends:
//!
//! * **PJRT** (default): each container thread owns a
//!   [`crate::runtime::Runtime`] and serves one microservice; the first
//!   compile of its (stage, batch) executable is the measured cold
//!   start. Requires `make artifacts` and a real `xla` binding.
//! * **Synthetic** ([`ServeParams::synthetic`]): container threads sleep
//!   the *modeled* cold-start and batched-execution times (same
//!   distributions as the simulator, drawn from the engine's seeded
//!   PCG), so the whole real-time machinery — threads, channels,
//!   wall-clock ticks, policy-driven scaling — runs anywhere, with no
//!   artifacts. CI smokes the live driver this way, and
//!   `rust/tests/test_driver_differential.rs` uses it to check sim
//!   vs live decision agreement for every registered policy.
//!
//! Results record through the same [`Recorder`]/[`Summary`] as the
//! simulator; [`ServeReport`] is a thin wrapper adding live-only tallies
//! (wall duration, PJRT batch stats).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ClusterConfig, SystemConfig};
use crate::coordinator::engine::{Driver, EffectCtx, EngineCore, SpawnEffect};
use crate::coordinator::policy::SchedulerPolicy;
use crate::coordinator::state::BatchStart;
use crate::metrics::{Recorder, Summary};
use crate::model::{Catalog, ChainId, MsId};
use crate::obs::{MetricsServer, ObsConfig, ObsReport, SharedSnapshot};
use crate::runtime::Runtime;
use crate::util::rng::Pcg;
use crate::util::{secs, Micros, MICROS_PER_S};

/// Executor implementation behind each live container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ExecBackend {
    /// Real batched inference through PJRT-compiled XLA artifacts.
    Pjrt,
    /// Modeled execution: threads sleep the sampled cold-start and
    /// exec(B) durations. No artifacts or PJRT needed.
    Synthetic,
}

/// Work item sent to a container's executor thread.
enum ContainerJob {
    /// Synthetic backend: occupy the container for `dur` engine-µs.
    Sleep { dur: Micros, rows: usize },
    /// PJRT backend: row-major (rows, input_dim) batched inference.
    Infer { rows: usize, inputs: Vec<f32> },
}

/// Messages into the coordinator loop.
enum Msg {
    Arrival { chain: ChainId },
    /// A container finished cold-starting (thread init complete).
    SpawnReady { cid: u64 },
    /// A container finished a batch.
    ExecDone {
        cid: u64,
        ms_id: MsId,
        exec_ms: f64,
        /// PJRT paid a compile ("cold start") inside this batch.
        cold: bool,
        rows: usize,
    },
    /// A container failed to come up (missing PJRT, bad artifact...).
    SpawnFailed { err: String },
    Tick,
    GenDone,
    /// Sharded runs only: the rebalancer asks this shard to give up one
    /// empty node's capacity to shard `to`.
    Donate { to: usize },
    /// Sharded runs only: capacity migrated in from another shard.
    Accept { cores: f64 },
}

/// Parameters for a live run.
#[derive(Debug, Clone)]
pub struct ServeParams {
    pub cfg: SystemConfig,
    pub chains: Vec<ChainId>,
    /// request rate (req/s) and duration
    pub rate: f64,
    pub duration_s: f64,
    /// Max live containers. The live "cluster" is one node with this
    /// many container slots; every container is an executor thread.
    pub executors: usize,
    /// Drain window after the generator stops (s); the run hard-stops at
    /// `duration_s + drain_s` even if requests are still in flight.
    pub drain_s: f64,
    /// Run the synthetic executor backend (no artifacts/PJRT needed).
    pub synthetic: bool,
    /// Bind address for the live `/metrics` endpoints (`None` = no
    /// responder). See `docs/OBSERVABILITY.md`.
    pub metrics_addr: Option<String>,
    /// Graceful-shutdown flag, polled once per coordinator-loop
    /// iteration: when it flips true the generator is cut off, in-flight
    /// work drains (bounded by `drain_s`), and the final [`ServeReport`]
    /// + last metrics snapshot are still emitted. `fifer serve` wires
    /// this to SIGINT via [`sigint_flag`]; tests flip a leaked flag.
    pub interrupt: Option<&'static AtomicBool>,
    /// Per-request span sampling: keep 1-in-N requests in the bounded
    /// trace ring served at `GET /traces` (0 disables the recorder).
    /// Live request rates are scrape-scale, so the default records
    /// every request.
    pub trace_sample: u64,
}

impl ServeParams {
    pub fn quick(rate: f64, duration_s: f64) -> ServeParams {
        ServeParams {
            cfg: SystemConfig::prototype(crate::config::Policy::Fifer),
            chains: vec![2, 3], // IPA + DetectFatigue (heavy mix)
            rate,
            duration_s,
            executors: 12,
            drain_s: 15.0,
            synthetic: false,
            metrics_addr: None,
            interrupt: None,
            trace_sample: 1,
        }
    }
}

/// Install a process-wide SIGINT handler (first Ctrl-C requests a
/// graceful drain; the second aborts immediately) and return the flag it
/// sets — pass it as [`ServeParams::interrupt`]. On non-Unix targets the
/// flag exists but no handler is installed. Idempotent.
#[cfg(unix)]
pub fn sigint_flag() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    static HITS: AtomicUsize = AtomicUsize::new(0);
    extern "C" fn on_sigint(_sig: i32) {
        // async-signal-safe: atomics only; a second SIGINT hard-aborts
        // for operators who really mean it
        if HITS.fetch_add(1, Ordering::SeqCst) >= 1 {
            std::process::abort();
        }
        FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // libc's signal(2); std already links libc on unix, so this
        // needs no external crate in the vendored-only build
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint);
    }
    &FLAG
}

/// Non-Unix fallback: a flag nothing sets (graceful shutdown via the
/// flag still works when flipped programmatically).
#[cfg(not(unix))]
pub fn sigint_flag() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    &FLAG
}

/// Results of a live serving run: the engine's [`Summary`] (and full
/// [`Recorder`]) plus live-only wall-clock and executor tallies.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Same aggregation as a simulation run (SLO violations, latency
    /// percentiles, container counts, per-stage RPC, energy model).
    pub summary: Summary,
    /// The underlying event log (job timelines, container history).
    pub recorder: Recorder,
    /// Wall-clock duration of the whole run (including drain).
    pub duration_s: f64,
    pub throughput_rps: f64,
    /// Batched execution passes completed (= `recorder.batches`, the
    /// same counter a simulation run reports).
    pub batches: u64,
    /// average realized batch size (requests per executor call)
    pub avg_batch: f64,
    /// PJRT compiles paid inside batches (0 under the synthetic backend).
    pub cold_compiles: u64,
    /// mean per-batch executor wall time by stage name
    pub stage_exec_ms: HashMap<&'static str, f64>,
    /// Final observability snapshot (timeline + SLO contract) — the same
    /// schema the `/metrics` endpoints serve and `--slo-timeline` emits.
    pub obs: Option<ObsReport>,
    /// The run was cut short by [`ServeParams::interrupt`] and drained
    /// instead of running its full duration.
    pub interrupted: bool,
}

/// Input dim per microservice — matches python/compile/model.MICROSERVICES.
fn input_dim(cat: &Catalog, ms_id: MsId) -> usize {
    match cat.microservices[ms_id].name {
        "IMC" | "AP" | "QA" => 1024,
        "HS" => 2048,
        "FACER" | "FACED" => 256,
        "ASR" => 1280,
        _ => 64, // POS / NER / NLP
    }
}

/// The wall-clock [`Driver`]: spawns are executor threads, batch
/// execution is a channel send, completions flow back through the
/// coordinator loop as `Msg`s.
struct RealTimeDriver {
    backend: ExecBackend,
    artifacts: PathBuf,
    /// Completion channel into the coordinator loop.
    back: Sender<Msg>,
    /// Per-container work channels (dropping one retires its thread).
    txs: HashMap<u64, Sender<ContainerJob>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl RealTimeDriver {
    fn new(backend: ExecBackend, artifacts: PathBuf, back: Sender<Msg>) -> RealTimeDriver {
        RealTimeDriver {
            backend,
            artifacts,
            back,
            txs: HashMap::new(),
            handles: Vec::new(),
        }
    }

    /// Close every container channel and join the executor threads.
    fn shutdown(mut self) {
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Driver for RealTimeDriver {
    fn begin_spawn(&mut self, ms_id: MsId, cold: bool, mut ctx: EffectCtx<'_>) -> SpawnEffect {
        let latency = match self.backend {
            // the shared modeled cold start (same formula and RNG stream
            // position as the simulator's virtual driver); the thread
            // sleeps it for real
            ExecBackend::Synthetic if cold => ctx.sample_cold_start(ms_id),
            // the real cold start is the PJRT compile, whose length is
            // unknown upfront — attribute with the model's expectation
            // until SpawnReady arrives
            ExecBackend::Pjrt if cold => ctx
                .coldstart
                .expected_micros(&ctx.cat.microservices[ms_id]),
            // warm spawns (not used by the current engine paths) carry
            // no modeled latency on either backend
            ExecBackend::Synthetic | ExecBackend::Pjrt => 0,
        };
        SpawnEffect::Pending(latency)
    }

    fn container_spawned(
        &mut self,
        cid: u64,
        ms_id: MsId,
        batch: usize,
        effect: SpawnEffect,
        ctx: EffectCtx<'_>,
    ) {
        let (jtx, jrx): (Sender<ContainerJob>, Receiver<ContainerJob>) = channel();
        self.txs.insert(cid, jtx);
        let back = self.back.clone();
        let name = ctx.cat.microservices[ms_id].name;
        // the latency this driver reported from begin_spawn: the
        // synthetic backend sleeps it for real before signalling ready
        let cold_us = effect.latency();
        match self.backend {
            ExecBackend::Synthetic => {
                self.handles.push(std::thread::spawn(move || {
                    // modeled cold start, interruptible so a retired or
                    // end-of-run container doesn't hold up shutdown joins
                    let mut slept = 0u64;
                    while slept < cold_us {
                        let step = (cold_us - slept).min(50_000);
                        std::thread::sleep(Duration::from_micros(step));
                        slept += step;
                        if matches!(
                            jrx.try_recv(),
                            Err(std::sync::mpsc::TryRecvError::Disconnected)
                        ) {
                            return;
                        }
                    }
                    if back.send(Msg::SpawnReady { cid }).is_err() {
                        return;
                    }
                    while let Ok(job) = jrx.recv() {
                        let ContainerJob::Sleep { dur, rows } = job else {
                            continue;
                        };
                        let t0 = Instant::now();
                        std::thread::sleep(Duration::from_micros(dur));
                        let done = Msg::ExecDone {
                            cid,
                            ms_id,
                            exec_ms: t0.elapsed().as_secs_f64() * 1e3,
                            cold: false,
                            rows,
                        };
                        if back.send(done).is_err() {
                            return;
                        }
                    }
                }));
            }
            ExecBackend::Pjrt => {
                let artifacts = self.artifacts.clone();
                self.handles.push(std::thread::spawn(move || {
                    // the real cold start: PJRT client + weight upload +
                    // compiling this container's (stage, batch) pair
                    let mut rt = match Runtime::new(&artifacts) {
                        Ok(rt) => rt,
                        Err(e) => {
                            let _ = back.send(Msg::SpawnFailed {
                                err: format!("{e:#}"),
                            });
                            return;
                        }
                    };
                    for b in [1usize, batch] {
                        let pb = rt.manifest.pick_batch(b);
                        if let Err(e) = rt.ensure_model(name, pb) {
                            let _ = back.send(Msg::SpawnFailed {
                                err: format!("{e:#}"),
                            });
                            return;
                        }
                    }
                    if back.send(Msg::SpawnReady { cid }).is_err() {
                        return;
                    }
                    while let Ok(job) = jrx.recv() {
                        let ContainerJob::Infer { rows, inputs } = job else {
                            continue;
                        };
                        let before = rt.compiled_count();
                        let t0 = Instant::now();
                        match rt.infer(name, rows, &inputs) {
                            Ok(_) => {
                                let done = Msg::ExecDone {
                                    cid,
                                    ms_id,
                                    exec_ms: t0.elapsed().as_secs_f64() * 1e3,
                                    cold: rt.compiled_count() > before,
                                    rows,
                                };
                                if back.send(done).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                let _ = back.send(Msg::SpawnFailed {
                                    err: format!("{e:#}"),
                                });
                                return;
                            }
                        }
                    }
                }));
            }
        }
    }

    fn exec_batch(&mut self, cid: u64, b: &BatchStart, mut ctx: EffectCtx<'_>) -> Option<Micros> {
        let rows = b.len;
        let job = match self.backend {
            ExecBackend::Synthetic => {
                // the shared exec model (and RNG stream) of the virtual
                // driver: exec(B) = exec(1)·(1 + γ·(B−1)) + warm overhead
                ContainerJob::Sleep {
                    dur: ctx.sample_batch_exec(b),
                    rows,
                }
            }
            ExecBackend::Pjrt => {
                let dim = input_dim(ctx.cat, b.ms_id);
                let mut inputs = vec![0.0f32; rows * dim];
                for v in inputs.iter_mut() {
                    *v = ctx.rng.normal() as f32 * 0.5;
                }
                ContainerJob::Infer { rows, inputs }
            }
        };
        let sent = match self.txs.get(&cid) {
            Some(tx) => tx.send(job).is_ok(),
            None => false,
        };
        if sent {
            None
        } else {
            // executor thread is gone (failed spawn): complete virtually
            // so the run drains instead of wedging
            Some(crate::util::ms(ctx.cat.microservices[b.ms_id].exec_ms_mean))
        }
    }

    fn container_retired(&mut self, cid: u64) {
        // closing the channel retires the thread; it is joined in
        // `shutdown`. Only idle containers are ever retired, so no work
        // is lost.
        self.txs.remove(&cid);
    }
}

/// Run the live server; blocks until the run drains (or hard-stops at
/// `duration_s + drain_s`).
///
/// The scheduler policy registered under `p.cfg.rm.policy` drives the
/// same [`EngineCore`] as the simulator, through the full hook surface:
/// `on_start` provisions the initial pool, `on_arrival` spawns reactive
/// per-request containers, `on_monitor` executes `ScalingPlan`s against
/// real executor threads, and `on_scan` retires idle ones. Batching
/// emerges exactly as in the simulator — requests queue on a busy
/// container's local slots and execute as one batched pass.
pub fn serve(p: ServeParams) -> Result<ServeReport> {
    let cat = Catalog::paper();
    let pol: Box<dyn SchedulerPolicy> = p.cfg.rm.policy.build();
    let backend = if p.synthetic {
        ExecBackend::Synthetic
    } else {
        // fail fast if artifacts are missing
        crate::runtime::Manifest::load(Path::new(&p.cfg.artifacts_dir))?;
        ExecBackend::Pjrt
    };

    // the live cluster: one node, `executors` container slots
    let mut cfg = p.cfg.clone();
    cfg.cluster = ClusterConfig {
        nodes: 1,
        cores_per_node: p.executors.max(1),
        cpu_per_container: 1.0,
        ..p.cfg.cluster.clone()
    };

    let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
    let artifacts = PathBuf::from(&cfg.artifacts_dir);
    let driver = RealTimeDriver::new(backend, artifacts, tx.clone());

    let start = Instant::now();
    let horizon = secs(p.duration_s);
    let end = horizon + secs(p.drain_s.max(0.0));
    let mut core = EngineCore::build(cfg, p.chains.clone(), p.rate, pol, driver);
    // live runs always collect telemetry (one branch per decision point;
    // the default ring is 24 h of minute buckets) — the /metrics
    // responder is what's optional. Enabled before bootstrap so the
    // initial provisioning spawns are counted, as in the sim driver.
    // Span recording rides the same collector, bounded by the default
    // trace ring, and is served at GET /traces.
    core.enable_obs(ObsConfig {
        trace_sample: p.trace_sample,
        ..ObsConfig::default()
    });
    core.bootstrap(horizon, end);
    let metrics: Option<(MetricsServer, SharedSnapshot)> = match &p.metrics_addr {
        Some(addr) => {
            let shared: SharedSnapshot = Arc::new(Mutex::new(None));
            let server = MetricsServer::start(addr, shared.clone())?;
            // publish a first (empty-timeline) snapshot so the endpoints
            // answer 200 from the moment serve() is up
            *shared.lock().expect("metrics snapshot lock") = core.obs_report();
            Some((server, shared))
        }
        None => None,
    };

    // --- load generator -------------------------------------------------
    {
        let gtx = tx.clone();
        let chains = p.chains.clone();
        let rate = p.rate;
        let dur = p.duration_s;
        let seed = p.cfg.seed;
        std::thread::spawn(move || {
            let mut rng = Pcg::new(seed ^ 0x9e37);
            let t0 = Instant::now();
            let mut i = 0usize;
            while t0.elapsed().as_secs_f64() < dur {
                let gap = rng.exponential(1.0 / rate.max(0.1));
                std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
                let chain = chains[i % chains.len()];
                i += 1;
                if gtx.send(Msg::Arrival { chain }).is_err() {
                    return;
                }
            }
            let _ = gtx.send(Msg::GenDone);
        });
    }

    // --- ticker: advances engine time so monitor/scan/window events
    // fire even while no requests move ------------------------------------
    {
        let ttx = tx.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_millis(5));
            if ttx.send(Msg::Tick).is_err() {
                return;
            }
        });
    }
    drop(tx);

    // --- coordinator loop -------------------------------------------------
    let mut gen_done = false;
    let mut fail: Option<String> = None;
    let mut batched_jobs = 0u64;
    let mut cold_compiles = 0u64;
    let mut stage_exec: HashMap<&'static str, (f64, u64)> = HashMap::new();
    let mut interrupted = false;
    // hard stop for the whole run; an interrupt pulls it in to "now +
    // drain window" so in-flight work gets a bounded chance to finish
    let mut stop_at = end;
    let mut last_pub: Micros = 0;

    while let Ok(msg) = rx.recv() {
        let t = start.elapsed().as_micros() as Micros;
        if !interrupted && p.interrupt.is_some_and(|f| f.load(Ordering::SeqCst)) {
            interrupted = true;
            gen_done = true; // stop waiting on the generator
            stop_at = (t + secs(p.drain_s.max(0.0))).min(end);
        }
        match msg {
            // an interrupted run sheds arrivals still in the channel
            Msg::Arrival { chain } if !interrupted => core.arrival_at(chain, t),
            Msg::Arrival { .. } => {}
            Msg::SpawnReady { cid } => core.spawn_completed(cid, t),
            Msg::ExecDone {
                cid,
                ms_id,
                exec_ms,
                cold,
                rows,
            } => {
                batched_jobs += rows as u64;
                if cold {
                    cold_compiles += 1;
                }
                let e = stage_exec
                    .entry(cat.microservices[ms_id].name)
                    .or_insert((0.0, 0));
                e.0 += exec_ms;
                e.1 += 1;
                core.batch_completed(cid, t);
            }
            Msg::Tick => core.advance_to(t),
            Msg::GenDone => gen_done = true,
            Msg::SpawnFailed { err } => {
                fail = Some(err);
                break;
            }
            // rebalancer traffic never reaches an unsharded loop
            Msg::Donate { .. } | Msg::Accept { .. } => {}
        }
        // publish a fresh snapshot at most once per second of engine time
        if let Some((_, shared)) = &metrics {
            if t.saturating_sub(last_pub) >= MICROS_PER_S {
                last_pub = t;
                *shared.lock().expect("metrics snapshot lock") = core.obs_report();
            }
        }
        let in_flight = core.jobs_arrived() - core.jobs_completed();
        if (gen_done && in_flight == 0) || t > stop_at {
            break;
        }
    }

    // fast-forward the remaining virtual schedule (idle scans, energy
    // sampling) so a live run settles exactly like a drained simulation
    // — unless it is bailing out, where firing more scaling plans would
    // only spawn doomed executors and delay the error
    if fail.is_none() {
        core.advance_to(stop_at);
    }
    let (recorder, driver, obs) = core.into_parts_obs();
    driver.shutdown();
    // emit the last snapshot (covering the drain) before the responder
    // goes down, then stop it
    if let Some((server, shared)) = metrics {
        *shared.lock().expect("metrics snapshot lock") = obs.clone();
        server.stop();
    }
    if let Some(err) = fail {
        anyhow::bail!("live executor failed: {err}");
    }

    let duration_s = start.elapsed().as_secs_f64();
    let summary = recorder.summarize(&cat);
    // batch count comes from the engine's recorder (the single source of
    // truth shared with the simulator); the ExecDone tallies only feed
    // the live-only columns (realized rows, compiles, per-stage wall ms)
    let batches = recorder.batches;
    Ok(ServeReport {
        throughput_rps: summary.jobs as f64 / duration_s.max(1e-9),
        avg_batch: if batches == 0 {
            0.0
        } else {
            batched_jobs as f64 / batches as f64
        },
        batches,
        cold_compiles,
        stage_exec_ms: stage_exec
            .into_iter()
            .map(|(k, (sum, cnt))| (k, sum / cnt.max(1) as f64))
            .collect(),
        duration_s,
        summary,
        recorder,
        obs,
        interrupted,
    })
}

/// What one live shard thread hands back when its loop drains.
struct ShardOutcome {
    recorder: Recorder,
    obs: Option<ObsReport>,
    batched_jobs: u64,
    cold_compiles: u64,
    stage_exec: HashMap<&'static str, (f64, u64)>,
    interrupted: bool,
    fail: Option<String>,
}

/// Run the live server sharded: N coordinator loops on real threads,
/// each owning an [`EngineCore`] over a slice of the executor budget
/// (one single-core node per executor, so nodes are the natural
/// migration unit), with arrivals routed by the same splitmix64 chain
/// hash as the sharded simulator and a main-thread rebalancer that
/// watches per-shard backlog pressure at the monitor cadence and asks
/// the least-pressured shard to donate one empty node to the most
/// pressured (`Msg::Donate` → [`EngineCore::donate_node_capacity`] →
/// `Msg::Accept`; capacity holding running containers never moves).
///
/// `shards <= 1` falls straight through to [`serve`] — the unsharded
/// path is untouched byte-for-byte. The merged [`ServeReport`] has the
/// same shape as an unsharded one; per-shard decision latency and load
/// appear in the merged obs snapshot (`/metrics/summary` `shards` key,
/// `/metrics/prom` `fifer_shard_*` series).
///
/// Known limit (shared with the sim, see docs/DESIGN.md §Sharding):
/// the engine's spawn-capacity guard is computed from the static
/// config, so a shard that *receives* capacity can pack existing nodes
/// better but not raise its container ceiling mid-run.
pub fn serve_sharded(p: ServeParams, shards: usize) -> Result<ServeReport> {
    use crate::coordinator::sharded::{partition_count, Rebalancer, RebalancerConfig, ShardRouter};
    use std::sync::atomic::AtomicU64;

    if shards <= 1 {
        return serve(p);
    }
    let nshards = shards;
    if p.executors < nshards {
        anyhow::bail!(
            "--shards {nshards} needs at least one executor per shard (have {})",
            p.executors
        );
    }
    let cat = Catalog::paper();
    let backend = if p.synthetic {
        ExecBackend::Synthetic
    } else {
        crate::runtime::Manifest::load(Path::new(&p.cfg.artifacts_dir))?;
        ExecBackend::Pjrt
    };

    // per-shard channels; every shard also holds the full sender list so
    // a donor can hand capacity straight to its receiver
    let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(nshards);
    let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(nshards);
    for _ in 0..nshards {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // (backlog, capacity-cores bits) published by each shard loop
    let load: Arc<Vec<(AtomicU64, AtomicU64)>> = Arc::new(
        (0..nshards)
            .map(|_| (AtomicU64::new(0), AtomicU64::new(0f64.to_bits())))
            .collect(),
    );
    let reports: Arc<Vec<Mutex<Option<ObsReport>>>> =
        Arc::new((0..nshards).map(|_| Mutex::new(None)).collect());
    let start = Instant::now(); // one epoch, so all shard clocks agree

    let metrics: Option<(MetricsServer, SharedSnapshot)> = match &p.metrics_addr {
        Some(addr) => {
            let shared: SharedSnapshot = Arc::new(Mutex::new(None));
            let server = MetricsServer::start(addr, shared.clone())?;
            Some((server, shared))
        }
        None => None,
    };

    // --- shard coordinator threads ------------------------------------
    let mut handles = Vec::with_capacity(nshards);
    for (k, rx) in rxs.into_iter().enumerate() {
        let exec_k = partition_count(p.executors, nshards, k);
        let base_cfg = p.cfg.clone();
        let chains = p.chains.clone();
        let peers = txs.clone();
        let my_tx = txs[k].clone();
        let load = load.clone();
        let reports = reports.clone();
        let (rate, duration_s, drain_s) = (p.rate, p.duration_s, p.drain_s);
        let (trace_sample, interrupt) = (p.trace_sample, p.interrupt);
        handles.push(std::thread::spawn(move || -> ShardOutcome {
            // policy and engine are built inside the thread (the policy
            // box is not Send); the shard cluster is one single-core
            // node per executor slot so empty nodes exist to migrate
            let pol: Box<dyn SchedulerPolicy> = base_cfg.rm.policy.build();
            let mut cfg = base_cfg;
            cfg.cluster = ClusterConfig {
                nodes: exec_k,
                cores_per_node: 1,
                cpu_per_container: 1.0,
                ..cfg.cluster.clone()
            };
            let driver =
                RealTimeDriver::new(backend, PathBuf::from(&cfg.artifacts_dir), my_tx.clone());
            let horizon = secs(duration_s);
            let end = horizon + secs(drain_s.max(0.0));
            let mut core = EngineCore::build(cfg, chains, rate / nshards as f64, pol, driver);
            core.enable_obs(ObsConfig {
                trace_sample,
                ..ObsConfig::default()
            });
            core.bootstrap(horizon, end);
            *reports[k].lock().expect("shard report lock") = core.obs_report();
            load[k].1.store(core.capacity_cores().to_bits(), Ordering::Relaxed);

            let mut gen_done = false;
            let mut fail: Option<String> = None;
            let mut batched_jobs = 0u64;
            let mut cold_compiles = 0u64;
            let mut stage_exec: HashMap<&'static str, (f64, u64)> = HashMap::new();
            let mut interrupted = false;
            let mut stop_at = end;
            let mut last_pub: Micros = 0;
            let cat = Catalog::paper();
            while let Ok(msg) = rx.recv() {
                let t = start.elapsed().as_micros() as Micros;
                if !interrupted && interrupt.is_some_and(|f| f.load(Ordering::SeqCst)) {
                    interrupted = true;
                    gen_done = true;
                    stop_at = (t + secs(drain_s.max(0.0))).min(end);
                }
                match msg {
                    Msg::Arrival { chain } if !interrupted => core.arrival_at(chain, t),
                    Msg::Arrival { .. } => {}
                    Msg::SpawnReady { cid } => core.spawn_completed(cid, t),
                    Msg::ExecDone {
                        cid,
                        ms_id,
                        exec_ms,
                        cold,
                        rows,
                    } => {
                        batched_jobs += rows as u64;
                        if cold {
                            cold_compiles += 1;
                        }
                        let e = stage_exec
                            .entry(cat.microservices[ms_id].name)
                            .or_insert((0.0, 0));
                        e.0 += exec_ms;
                        e.1 += 1;
                        core.batch_completed(cid, t);
                    }
                    Msg::Tick => core.advance_to(t),
                    Msg::GenDone => gen_done = true,
                    Msg::SpawnFailed { err } => {
                        fail = Some(err);
                        break;
                    }
                    Msg::Donate { to } => {
                        if let Some(cores) = core.donate_node_capacity() {
                            let _ = peers[to].send(Msg::Accept { cores });
                        }
                    }
                    Msg::Accept { cores } => core.accept_node_capacity(cores),
                }
                load[k].0.store(core.backlog() as u64, Ordering::Relaxed);
                load[k].1.store(core.capacity_cores().to_bits(), Ordering::Relaxed);
                if t.saturating_sub(last_pub) >= MICROS_PER_S {
                    last_pub = t;
                    *reports[k].lock().expect("shard report lock") = core.obs_report();
                }
                let in_flight = core.jobs_arrived() - core.jobs_completed();
                if (gen_done && in_flight == 0) || t > stop_at {
                    break;
                }
            }
            if fail.is_none() {
                core.advance_to(stop_at);
            }
            let (recorder, driver, obs) = core.into_parts_obs();
            driver.shutdown();
            *reports[k].lock().expect("shard report lock") = obs.clone();
            ShardOutcome {
                recorder,
                obs,
                batched_jobs,
                cold_compiles,
                stage_exec,
                interrupted,
                fail,
            }
        }));
    }

    // --- load generator: one stream, chain-hash routed ------------------
    {
        let gtxs = txs.clone();
        let chains = p.chains.clone();
        let rate = p.rate;
        let dur = p.duration_s;
        let seed = p.cfg.seed;
        let router = ShardRouter::new(seed, nshards);
        std::thread::spawn(move || {
            let mut rng = Pcg::new(seed ^ 0x9e37);
            let t0 = Instant::now();
            let mut i = 0usize;
            while t0.elapsed().as_secs_f64() < dur {
                let gap = rng.exponential(1.0 / rate.max(0.1));
                std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
                let chain = chains[i % chains.len()];
                i += 1;
                if gtxs[router.route(chain)].send(Msg::Arrival { chain }).is_err() {
                    return;
                }
            }
            for tx in &gtxs {
                let _ = tx.send(Msg::GenDone);
            }
        });
    }

    // --- ticker: broadcast; exits once every shard loop is gone ---------
    {
        let ttxs = txs.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_millis(5));
            if ttxs.iter().all(|tx| tx.send(Msg::Tick).is_err()) {
                return;
            }
        });
    }

    // --- main thread: snapshot merging + the rebalance tick -------------
    let mut rebalancer = Rebalancer::new(RebalancerConfig::default());
    let monitor_s = p.cfg.rm.monitor_interval_s.max(0.1);
    let mut last_rebalance = Instant::now();
    // is_finished (not a hand-rolled counter) so a panicking shard can't
    // wedge the supervisor
    while handles.iter().any(|h| !h.is_finished()) {
        std::thread::sleep(Duration::from_millis(200));
        if let Some((_, shared)) = &metrics {
            let snaps: Vec<ObsReport> = reports
                .iter()
                .filter_map(|m| m.lock().expect("shard report lock").clone())
                .collect();
            if snaps.len() == nshards {
                *shared.lock().expect("metrics snapshot lock") = crate::obs::merge_reports(snaps);
            }
        }
        if last_rebalance.elapsed().as_secs_f64() >= monitor_s {
            last_rebalance = Instant::now();
            let pressures: Vec<f64> = load
                .iter()
                .map(|(b, c)| {
                    b.load(Ordering::Relaxed) as f64
                        / f64::from_bits(c.load(Ordering::Relaxed)).max(1e-9)
                })
                .collect();
            if let Some((donor, receiver)) = rebalancer.plan(&pressures) {
                // best effort: the donor may refuse (no empty node), in
                // which case nothing moves and nothing is lost
                if txs[donor].send(Msg::Donate { to: receiver }).is_ok() {
                    rebalancer.record();
                }
            }
        }
    }
    drop(txs);

    let mut outcomes: Vec<ShardOutcome> = Vec::with_capacity(nshards);
    for h in handles {
        match h.join() {
            Ok(o) => outcomes.push(o),
            Err(_) => anyhow::bail!("shard coordinator thread panicked"),
        }
    }

    let obs = crate::obs::merge_reports(outcomes.iter().filter_map(|o| o.obs.clone()).collect());
    if let Some((server, shared)) = metrics {
        *shared.lock().expect("metrics snapshot lock") = obs.clone();
        server.stop();
    }
    if let Some(err) = outcomes.iter_mut().find_map(|o| o.fail.take()) {
        anyhow::bail!("live executor failed: {err}");
    }

    let duration_s = start.elapsed().as_secs_f64();
    let recorder = Recorder::merge(outcomes.iter().map(|o| o.recorder.clone()).collect());
    let summary = recorder.summarize(&cat);
    let batches = recorder.batches;
    let batched_jobs: u64 = outcomes.iter().map(|o| o.batched_jobs).sum();
    let mut stage_exec: HashMap<&'static str, (f64, u64)> = HashMap::new();
    for o in &outcomes {
        for (name, (sum, cnt)) in &o.stage_exec {
            let e = stage_exec.entry(name).or_insert((0.0, 0));
            e.0 += sum;
            e.1 += cnt;
        }
    }
    Ok(ServeReport {
        throughput_rps: summary.jobs as f64 / duration_s.max(1e-9),
        avg_batch: if batches == 0 {
            0.0
        } else {
            batched_jobs as f64 / batches as f64
        },
        batches,
        cold_compiles: outcomes.iter().map(|o| o.cold_compiles).sum(),
        stage_exec_ms: stage_exec
            .into_iter()
            .map(|(k, (sum, cnt))| (k, sum / cnt.max(1) as f64))
            .collect(),
        duration_s,
        summary,
        recorder,
        obs,
        interrupted: outcomes.iter().any(|o| o.interrupted),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    #[test]
    fn quick_params_sane() {
        let p = ServeParams::quick(10.0, 1.0);
        // default policy is Fifer — a batching RM
        assert_eq!(p.cfg.rm.policy, Policy::Fifer);
        assert!(p.cfg.rm.policy.build().batching());
        assert_eq!(p.chains.len(), 2);
        assert!(!p.synthetic, "PJRT is the default backend");
    }

    #[test]
    fn synthetic_serve_completes_jobs_without_artifacts() {
        // the real-time driver end to end: policy-spawned executor
        // threads, real cold-start sleeps, wall-clock monitor ticks —
        // no artifacts or PJRT anywhere. Bline spawns per arrival at
        // every stage, so even this 1 s horizon drains fully.
        let mut p = ServeParams::quick(20.0, 1.0);
        p.cfg.rm = crate::config::RmConfig::paper(Policy::Bline);
        p.synthetic = true;
        p.drain_s = 14.0;
        p.cfg.rm.monitor_interval_s = 1.0;
        p.cfg.rm.sample_window_s = 1.0;
        let r = serve(p).unwrap();
        assert!(r.summary.jobs > 0, "no jobs completed");
        assert!(r.summary.total_spawned > 0, "policy never spawned");
        assert!(r.batches > 0 && r.avg_batch >= 1.0);
        assert_eq!(r.cold_compiles, 0, "synthetic backend never compiles");
        assert_eq!(
            r.recorder.jobs.len() as u64,
            r.summary.jobs,
            "recorder/summary consistency"
        );
    }

    #[test]
    fn interrupt_flag_drains_and_still_reports() {
        // graceful shutdown: flip the interrupt flag mid-run (tests use a
        // leaked flag; `fifer serve` wires the same field to SIGINT) and
        // the run must cut off arrivals, drain, and still emit the full
        // report + final observability snapshot
        let flag: &'static AtomicBool = Box::leak(Box::new(AtomicBool::new(false)));
        let mut p = ServeParams::quick(20.0, 30.0); // nominally 30 s...
        p.cfg.rm = crate::config::RmConfig::paper(Policy::Bline);
        p.synthetic = true;
        p.drain_s = 10.0;
        p.cfg.rm.monitor_interval_s = 1.0;
        p.cfg.rm.sample_window_s = 1.0;
        p.interrupt = Some(flag);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(2)); // ...interrupted at ~2 s
            flag.store(true, Ordering::SeqCst);
        });
        let r = serve(p).unwrap();
        assert!(r.interrupted, "interrupt flag must be reported");
        assert!(
            r.duration_s < 25.0,
            "interrupted run must stop early, ran {} s",
            r.duration_s
        );
        let obs = r.obs.expect("live runs always collect telemetry");
        assert!(!obs.rows.is_empty(), "final snapshot has timeline rows");
        assert_eq!(obs.contract().len(), 4, "full SLO contract present");
    }

    // End-to-end PJRT serve() tests require artifacts and live in
    // rust/tests/test_server_live.rs.
}
