//! `fifer` — CLI for the Fifer serverless function-chain RM framework.
//!
//! Subcommands map onto the paper's evaluation (docs/DESIGN.md §4):
//!
//! ```text
//! fifer serve      live serving: real PJRT batched inference (needs artifacts)
//! fifer simulate   event-driven cluster simulation of one policy/mix/trace
//! fifer compare    run all five RMs and print the Fig. 8-style table
//! fifer scenario   run/list declarative TOML sweep matrices (parallel)
//! fifer predict    score the Fig. 6 predictor zoo on a trace
//! fifer coldstart  print the Fig. 2 cold/warm characterization
//! fifer stages     print the Fig. 3 per-stage breakdown
//! ```

use std::path::Path;

use anyhow::{anyhow, Result};
use fifer::bench::Table;
use fifer::cli::Args;
use fifer::config::{Policy, RmConfig};
use fifer::experiments::{self, TraceKind};
use fifer::scenario::{self, ScenarioSpec};
use fifer::server::{serve_sharded, ServeParams};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn trace_kind(name: &str) -> Result<TraceKind> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "poisson" => TraceKind::Poisson,
        "wiki" => TraceKind::Wiki,
        "wits" => TraceKind::Wits,
        other => anyhow::bail!("unknown trace {other:?} (poisson|wiki|wits)"),
    })
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(&args),
        "scenario" => cmd_scenario(&args),
        "predict" => cmd_predict(&args),
        "coldstart" => cmd_coldstart(&args),
        "stages" => cmd_stages(&args),
        _ => {
            let policy_help = format!(
                "scheduler policy ({}); default fifer",
                Policy::names().join("|")
            );
            print!(
                "{}",
                Args::render_help_with_options(
                    "fifer",
                    "stage-aware serverless function-chain resource manager \
                     (Fifer, Middleware'20 reproduction)",
                    &[
                        ("serve", "live serving with real PJRT batched inference"),
                        ("simulate", "event-driven cluster simulation (one policy)"),
                        ("compare", "every registered RM side by side (Fig. 8 style)"),
                        ("scenario", "run/list declarative TOML sweep matrices"),
                        ("predict", "score load predictors on a trace (Fig. 6)"),
                        ("coldstart", "cold/warm start characterization (Fig. 2)"),
                        ("stages", "per-stage execution breakdown (Fig. 3)"),
                    ],
                    &[
                        ("--policy <name>", policy_help.as_str()),
                        (
                            "--synthetic",
                            "serve: modeled executors (no artifacts/PJRT needed)",
                        ),
                        (
                            "--executors <n>",
                            "serve: max live containers (executor threads)",
                        ),
                        (
                            "--shards <n>",
                            "serve: split the coordinator into n chain-hash shards \
                             (docs/DESIGN.md §Sharding)",
                        ),
                        ("--drain <s>", "serve: drain window after the generator stops"),
                        ("--monitor <s>", "serve: monitor-tick interval override"),
                        ("--json <file>", "serve: write the metrics summary as JSON"),
                        (
                            "--metrics-addr <ip:port>",
                            "serve: expose live /metrics endpoints (docs/OBSERVABILITY.md)",
                        ),
                        (
                            "--trace-sample <1-in-N>",
                            "serve/scenario: per-request span sampling rate (default 1-in-1)",
                        ),
                        (
                            "--trace-out <file>",
                            "scenario run: export sampled span trees as Chrome trace JSON",
                        ),
                        (
                            "--optimality",
                            "simulate/scenario run: offline lower bounds + gap-to-bound \
                             (docs/EXPERIMENTS.md)",
                        ),
                    ],
                )
            );
            Ok(())
        }
    }
}

const SCENARIO_USAGE: &str = "usage:
  fifer scenario run <file|builtin> [--threads N] [--json out.json] [--csv out.csv]
                     [--slo-timeline out.json]
                     [--trace-out spans.json] [--trace-sample 1-in-N]
                     [--optimality]
  fifer scenario list              list built-in scenarios
  fifer scenario show <builtin>    print a built-in scenario file";

/// Parse `--trace-sample` as `N` or `1-in-N` (N >= 1).
fn parse_trace_sample(s: &str) -> Result<u64> {
    let n: u64 = s
        .strip_prefix("1-in-")
        .unwrap_or(s)
        .parse()
        .map_err(|_| anyhow!("--trace-sample wants N or 1-in-N, got {s:?}"))?;
    if n == 0 {
        return Err(anyhow!("--trace-sample must be at least 1"));
    }
    Ok(n)
}

fn cmd_scenario(args: &Args) -> Result<()> {
    match args.pos(0).unwrap_or("help") {
        "run" => {
            let target = args.pos(1).ok_or_else(|| {
                anyhow!("scenario run needs a file or built-in name\n{SCENARIO_USAGE}")
            })?;
            let spec = match scenario::builtin(target) {
                Some(text) => ScenarioSpec::parse(text)?,
                None => ScenarioSpec::load(Path::new(target))?,
            };
            let threads = args.usize_or("threads", 1)?;
            let cells = spec.cells();
            println!(
                "scenario {}: {} cells ({} traces x {} mixes x {} policies x {} seeds{}), \
                 {} thread(s)",
                spec.name,
                cells.len(),
                spec.traces.len(),
                spec.mixes.len(),
                spec.policies.len(),
                spec.seeds.len(),
                if spec.shard_counts != [1] {
                    format!(" x {} shard counts", spec.shard_counts.len())
                } else {
                    String::new()
                },
                threads.clamp(1, cells.len().max(1)),
            );
            // observability collection is opt-in: the plain sweep stays
            // collector-free; --slo-timeline and/or --trace-out turn the
            // collector on everywhere, with span recording only when a
            // trace export was requested (default: every request —
            // sweeps are short; thin with --trace-sample 1-in-N)
            let timeline_out = args.get("slo-timeline");
            let trace_out = args.get("trace-out");
            let trace_sample = match args.get("trace-sample") {
                Some(s) => parse_trace_sample(s)?,
                None => 1,
            };
            let obs = (timeline_out.is_some() || trace_out.is_some()).then(|| {
                fifer::obs::ObsConfig {
                    trace_sample: if trace_out.is_some() { trace_sample } else { 0 },
                    // exports want the full run, not a live ring
                    trace_keep: usize::MAX,
                    ..fifer::obs::ObsConfig::default()
                }
            });
            // offline lower-bound estimators (see docs/EXPERIMENTS.md
            // "Optimality gap"): per-cell invocation logs feed the
            // greedy/path-cover/segment trio; pure observers, so the
            // sweep stays byte-identical across --threads
            let optimality = args.flag("optimality");
            let results = scenario::run_scenario_full(&spec, threads, obs, optimality)?;
            // the shards column only appears when the sweep actually
            // varies it, keeping classic sweep output unchanged
            let sharded = spec.shard_counts != [1];
            let mut header = vec!["trace", "mix", "policy", "seed"];
            if sharded {
                header.push("shards");
            }
            header.extend([
                "jobs", "viol%", "median ms", "p99 ms", "avg cont", "cold", "energy Wh",
            ]);
            let mut t = Table::new(&header);
            for r in &results {
                let mut row = vec![
                    r.cell.trace.clone(),
                    r.cell.mix.clone(),
                    r.cell.policy.name().to_string(),
                    format!("{}", r.cell.seed),
                ];
                if sharded {
                    row.push(format!("{}", r.cell.shards));
                }
                row.extend([
                    format!("{}", r.summary.jobs),
                    format!("{:.2}", r.summary.slo_violation_pct),
                    format!("{:.0}", r.summary.median_ms),
                    format!("{:.0}", r.summary.p99_ms),
                    format!("{:.1}", r.summary.avg_containers),
                    format!("{}", r.summary.cold_starts),
                    format!("{:.1}", r.summary.energy_wh),
                ]);
                t.row(&row);
            }
            t.print();
            if optimality {
                let mut g = Table::new(&[
                    "trace", "mix", "policy", "seed", "bound cont-s", "achieved cont-s",
                    "gap%", "bound cold", "achieved cold", "cold gap%",
                ]);
                for r in &results {
                    if let Some(o) = &r.summary.optimality {
                        g.row(&[
                            r.cell.trace.clone(),
                            r.cell.mix.clone(),
                            r.cell.policy.name().to_string(),
                            format!("{}", r.cell.seed),
                            format!("{:.1}", o.bound_container_s),
                            format!("{:.1}", o.achieved_container_s),
                            format!("{:.1}", o.gap_container_pct),
                            format!("{}", o.bound_cold_starts),
                            format!("{}", o.achieved_cold_starts),
                            format!("{:.1}", o.gap_cold_start_pct),
                        ]);
                    }
                }
                println!("optimality gap (offline lower bounds, per objective):");
                g.print();
            }
            if let Some(p) = args.get("json") {
                std::fs::write(p, scenario::results_json(&spec, &results).to_string())?;
                println!("wrote {p}");
            }
            if let Some(p) = args.get("csv") {
                std::fs::write(p, scenario::results_csv(&results))?;
                println!("wrote {p}");
            }
            if let Some(p) = timeline_out {
                std::fs::write(p, scenario::results_obs_json(&spec, &results).to_string())?;
                println!("wrote {p}");
            }
            if let Some(p) = trace_out {
                std::fs::write(p, scenario::results_trace_json(&spec, &results).to_string())?;
                println!("wrote {p} (load in chrome://tracing or Perfetto)");
            }
            Ok(())
        }
        "list" => {
            for (name, _, about) in scenario::BUILTINS {
                println!("{name:<16} {about}");
            }
            Ok(())
        }
        "show" => {
            let name = args
                .pos(1)
                .ok_or_else(|| anyhow!("scenario show needs a built-in name\n{SCENARIO_USAGE}"))?;
            let text = scenario::builtin(name).ok_or_else(|| {
                anyhow!(
                    "no built-in scenario {name:?} (try: {})",
                    scenario::BUILTINS
                        .iter()
                        .map(|(n, _, _)| *n)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            print!("{text}");
            Ok(())
        }
        other => {
            if other != "help" {
                eprintln!("unknown scenario subcommand {other:?}");
            }
            println!("{SCENARIO_USAGE}");
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut p = ServeParams::quick(
        args.f64_or("rate", 20.0)?,
        args.f64_or("duration", 10.0)?,
    );
    p.executors = args.usize_or("executors", p.executors)?;
    p.drain_s = args.f64_or("drain", p.drain_s)?;
    p.synthetic = args.flag("synthetic");
    let shards = args.usize_or("shards", 1)?;
    // --no-batching is shorthand for the non-batching baseline policy;
    // combining it with an explicit batching --policy is contradictory
    let policy = match (args.get("policy"), args.flag("no-batching")) {
        (Some(name), false) => Policy::from_name(name)?,
        (None, no_batch) => Policy::from_name(if no_batch { "bline" } else { "fifer" })?,
        (Some(name), true) => {
            let policy = Policy::from_name(name)?;
            if policy.batching() {
                anyhow::bail!(
                    "--no-batching conflicts with --policy {} (a batching RM); \
                     pick a non-batching policy or drop the flag",
                    policy.name()
                );
            }
            policy
        }
    };
    p.cfg.rm = RmConfig::paper(policy);
    p.cfg.rm.monitor_interval_s = args.f64_or("monitor", p.cfg.rm.monitor_interval_s)?;
    p.cfg.artifacts_dir = args.str_or("artifacts", "artifacts");
    p.metrics_addr = args.get("metrics-addr").map(|s| s.to_string());
    // span recording is on by default live (sample 1-in-1 into a
    // bounded ring); --trace-sample 1-in-N thins it, 0 is rejected
    if let Some(s) = args.get("trace-sample") {
        p.trace_sample = parse_trace_sample(s)?;
    }
    // Ctrl-C drains in-flight jobs and still emits the final report
    // (a second Ctrl-C aborts immediately)
    p.interrupt = Some(fifer::server::sigint_flag());
    if let Some(addr) = &p.metrics_addr {
        println!(
            "metrics: http://{addr}/metrics (also /metrics/summary, \
             /metrics/history, /metrics/prom, /traces)"
        );
    }
    println!(
        "live serve: rate={} req/s, {}s (+{}s drain), policy={} (batching={}), \
         up to {} containers, {} backend{}",
        p.rate,
        p.duration_s,
        p.drain_s,
        policy.name(),
        policy.batching(),
        p.executors,
        if p.synthetic { "synthetic" } else { "PJRT" },
        if shards > 1 {
            format!(", {shards} coordinator shards")
        } else {
            String::new()
        }
    );
    let r = serve_sharded(p, shards)?;
    if r.interrupted {
        println!("interrupted: generator stopped early, in-flight jobs drained");
    }
    let s = &r.summary;
    println!(
        "jobs={} throughput={:.1} req/s median={:.1}ms p99={:.1}ms \
         slo-violations={:.2}% batches={} avg-batch={:.2} cold-compiles={}",
        s.jobs,
        r.throughput_rps,
        s.median_ms,
        s.p99_ms,
        s.slo_violation_pct,
        r.batches,
        r.avg_batch,
        r.cold_compiles
    );
    println!(
        "containers: spawned={} avg-live={:.1} cold-starts={} reclaimed={} energy={:.1}Wh",
        s.total_spawned,
        s.avg_containers,
        s.cold_starts,
        r.recorder.reclaimed,
        s.energy_wh
    );
    let mut t = Table::new(&["stage", "mean batch exec (ms)"]);
    let mut rows: Vec<_> = r.stage_exec_ms.iter().collect();
    rows.sort_by_key(|(name, _)| **name);
    for (name, ms) in rows {
        t.row(&[name.to_string(), format!("{ms:.2}")]);
    }
    t.print();
    if let Some(path) = args.get("json") {
        std::fs::write(path, s.to_json().to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let policy = Policy::from_name(&args.str_or("policy", "fifer"))?;
    let kind = trace_kind(&args.str_or("trace", "poisson"))?;
    let mix = args.str_or("mix", "Heavy");
    let duration = args.usize_or("duration", 900)?;
    let prototype = !args.flag("large");
    let seed = args.u64_or("seed", 42)?;
    let optimality = args.flag("optimality");
    let run = if optimality {
        experiments::run_policy_opt(policy, &mix, kind, duration, prototype, seed)
    } else {
        experiments::run_policy(policy, &mix, kind, duration, prototype, seed)
    };
    let s = &run.summary;
    println!(
        "{} on {}/{} ({}s, {} cluster):",
        policy.name(),
        kind.name(),
        mix,
        duration,
        if prototype { "prototype" } else { "2500-core" }
    );
    println!(
        "  jobs={} slo-violations={:.2}% median={:.0}ms p95={:.0}ms p99={:.0}ms",
        s.jobs, s.slo_violation_pct, s.median_ms, s.p95_ms, s.p99_ms
    );
    println!(
        "  avg-containers={:.1} spawned={} cold-starts={} energy={:.1}Wh",
        s.avg_containers, s.total_spawned, s.cold_starts, s.energy_wh
    );
    if let Some(o) = &s.optimality {
        println!(
            "  optimality: container-s bound={:.1} achieved={:.1} gap={:.1}%, \
             cold-starts bound={} achieved={} gap={:.1}%",
            o.bound_container_s,
            o.achieved_container_s,
            o.gap_container_pct,
            o.bound_cold_starts,
            o.achieved_cold_starts,
            o.gap_cold_start_pct,
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let kind = trace_kind(&args.str_or("trace", "poisson"))?;
    let mix = args.str_or("mix", "Heavy");
    let duration = args.usize_or("duration", 900)?;
    let prototype = !args.flag("large");
    let seed = args.u64_or("seed", 42)?;
    let runs: Vec<_> = Policy::ALL
        .iter()
        .map(|&p| experiments::run_policy(p, &mix, kind, duration, prototype, seed))
        .collect();
    let base = runs[0].summary.clone(); // Bline
    let mut t = Table::new(&[
        "policy", "viol%", "avg cont", "cont/Bline", "median ms", "p99 ms", "cold", "energy Wh",
    ]);
    for r in &runs {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.2}", r.summary.slo_violation_pct),
            format!("{:.1}", r.summary.avg_containers),
            fifer::bench::norm(r.summary.avg_containers, base.avg_containers),
            format!("{:.0}", r.summary.median_ms),
            format!("{:.0}", r.summary.p99_ms),
            format!("{}", r.summary.cold_starts),
            format!("{:.1}", r.summary.energy_wh),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let art = args.str_or("artifacts", "artifacts");
    let results = experiments::fig6_predictors(&art, 0.15);
    let mut t = Table::new(&["model", "RMSE (req/s)", "latency (µs)", "accuracy %"]);
    for r in &results {
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.rmse),
            format!("{:.1}", r.latency_us),
            format!("{:.1}", r.accuracy_pct),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_coldstart(args: &Args) -> Result<()> {
    let samples = args.usize_or("samples", 100)?;
    let rows = experiments::fig2_coldstart(samples, 1);
    let mut t = Table::new(&[
        "model", "exec ms", "spawn ms", "pull ms", "init ms", "cold total", "warm total",
    ]);
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.exec_ms),
            format!("{:.0}", r.spawn_ms),
            format!("{:.0}", r.pull_ms),
            format!("{:.0}", r.init_ms),
            format!("{:.0}", r.cold_total_ms),
            format!("{:.1}", r.warm_total_ms),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_stages(_args: &Args) -> Result<()> {
    for b in experiments::fig3a_breakdown() {
        println!("{}:", b.chain);
        for (name, exec, pct) in &b.stages {
            println!("  {name:<6} {exec:>7.2} ms  {pct:>5.1}%");
        }
    }
    println!("\nexecution-time variation (100 runs):");
    for (name, mean, std) in experiments::fig3b_variation(100, 7) {
        println!("  {name:<6} mean {mean:>7.2} ms  std {std:>5.2} ms");
    }
    Ok(())
}
