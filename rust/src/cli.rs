//! Command-line argument parser (clap substitute).
//!
//! Supports `fifer <subcommand> [--flag] [--key value] [--key=value]`
//! with typed accessors, defaults, and generated help text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the binary name). The first non-flag token
    /// is the subcommand; everything after is options/positionals.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.command = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    a.values.insert(k.to_string(), v[1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap().clone();
                    a.values.insert(stripped.to_string(), v);
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options are not supported: {tok}");
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    /// The i-th positional argument (after the subcommand), if present.
    /// Nested subcommands read their verb from `pos(0)` — e.g. in
    /// `fifer scenario run sweep.toml`, `command` is `"scenario"`,
    /// `pos(0)` is `"run"` and `pos(1)` is `"sweep.toml"`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number, got {v:?}: {e}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got {v:?}: {e}")),
        }
    }

    /// Render help for a set of subcommands.
    pub fn render_help(binary: &str, about: &str, commands: &[(&str, &str)]) -> String {
        Args::render_help_with_options(binary, about, commands, &[])
    }

    /// Render help with an additional OPTIONS section (e.g. choices
    /// derived from a registry at runtime).
    pub fn render_help_with_options(
        binary: &str,
        about: &str,
        commands: &[(&str, &str)],
        options: &[(&str, &str)],
    ) -> String {
        let mut s = format!(
            "{binary} — {about}\n\nUSAGE:\n  {binary} <command> [options]\n\nCOMMANDS:\n"
        );
        let w = commands.iter().map(|(c, _)| c.len()).max().unwrap_or(0);
        for (c, h) in commands {
            s.push_str(&format!("  {c:<w$}  {h}\n"));
        }
        if !options.is_empty() {
            s.push_str("\nOPTIONS:\n");
            let w = options.iter().map(|(o, _)| o.len()).max().unwrap_or(0);
            for (o, h) in options {
                s.push_str(&format!("  {o:<w$}  {h}\n"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_kv() {
        let a = Args::parse(&argv("simulate --trace wits --duration 600")).unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("trace"), Some("wits"));
        assert_eq!(a.u64_or("duration", 0).unwrap(), 600);
    }

    #[test]
    fn parses_eq_form_and_flags() {
        let a = Args::parse(&argv("bench --policy=fifer --verbose")).unwrap();
        assert_eq!(a.get("policy"), Some("fifer"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positionals_and_defaults() {
        let a = Args::parse(&argv("run file1 file2 --n 3")).unwrap();
        assert_eq!(a.positional, vec!["file1", "file2"]);
        assert_eq!(a.f64_or("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
        assert_eq!(a.pos(0), Some("file1"));
        assert_eq!(a.pos(1), Some("file2"));
        assert_eq!(a.pos(2), None);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv("x --n abc")).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn negative_number_value() {
        let a = Args::parse(&argv("x --offset -5")).unwrap();
        assert_eq!(a.get("offset"), Some("-5"));
    }

    #[test]
    fn help_rendering() {
        let h = Args::render_help("fifer", "about", &[("serve", "run"), ("sim", "simulate")]);
        assert!(h.contains("serve") && h.contains("simulate"));
        assert!(!h.contains("OPTIONS"));
    }

    #[test]
    fn help_rendering_with_options() {
        let h = Args::render_help_with_options(
            "fifer",
            "about",
            &[("serve", "run")],
            &[("--policy <name>", "Bline|Fifer")],
        );
        assert!(h.contains("OPTIONS"));
        assert!(h.contains("--policy <name>") && h.contains("Bline|Fifer"));
    }
}
