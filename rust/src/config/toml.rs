//! Minimal TOML-subset parser for the config system.
//!
//! Supports what fifer config files need: `[section]` headers,
//! `key = value` with string / integer / float / bool / flat-array values,
//! `#` comments and blank lines. Nested tables and multi-line values are
//! intentionally out of scope.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// section -> key -> value
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: unterminated section header", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string: {s}");
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array: {s}");
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items = split_top_level(inner)?;
        return Ok(TomlValue::Arr(
            items
                .iter()
                .map(|i| parse_value(i.trim()))
                .collect::<Result<Vec<_>>>()?,
        ));
    }
    s.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| anyhow!("cannot parse value: {s}"))
}

fn split_top_level(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or_else(|| anyhow!("unbalanced ]"))?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# cluster shape
[cluster]
nodes = 5
cores_per_node = 16
name = "prototype"   # inline comment
off_when_idle = true
batches = [1, 2, 4]
"#,
        )
        .unwrap();
        let c = &doc["cluster"];
        assert_eq!(c["nodes"].as_usize().unwrap(), 5);
        assert_eq!(c["name"].as_str().unwrap(), "prototype");
        assert!(c["off_when_idle"].as_bool().unwrap());
        assert_eq!(
            c["batches"],
            TomlValue::Arr(vec![
                TomlValue::Num(1.0),
                TomlValue::Num(2.0),
                TomlValue::Num(4.0)
            ])
        );
    }

    #[test]
    fn root_section_keys() {
        let doc = parse("x = 1.5\n[s]\ny = 2").unwrap();
        assert_eq!(doc[""]["x"].as_f64().unwrap(), 1.5);
        assert_eq!(doc["s"]["y"].as_f64().unwrap(), 2.0);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc[""]["k"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors() {
        assert!(parse("[oops").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = \"x").is_err());
    }

    #[test]
    fn type_mismatch_errors() {
        let doc = parse("k = 5").unwrap();
        assert!(doc[""]["k"].as_str().is_err());
        assert!(doc[""]["k"].as_bool().is_err());
    }
}
