//! Configuration system: cluster shape, RM policy knobs, file loading.
//!
//! Defaults reproduce the paper's two testbeds:
//! * [`ClusterConfig::prototype`] — the 80-compute-core Kubernetes cluster
//!   (5 × dual-socket 16-core nodes per Table 1; one head node).
//! * [`ClusterConfig::simulation`] — the 2500-core simulated cluster
//!   (30× the prototype, §5.3).
//!
//! Config files use a TOML subset (see [`toml`]); every knob can also be
//! overridden programmatically — examples/ show both styles.

pub mod toml;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::policy::SchedulerPolicy;

use self::toml::{TomlDoc, TomlValue};

/// One parsed `[section]` of a TOML-subset document.
pub type TomlSection = BTreeMap<String, TomlValue>;

/// Cluster shape + power model parameters.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub cores_per_node: usize,
    /// CPU share per container (paper: 0.5 core).
    pub cpu_per_container: f64,
    /// Node idle power draw in watts (sockets powered, no work).
    pub idle_watts: f64,
    /// Node peak power draw in watts (all cores busy).
    pub peak_watts: f64,
    /// Seconds of complete inactivity after which a node powers off.
    pub node_off_after_s: f64,
}

impl ClusterConfig {
    /// The paper's real-system testbed: 80 compute cores.
    /// Xeon Gold 6242 (2 sockets × 16 cores): idle ~110 W, peak ~300 W.
    pub fn prototype() -> ClusterConfig {
        ClusterConfig {
            nodes: 5,
            cores_per_node: 16,
            cpu_per_container: 0.5,
            idle_watts: 110.0,
            peak_watts: 300.0,
            node_off_after_s: 60.0,
        }
    }

    /// The paper's large-scale simulation: ~2500 cores (30× prototype).
    pub fn simulation() -> ClusterConfig {
        ClusterConfig {
            nodes: 78,
            cores_per_node: 32,
            cpu_per_container: 0.5,
            idle_watts: 180.0,
            peak_watts: 520.0,
            node_off_after_s: 60.0,
        }
    }

    /// Look up a named preset: `"prototype"` or `"simulation"` (the two
    /// testbeds of the paper). Config and scenario files both use this.
    pub fn preset(name: &str) -> Result<ClusterConfig> {
        match name {
            "prototype" => Ok(ClusterConfig::prototype()),
            "simulation" => Ok(ClusterConfig::simulation()),
            other => anyhow::bail!("unknown cluster preset {other:?} (prototype|simulation)"),
        }
    }

    /// Every `[cluster]` key [`ClusterConfig::apply_doc`] understands
    /// (plus `preset`, resolved by the caller). Strict parsers (the
    /// scenario layer) reject keys outside this list.
    pub const DOC_KEYS: [&'static str; 7] = [
        "preset",
        "nodes",
        "cores_per_node",
        "cpu_per_container",
        "idle_watts",
        "peak_watts",
        "node_off_after_s",
    ];

    /// Apply `[cluster]` overrides from a parsed config/scenario file on
    /// top of the current values. The `preset` key is resolved by the
    /// caller (it replaces the whole struct); every other key overrides
    /// one field. Unknown keys are ignored here (config files stay
    /// forward-compatible) — strict consumers check [`ClusterConfig::DOC_KEYS`].
    pub fn apply_doc(&mut self, sec: &TomlSection) -> Result<()> {
        let g = |k: &str, d: f64| -> Result<f64> {
            sec.get(k).map(|v| v.as_f64()).unwrap_or(Ok(d))
        };
        self.nodes = g("nodes", self.nodes as f64)? as usize;
        self.cores_per_node = g("cores_per_node", self.cores_per_node as f64)? as usize;
        self.cpu_per_container = g("cpu_per_container", self.cpu_per_container)?;
        self.idle_watts = g("idle_watts", self.idle_watts)?;
        self.peak_watts = g("peak_watts", self.peak_watts)?;
        self.node_off_after_s = g("node_off_after_s", self.node_off_after_s)?;
        Ok(())
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    /// Max containers one node can host.
    pub fn containers_per_node(&self) -> usize {
        (self.cores_per_node as f64 / self.cpu_per_container).floor() as usize
    }

    pub fn max_containers(&self) -> usize {
        self.nodes * self.containers_per_node()
    }
}

/// Name of a registered RM framework (paper §5.3 "Metrics and RM
/// Policies", plus post-paper additions). This enum is a thin facade
/// over the scheduler-policy registry
/// ([`crate::coordinator::policy::build`]): [`Policy::build`] resolves a
/// name to its [`SchedulerPolicy`] implementation, and every capability
/// query below delegates to the trait object — the engines never branch
/// on this enum. `RScale` is Fifer minus prediction (GrandSLAm-like);
/// `BPred` is Bline plus LSF plus EWMA prediction (Archipelago-like);
/// `Kn` is a Knative-style concurrency autoscaler; `FiferEq` is Fifer
/// ablated to equal-division slack + FIFO ordering (§6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    Bline,
    SBatch,
    RScale,
    BPred,
    Fifer,
    Kn,
    FiferEq,
}

impl Policy {
    /// Every registered policy. The paper's five RMs come first (several
    /// drivers index or slice the head of this list).
    pub const ALL: [Policy; 7] = [
        Policy::Bline,
        Policy::SBatch,
        Policy::RScale,
        Policy::BPred,
        Policy::Fifer,
        Policy::Kn,
        Policy::FiferEq,
    ];

    /// The five RM frameworks evaluated by the paper (§5.3).
    pub const PAPER: [Policy; 5] = [
        Policy::Bline,
        Policy::SBatch,
        Policy::RScale,
        Policy::BPred,
        Policy::Fifer,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Bline => "Bline",
            Policy::SBatch => "SBatch",
            Policy::RScale => "RScale",
            Policy::BPred => "BPred",
            Policy::Fifer => "Fifer",
            Policy::Kn => "Kn",
            Policy::FiferEq => "FiferEq",
        }
    }

    /// All registered policy names, registry order (for CLI help/errors).
    pub fn names() -> Vec<&'static str> {
        Policy::ALL.iter().map(|p| p.name()).collect()
    }

    pub fn from_name(s: &str) -> Result<Policy> {
        Policy::ALL
            .iter()
            .copied()
            .find(|p| p.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| {
                anyhow!(
                    "unknown policy {s:?} (registered: {})",
                    Policy::names().join("/")
                )
            })
    }

    /// Resolve this name to its scheduler-policy implementation.
    pub fn build(&self) -> Box<dyn SchedulerPolicy> {
        crate::coordinator::policy::build(*self)
    }

    /// Does this RM batch requests (local queues > 1)?
    pub fn batching(&self) -> bool {
        self.build().batching()
    }

    /// Does this RM scale proactively from a load forecast?
    pub fn proactive(&self) -> bool {
        self.build().proactive()
    }

    /// Does this RM use LSF (least-slack-first) queue ordering?
    pub fn lsf(&self) -> bool {
        self.build().queue_order() == crate::coordinator::queue::Ordering::LeastSlackFirst
    }
}

/// Slack distribution across stages (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlackPolicy {
    /// Proportional to stage execution time (Fifer's default).
    Proportional,
    /// Equal division across stages (used by SBatch).
    EqualDivision,
}

/// RM framework knobs (paper §4 and §5).
#[derive(Debug, Clone)]
pub struct RmConfig {
    pub policy: Policy,
    pub slack_policy: SlackPolicy,
    /// Monitoring interval T (paper: 10 s).
    pub monitor_interval_s: f64,
    /// Arrival-rate sampling window W_s (paper: 5 s).
    pub sample_window_s: f64,
    /// History length fed to predictors (paper: last 100 s).
    pub history_s: f64,
    /// Idle-container reclamation timeout (paper: 10 min).
    pub idle_timeout_s: f64,
    /// Cap on per-stage batch size (compiled artifact sizes cap at 32).
    pub max_batch: usize,
    /// Safety factor on SBatch's fixed pool sizing.
    pub sbatch_headroom: f64,
    /// Smoothing factor for the EWMA predictor used by BPred.
    pub ewma_alpha: f64,
    /// Cluster-capacity guard: max fraction of total container slots one
    /// stage may hold. Prevents a transient stage-0 backlog from starving
    /// downstream stages of the chain (engineering detail on top of the
    /// paper's Algorithm 1, which assumes an uncapped cluster).
    pub max_stage_fraction: f64,
    /// Marginal cost of adding one request to an inference batch:
    /// exec(B) = exec(1) · (1 + γ·(B−1)). γ=1 is serial execution; the
    /// default 0.25 matches batched-matmul amortization measured on the
    /// real PJRT artifacts (see docs/EXPERIMENTS.md §Perf calibration).
    pub batch_cost_gamma: f64,
}

impl RmConfig {
    pub fn paper(policy: Policy) -> RmConfig {
        RmConfig {
            policy,
            // each policy declares its preferred slack distribution
            // (SBatch and FiferEq: equal division); still overridable
            // via [rm] slack_policy in a config file.
            slack_policy: policy
                .build()
                .slack_policy()
                .unwrap_or(SlackPolicy::Proportional),
            monitor_interval_s: 10.0,
            sample_window_s: 5.0,
            history_s: 100.0,
            idle_timeout_s: 600.0,
            max_batch: 32,
            sbatch_headroom: 2.0,
            ewma_alpha: 0.5,
            max_stage_fraction: 0.5,
            batch_cost_gamma: 0.25,
        }
    }

    /// Every `[rm]` key [`RmConfig::apply_doc`] understands. Strict
    /// parsers (the scenario layer) reject keys outside this list.
    pub const DOC_KEYS: [&'static str; 10] = [
        "monitor_interval_s",
        "sample_window_s",
        "history_s",
        "idle_timeout_s",
        "max_batch",
        "sbatch_headroom",
        "ewma_alpha",
        "max_stage_fraction",
        "batch_cost_gamma",
        "slack_policy",
    ];

    /// Apply `[rm]` overrides from a parsed config/scenario file. The
    /// policy itself is not an `[rm]` key (config files name it at the
    /// root; scenario files sweep a whole policy list instead). Unknown
    /// keys are ignored here — strict consumers check [`RmConfig::DOC_KEYS`].
    pub fn apply_doc(&mut self, sec: &TomlSection) -> Result<()> {
        let g = |k: &str, d: f64| -> Result<f64> {
            sec.get(k).map(|v| v.as_f64()).unwrap_or(Ok(d))
        };
        self.monitor_interval_s = g("monitor_interval_s", self.monitor_interval_s)?;
        self.sample_window_s = g("sample_window_s", self.sample_window_s)?;
        self.history_s = g("history_s", self.history_s)?;
        self.idle_timeout_s = g("idle_timeout_s", self.idle_timeout_s)?;
        self.max_batch = g("max_batch", self.max_batch as f64)? as usize;
        self.sbatch_headroom = g("sbatch_headroom", self.sbatch_headroom)?;
        self.ewma_alpha = g("ewma_alpha", self.ewma_alpha)?;
        self.max_stage_fraction = g("max_stage_fraction", self.max_stage_fraction)?;
        self.batch_cost_gamma = g("batch_cost_gamma", self.batch_cost_gamma)?;
        if let Some(v) = sec.get("slack_policy") {
            self.slack_policy = match v.as_str()? {
                "proportional" => SlackPolicy::Proportional,
                "equal" => SlackPolicy::EqualDivision,
                other => anyhow::bail!("unknown slack_policy {other:?}"),
            };
        }
        Ok(())
    }
}

/// Top-level config: cluster + RM + experiment knobs.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub cluster: ClusterConfig,
    pub rm: RmConfig,
    /// Directory with AOT artifacts (manifest.json etc).
    pub artifacts_dir: String,
    pub seed: u64,
}

impl SystemConfig {
    pub fn prototype(policy: Policy) -> SystemConfig {
        SystemConfig {
            cluster: ClusterConfig::prototype(),
            rm: RmConfig::paper(policy),
            artifacts_dir: "artifacts".to_string(),
            seed: 42,
        }
    }

    pub fn simulation(policy: Policy) -> SystemConfig {
        SystemConfig {
            cluster: ClusterConfig::simulation(),
            rm: RmConfig::paper(policy),
            artifacts_dir: "artifacts".to_string(),
            seed: 42,
        }
    }

    /// Load overrides from a TOML-subset file on top of paper defaults.
    pub fn from_file(path: &Path) -> Result<SystemConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = toml::parse(&text)?;
        SystemConfig::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<SystemConfig> {
        let root = doc.get("").cloned().unwrap_or_default();
        let policy = match root.get("policy") {
            Some(v) => Policy::from_name(v.as_str()?)?,
            None => Policy::Fifer,
        };
        let mut cfg = SystemConfig::prototype(policy);
        if let Some(v) = root.get("seed") {
            cfg.seed = v.as_f64()? as u64;
        }
        if let Some(v) = root.get("artifacts_dir") {
            cfg.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(c) = doc.get("cluster") {
            if let Some(v) = c.get("preset") {
                cfg.cluster = ClusterConfig::preset(v.as_str()?)?;
            }
            cfg.cluster.apply_doc(c)?;
        }
        if let Some(r) = doc.get("rm") {
            cfg.rm.apply_doc(r)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototype_matches_paper() {
        let c = ClusterConfig::prototype();
        assert_eq!(c.total_cores(), 80);
        assert_eq!(c.containers_per_node(), 32);
    }

    #[test]
    fn simulation_scale() {
        let c = ClusterConfig::simulation();
        assert!((2400..=2600).contains(&c.total_cores()), "{}", c.total_cores());
        // ~30x the prototype, per §5.3
        assert!(c.total_cores() >= 30 * 80);
    }

    #[test]
    fn policy_traits() {
        assert!(!Policy::Bline.batching() && !Policy::Bline.proactive());
        assert!(Policy::SBatch.batching() && !Policy::SBatch.proactive());
        assert!(Policy::RScale.batching() && !Policy::RScale.proactive());
        assert!(!Policy::BPred.batching() && Policy::BPred.proactive());
        assert!(Policy::Fifer.batching() && Policy::Fifer.proactive());
        assert!(Policy::Fifer.lsf() && !Policy::Bline.lsf());
        // post-paper registrations
        assert!(Policy::Kn.batching() && !Policy::Kn.proactive() && !Policy::Kn.lsf());
        assert!(Policy::FiferEq.batching() && Policy::FiferEq.proactive());
        assert!(!Policy::FiferEq.lsf(), "FiferEq ablates LSF to FIFO");
    }

    #[test]
    fn policy_from_name() {
        assert_eq!(Policy::from_name("fifer").unwrap(), Policy::Fifer);
        assert_eq!(Policy::from_name("BLINE").unwrap(), Policy::Bline);
        assert_eq!(Policy::from_name("kn").unwrap(), Policy::Kn);
        assert_eq!(Policy::from_name("fifereq").unwrap(), Policy::FiferEq);
        let err = Policy::from_name("nope").unwrap_err().to_string();
        // error message derives from the registry, not a hardcoded list
        for name in Policy::names() {
            assert!(err.contains(name), "{err} missing {name}");
        }
    }

    #[test]
    fn paper_policies_head_the_registry() {
        assert_eq!(&Policy::ALL[..5], &Policy::PAPER[..]);
    }

    #[test]
    fn sbatch_defaults_to_equal_division() {
        assert_eq!(
            RmConfig::paper(Policy::SBatch).slack_policy,
            SlackPolicy::EqualDivision
        );
        assert_eq!(
            RmConfig::paper(Policy::Fifer).slack_policy,
            SlackPolicy::Proportional
        );
        // the ablated Fifer declares equal division through the trait
        assert_eq!(
            RmConfig::paper(Policy::FiferEq).slack_policy,
            SlackPolicy::EqualDivision
        );
    }

    #[test]
    fn from_doc_overrides() {
        let doc = toml::parse(
            r#"
policy = "rscale"
seed = 7
[cluster]
preset = "simulation"
nodes = 10
[rm]
idle_timeout_s = 30
slack_policy = "equal"
"#,
        )
        .unwrap();
        let cfg = SystemConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.rm.policy, Policy::RScale);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.cluster.nodes, 10);
        assert_eq!(cfg.cluster.cores_per_node, 32); // from simulation preset
        assert_eq!(cfg.rm.idle_timeout_s, 30.0);
        assert_eq!(cfg.rm.slack_policy, SlackPolicy::EqualDivision);
    }

    #[test]
    fn apply_doc_covers_engine_knobs() {
        let doc = toml::parse("[rm]\nbatch_cost_gamma = 0.5\nmax_stage_fraction = 0.25").unwrap();
        let mut rm = RmConfig::paper(Policy::Fifer);
        rm.apply_doc(&doc["rm"]).unwrap();
        assert_eq!(rm.batch_cost_gamma, 0.5);
        assert_eq!(rm.max_stage_fraction, 0.25);
        assert!(ClusterConfig::preset("nope").is_err());
        assert_eq!(ClusterConfig::preset("simulation").unwrap().nodes, 78);
    }

    #[test]
    fn from_doc_rejects_bad_values() {
        let doc = toml::parse("policy = \"zzz\"").unwrap();
        assert!(SystemConfig::from_doc(&doc).is_err());
        let doc = toml::parse("[cluster]\npreset = \"zzz\"").unwrap();
        assert!(SystemConfig::from_doc(&doc).is_err());
    }
}
