//! Statistics helpers: percentiles, moments, regression, RMSE, CDFs.
//!
//! Every number the paper reports (P99 tails, medians, SLO-violation
//! percentages, RMSE of predictors, CDFs) flows through here.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice. p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Root-mean-squared error between two equal-length series.
pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(actual)
        .map(|(p, a)| (p - a) * (p - a))
        .sum();
    (s / pred.len() as f64).sqrt()
}

/// Empirical CDF points (x, fraction <= x) at `points` evenly spaced
/// quantiles — what Fig. 10a plots.
pub fn cdf_points(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return Vec::new();
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (1..=points)
        .map(|i| {
            let q = i as f64 / points as f64;
            (percentile_sorted(&v, q * 100.0), q)
        })
        .collect()
}

/// Histogram with equal-width bins over [lo, hi]; returns bin counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    let mut counts = vec![0u64; bins];
    if hi <= lo || bins == 0 {
        return counts;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || !x.is_finite() {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
}

/// Ordinary least squares y = a + b*x. Returns (intercept, slope).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx.abs() < 1e-12 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Streaming mean/variance (Welford). Used by load monitors.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118033988).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        assert_eq!(percentile(&[42.0], 75.0), 42.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn rmse_known() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let cdf = cdf_points(&xs, 10);
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(cdf.last().unwrap().0, 5.0);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.5, 0.9, 1.5, 2.5, 99.0];
        let h = histogram(&xs, 0.0, 3.0, 3);
        assert_eq!(h, vec![3, 1, 2]); // 99.0 clamps to the last bin
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        let (a0, b0) = linear_fit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!((a0, b0), (6.0, 0.0)); // degenerate x
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }
}
