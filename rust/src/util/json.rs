//! Minimal JSON parser/writer (serde_json substitute, no crates.io access).
//!
//! Parses the AOT manifest, predictor weights and trace files emitted by
//! `python/compile/aot.py`, and serializes experiment results. Supports the
//! full JSON grammar except exotic number forms; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Flatten a (possibly nested) numeric array in row-major order —
    /// how predictor_weights.json stores matrices.
    pub fn as_f32_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        fn rec(v: &Json, out: &mut Vec<f32>) -> Result<()> {
            match v {
                Json::Num(n) => out.push(*n as f32),
                Json::Arr(a) => {
                    for x in a {
                        rec(x, out)?;
                    }
                }
                _ => bail!("non-numeric element in tensor"),
            }
            Ok(())
        }
        rec(self, &mut out)?;
        Ok(out)
    }

    // -- writer ------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // note: surrogate pairs unsupported (not emitted
                            // by our python tooling)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // multi-byte utf-8: back up and take the full char
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = s
            .parse()
            .map_err(|e| anyhow!("bad number {s:?} at byte {start}: {e}"))?;
        Ok(Json::Num(n))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(j, Json::Str("café ✓".into()));
        let round = j.to_string();
        assert_eq!(Json::parse(&round).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn flat_tensor() {
        let j = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(j.as_f32_flat().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse("{\"a\":1}").unwrap();
        assert!(j.get("b").is_err());
        assert!(j.get("a").unwrap().as_str().is_err());
        assert_eq!(j.get("a").unwrap().as_usize().unwrap(), 1);
    }
}
