//! Deterministic PRNG + distributions (crates.io `rand` substitute).
//!
//! The simulator, trace generators and property tests all need seedable,
//! reproducible randomness. This is a PCG-XSH-RR 64/32 generator (O'Neill
//! 2014) plus the handful of distributions the paper's workloads need:
//! uniform, normal (Box–Muller), lognormal, exponential and Poisson.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with random rotation.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
    /// cached second normal sample from Box–Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
            spare_normal: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg::with_stream(seed ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for the n we use (< 2^32)
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with the given mean (= 1/rate).
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Poisson-distributed count. Knuth for small lambda, normal
    /// approximation (rounded, clamped at 0) for large lambda.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_ms(lambda, lambda.sqrt());
            z.round().max(0.0) as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg::new(3);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg::new(13);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential(3.0)).sum();
        assert!((s / n as f64 - 3.0).abs() < 0.05);
    }

    #[test]
    fn poisson_small_and_large_lambda() {
        let mut r = Pcg::new(17);
        for &lambda in &[0.5, 5.0, 50.0, 500.0] {
            let n = 20_000;
            let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = s as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-1.0), 0);
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(19);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xs, ys);
    }
}
