//! Substrate utilities built from scratch for this repo: PRNG,
//! statistics, JSON codec, time units, and a mini property-test harness.

pub mod json;
pub mod rng;
pub mod stats;

/// Simulation / serving time in microseconds. All latencies in the paper
/// are milliseconds; µs resolution keeps sub-ms scheduling overheads exact.
pub type Micros = u64;

pub const MICROS_PER_MS: u64 = 1_000;
pub const MICROS_PER_S: u64 = 1_000_000;

#[inline]
pub fn ms(v: f64) -> Micros {
    (v * MICROS_PER_MS as f64).round().max(0.0) as Micros
}

#[inline]
pub fn secs(v: f64) -> Micros {
    (v * MICROS_PER_S as f64).round().max(0.0) as Micros
}

#[inline]
pub fn to_ms(t: Micros) -> f64 {
    t as f64 / MICROS_PER_MS as f64
}

#[inline]
pub fn to_secs(t: Micros) -> f64 {
    t as f64 / MICROS_PER_S as f64
}

/// Mini property-test harness (proptest substitute).
///
/// Runs `cases` random trials; on failure reports the seed so the case can
/// be replayed deterministically with [`prop::replay`].
pub mod prop {
    use super::rng::Pcg;

    pub type PropResult = Result<(), String>;

    pub fn assert_prop(cond: bool, msg: &str) -> PropResult {
        if cond {
            Ok(())
        } else {
            Err(msg.to_string())
        }
    }

    /// Run `f` against `cases` independently-seeded generators; panic with
    /// the failing seed on the first violation.
    pub fn check<F>(name: &str, cases: u64, mut f: F)
    where
        F: FnMut(&mut Pcg) -> PropResult,
    {
        for case in 0..cases {
            let seed = 0x5eed_0000 + case;
            let mut rng = Pcg::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property {name:?} failed on seed {seed:#x}: {msg}");
            }
        }
    }

    /// Replay a single failing seed (for debugging).
    pub fn replay<F>(seed: u64, mut f: F)
    where
        F: FnMut(&mut Pcg) -> PropResult,
    {
        let mut rng = Pcg::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("replay of seed {seed:#x} failed: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions() {
        assert_eq!(ms(1.5), 1_500);
        assert_eq!(secs(2.0), 2_000_000);
        assert_eq!(to_ms(2_500), 2.5);
        assert_eq!(to_secs(500_000), 0.5);
        assert_eq!(ms(-1.0), 0);
    }

    #[test]
    fn prop_harness_passes() {
        prop::check("uniform_in_range", 50, |rng| {
            let x = rng.range(3.0, 5.0);
            prop::assert_prop((3.0..5.0).contains(&x), "range bound")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn prop_harness_reports_failure() {
        prop::check("always_fails", 5, |_| prop::assert_prop(false, "nope"));
    }
}
