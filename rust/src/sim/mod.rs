//! High-fidelity event-driven cluster simulator (paper §5.2).
//!
//! Simulates the full serverless stack — request arrival, per-stage global
//! queues, container local queues, cold starts (spawn + image pull +
//! runtime init), serial in-container execution, greedy placement, idle
//! scale-in, node power — at microsecond resolution, driven by the same
//! coordinator primitives as the live server. The paper validated its
//! simulator against the real prototype; we do the same in
//! `rust/tests/test_server_live.rs` (graceful no-op without artifacts).
//!
//! All *policy* decisions (spawning, scaling, reclamation, queue
//! ordering) are delegated to a [`SchedulerPolicy`] trait object — the
//! engine owns mechanics only and contains no per-policy branches. Use
//! [`run_sim`] for a registered policy, [`run_sim_with`] /
//! [`Engine::with_policy`] to inject your own implementation.
//!
//! Events are processed from a binary heap ordered by (time, seq); all
//! randomness flows from one seeded PCG, so runs are exactly reproducible.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::coldstart::ColdStartModel;
use crate::config::SystemConfig;
use crate::coordinator::policy::{PolicyView, ScalingPlan, SchedulerPolicy};
use crate::coordinator::queue::{QueueEntry, StageQueue};
use crate::coordinator::state::StateStore;
use crate::coordinator::{lsf_key, scaling, slack::SlackPlan};
use crate::energy::ClusterEnergy;
use crate::metrics::{JobRecord, Recorder, StageRecord};
use crate::model::{Catalog, ChainId, MsId};
use crate::predictor::Predictor;
use crate::trace::Trace;
use crate::util::rng::Pcg;
use crate::util::{ms, secs, Micros, MICROS_PER_S};

/// Simulator events. Ord is required by the heap; ordering beyond the
/// (time, seq) key is irrelevant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// A request for `chain` arrives.
    Arrival { chain: ChainId },
    /// Container finished cold-starting.
    SpawnDone { cid: u64 },
    /// Container finished executing its current batch.
    BatchDone { cid: u64 },
    /// Close one W_s arrival-sampling window (predictor input).
    WindowClose,
    /// Periodic monitoring: the policy's `on_monitor` hook (Algorithm 1).
    Monitor,
    /// Periodic `on_scan` reclamation + energy sampling.
    Scan,
}

/// Per-job simulation state; stage records accumulate in place and move
/// into the [`Recorder`] at completion.
#[derive(Debug)]
struct JobState {
    chain: ChainId,
    arrival: Micros,
    stage_idx: usize,
    stages: Vec<StageRecord>,
    cur_enqueued: Micros,
    cur_exec_start: Micros,
    cur_cold_wait: Micros,
    done: bool,
}

/// Simulation parameters beyond the [`SystemConfig`].
#[derive(Debug, Clone)]
pub struct SimParams {
    pub cfg: SystemConfig,
    /// Chains of the workload mix (jobs pick uniformly).
    pub chains: Vec<ChainId>,
    pub trace: Trace,
    /// Drain window after the trace ends (s).
    pub drain_s: f64,
}

pub struct Engine {
    cat: Catalog,
    p: SimParams,
    plan: SlackPlan,
    queues: HashMap<MsId, StageQueue>,
    store: StateStore,
    cold: ColdStartModel,
    /// The scheduler policy. Held in an Option so hooks can borrow the
    /// engine immutably (for the `PolicyView`) while the trait object is
    /// temporarily taken out; always `Some` between events.
    policy: Option<Box<dyn SchedulerPolicy>>,
    predictor: Option<Box<dyn Predictor>>,
    rng: Pcg,
    events: BinaryHeap<Reverse<(Micros, u64, Event)>>,
    seq: u64,
    now: Micros,
    jobs: Vec<JobState>,
    pub recorder: Recorder,
    energy: ClusterEnergy,
    /// Per-second arrival counts inside the current sampling window.
    window_counts: Vec<u64>,
    window_start: Micros,
    /// Trailing window maxima used to sanity-clamp out-of-distribution
    /// forecasts; retention = history_s / sample_window_s windows.
    recent_maxima: VecDeque<f64>,
    maxima_keep: usize,
    stages: Vec<MsId>,
    /// Average trace rate, exposed to policies (SBatch pool sizing).
    avg_rate: f64,
    /// host-time sampling of dispatch decisions (§6.1.5 overhead metric)
    decision_probe: u64,
}

impl Engine {
    /// Build an engine for the policy registered under `cfg.rm.policy`.
    pub fn new(p: SimParams) -> Engine {
        let pol = p.cfg.rm.policy.build();
        Engine::with_policy(p, pol)
    }

    /// Build an engine driven by an arbitrary [`SchedulerPolicy`] — the
    /// extension point for policies outside the registry (see
    /// `examples/custom_policy.rs`).
    pub fn with_policy(p: SimParams, pol: Box<dyn SchedulerPolicy>) -> Engine {
        let cat = Catalog::paper();
        let plan = SlackPlan::build(&cat, &p.chains, &p.cfg.rm, pol.batching());
        let order = pol.queue_order();
        let mut stages: Vec<MsId> = Vec::new();
        for &c in &p.chains {
            for &s in &cat.chains[c].stages {
                if !stages.contains(&s) {
                    stages.push(s);
                }
            }
        }
        let queues = stages
            .iter()
            .map(|&s| (s, StageQueue::new(order)))
            .collect();
        let store = StateStore::new(
            p.cfg.cluster.nodes,
            p.cfg.cluster.cores_per_node,
            p.cfg.cluster.cpu_per_container,
        );
        let energy = ClusterEnergy::new(p.cfg.cluster.nodes);
        let predictor = pol.make_predictor(&p.cfg);
        let nwin = p.cfg.rm.sample_window_s.max(1.0) as usize;
        let maxima_keep = (p.cfg.rm.history_s / p.cfg.rm.sample_window_s.max(1e-9))
            .ceil()
            .max(1.0) as usize;
        let avg_rate = p.trace.avg_rate();
        let rng = Pcg::new(p.cfg.seed);
        Engine {
            cat,
            plan,
            queues,
            store,
            cold: ColdStartModel::default(),
            policy: Some(pol),
            predictor,
            rng,
            events: BinaryHeap::new(),
            seq: 0,
            now: 0,
            jobs: Vec::new(),
            recorder: Recorder::new(),
            energy,
            window_counts: vec![0; nwin],
            window_start: 0,
            recent_maxima: VecDeque::with_capacity(maxima_keep),
            maxima_keep,
            stages,
            avg_rate,
            decision_probe: 0,
            p,
        }
    }

    pub fn catalog(&self) -> &Catalog {
        &self.cat
    }

    fn push(&mut self, t: Micros, ev: Event) {
        self.seq += 1;
        self.events.push(Reverse((t, self.seq, ev)));
    }

    /// Read-only snapshot for policy hooks.
    fn view(&self, forecast: Option<f64>) -> PolicyView<'_> {
        PolicyView {
            cat: &self.cat,
            cfg: &self.p.cfg,
            chains: &self.p.chains,
            plan: &self.plan,
            stages: &self.stages,
            queues: &self.queues,
            store: &self.store,
            cold: &self.cold,
            now: self.now,
            forecast,
            avg_rate_hint: self.avg_rate,
        }
    }

    /// Spawn the plan's containers in order. Within an entry, a rejected
    /// spawn skips to the next entry — or aborts the whole plan when the
    /// policy asked for `stop_on_full` (fixed-pool provisioning).
    fn execute_plan(&mut self, plan: ScalingPlan) {
        let ScalingPlan {
            spawns,
            stop_on_full,
        } = plan;
        'spawning: for (ms_id, n) in spawns {
            for _ in 0..n {
                if self.spawn_container(ms_id, true).is_none() {
                    if stop_on_full {
                        break 'spawning;
                    }
                    break;
                }
            }
        }
    }

    /// Run the full simulation; returns the populated recorder.
    pub fn run(self) -> Recorder {
        self.run_checked(0)
            .expect("run without invariant checks cannot fail")
    }

    /// Run the full simulation, verifying conservation and store
    /// invariants every `check_every` events (0 = never). Used by the
    /// policy-conformance suite to certify arbitrary policies.
    pub fn run_checked(mut self, check_every: u64) -> Result<Recorder, String> {
        let horizon = secs(self.p.trace.duration_s() as f64);
        // seed arrivals
        let mut arr_rng = self.rng.fork(0xa221);
        let arrivals = self.p.trace.arrivals(&mut arr_rng);
        let nchains = self.p.chains.len();
        for (i, t) in arrivals.into_iter().enumerate() {
            let chain = self.p.chains[i % nchains.max(1)];
            self.push(t, Event::Arrival { chain });
        }
        // initial provisioning at t = 0 (e.g. SBatch's fixed pool)
        let mut pol = self.policy.take().expect("policy present");
        let start_plan = pol.on_start(&self.view(None));
        self.policy = Some(pol);
        self.execute_plan(start_plan);
        // periodic events
        self.push(secs(self.p.cfg.rm.sample_window_s), Event::WindowClose);
        self.push(secs(self.p.cfg.rm.monitor_interval_s), Event::Monitor);
        self.push(secs(self.p.cfg.rm.monitor_interval_s), Event::Scan);

        let end = horizon + secs(self.p.drain_s);
        let mut steps = 0u64;
        while let Some(Reverse((t, _, ev))) = self.events.pop() {
            if t > end {
                break;
            }
            self.now = t;
            match ev {
                Event::Arrival { chain } => self.on_arrival(chain),
                Event::SpawnDone { cid } => self.on_spawn_done(cid),
                Event::BatchDone { cid } => self.on_batch_done(cid),
                Event::WindowClose => self.on_window_close(),
                Event::Monitor => {
                    if t <= horizon {
                        self.on_monitor();
                        let next = t + secs(self.p.cfg.rm.monitor_interval_s);
                        self.push(next, Event::Monitor);
                    }
                }
                Event::Scan => {
                    self.on_scan();
                    if t <= end {
                        let next = t + secs(self.p.cfg.rm.monitor_interval_s);
                        self.push(next, Event::Scan);
                    }
                }
            }
            steps += 1;
            if check_every > 0 && steps % check_every == 0 {
                self.check_conservation()?;
                self.check_store()?;
            }
        }
        // final energy settlement + retire remaining containers at horizon
        let cids: Vec<u64> = self.store.container_ids();
        for cid in cids {
            self.recorder.container_retired(cid, self.now.min(end));
        }
        self.settle_energy(end.min(self.now.max(horizon)));
        self.recorder.horizon = horizon;
        self.recorder.energy_wh = self.energy.total_wh();
        Ok(self.recorder)
    }

    // ------------------------------------------------------------------
    // event handlers
    // ------------------------------------------------------------------

    fn on_arrival(&mut self, chain: ChainId) {
        let job_id = self.jobs.len() as u64;
        self.jobs.push(JobState {
            chain,
            arrival: self.now,
            stage_idx: 0,
            stages: Vec::with_capacity(self.cat.chains[chain].stages.len()),
            cur_enqueued: 0,
            cur_exec_start: 0,
            cur_cold_wait: 0,
            done: false,
        });
        // arrival-rate sampling for the predictor; an arrival delivered
        // exactly at a window boundary (before the WindowClose event
        // fires) still counts — clamp into the final bucket instead of
        // silently dropping it from the predictor input.
        let sec_in_window = ((self.now - self.window_start) / MICROS_PER_S) as usize;
        let bucket = sec_in_window.min(self.window_counts.len() - 1);
        self.window_counts[bucket] += 1;
        self.enqueue_stage(job_id, self.now);
    }

    fn enqueue_stage(&mut self, job_id: u64, t: Micros) {
        let (chain, stage_idx, arrival) = {
            let j = &mut self.jobs[job_id as usize];
            j.cur_enqueued = t;
            j.cur_cold_wait = 0;
            (j.chain, j.stage_idx, j.arrival)
        };
        let ms_id = self.cat.chains[chain].stages[stage_idx];
        let key = lsf_key(&self.cat, chain, stage_idx, arrival);
        self.seq += 1;
        let entry = QueueEntry {
            job_id,
            lsf_key: key,
            enqueued: t,
            seq: self.seq,
        };
        self.queues.get_mut(&ms_id).unwrap().push(entry);

        // event-driven per-request spawning is the policy's call (e.g.
        // Bline/BPred spawn the uncovered deficit, §3)
        let mut pol = self.policy.take().expect("policy present");
        let n = pol.on_arrival(ms_id, &self.view(None));
        self.policy = Some(pol);
        for _ in 0..n {
            if self.spawn_container(ms_id, true).is_none() {
                break; // cluster full
            }
        }
        self.try_dispatch(ms_id);
    }

    /// Move queued requests into warm container slots (greedy §4.4.1).
    fn try_dispatch(&mut self, ms_id: MsId) {
        let probe = self.decision_probe % 512 == 0;
        let t0 = probe.then(std::time::Instant::now);
        loop {
            if self.queues[&ms_id].is_empty() {
                break;
            }
            let Some(cid) = self.store.pick_container(ms_id) else {
                break;
            };
            let entry = self.queues.get_mut(&ms_id).unwrap().pop().unwrap();
            if self.store.dispatch(cid, entry.job_id, self.now) {
                self.start_exec(cid);
            }
        }
        self.decision_probe += 1;
        if let Some(t0) = t0 {
            self.recorder.decision_ns.push(t0.elapsed().as_nanos() as u64);
        }
    }

    /// Begin executing the container's queued requests as ONE batched
    /// inference pass (continuous batching: everything queued locally at
    /// kick-off time runs together; exec(B) = exec(1)·(1 + γ·(B−1))).
    fn start_exec(&mut self, cid: u64) {
        let b = self.store.begin_batch(cid);
        let base_ms = self.cat.microservices[b.ms_id].sample_exec_ms(&mut self.rng);
        let gamma = self.p.cfg.rm.batch_cost_gamma;
        let exec_ms = base_ms * (1.0 + gamma * (b.jobs.len() as f64 - 1.0));
        let overhead = self.cold.warm_overhead();
        let done_at = self.now + overhead + ms(exec_ms);
        for &job_id in &b.jobs {
            let j = &mut self.jobs[job_id as usize];
            j.cur_exec_start = self.now;
            // cold-start attribution: the job waited on this container's
            // spawn if it was enqueued before the container came up.
            j.cur_cold_wait = if b.started_cold && j.cur_enqueued < b.ready_at {
                (self.now - j.cur_enqueued).min(b.spawn_latency)
            } else {
                0
            };
        }
        self.push(done_at, Event::BatchDone { cid });
    }

    fn on_batch_done(&mut self, cid: u64) {
        let (ms_id, batch_jobs) = self.store.finish_batch(cid, self.now);
        self.recorder.container_executed(cid, batch_jobs.len() as u64);

        // Kick off the next batch immediately: the container must be Busy
        // again *before* job advancement below can trigger spawns (which
        // may evict idle containers — including this one otherwise).
        if !self
            .store
            .get(cid)
            .expect("container alive after finish_batch")
            .local
            .is_empty()
        {
            self.start_exec(cid);
        }

        // finalize stage records and advance every job of the batch
        for job_id in batch_jobs {
            let advance = {
                let j = &mut self.jobs[job_id as usize];
                j.stages.push(StageRecord {
                    ms_id,
                    enqueued: j.cur_enqueued,
                    exec_start: j.cur_exec_start,
                    exec_end: self.now,
                    cold_wait: j.cur_cold_wait,
                });
                j.stage_idx += 1;
                if j.stage_idx >= self.cat.chains[j.chain].stages.len() {
                    j.done = true;
                    None
                } else {
                    Some(job_id)
                }
            };
            match advance {
                None => {
                    let j = &mut self.jobs[job_id as usize];
                    self.recorder.job(JobRecord {
                        chain: j.chain,
                        arrival: j.arrival,
                        completion: self.now,
                        stages: std::mem::take(&mut j.stages),
                    });
                }
                Some(jid) => self.enqueue_stage(jid, self.now),
            }
        }

        // refill from the global queue (cid itself may have been evicted
        // by a capacity-pressure spawn during job advancement — fine, the
        // dispatcher picks any warm container of this stage)
        self.try_dispatch(ms_id);
    }

    fn on_spawn_done(&mut self, cid: u64) {
        // None when the container was already reclaimed
        if let Some(ms_id) = self.store.warm_up(cid, self.now) {
            self.try_dispatch(ms_id);
        }
    }

    fn on_window_close(&mut self) {
        // max per-second arrival rate inside the window (paper §4.5)
        let max_rate = self.window_counts.iter().copied().max().unwrap_or(0) as f64;
        if let Some(p) = self.predictor.as_mut() {
            p.observe(max_rate);
        }
        if self.recent_maxima.len() >= self.maxima_keep {
            self.recent_maxima.pop_front();
        }
        self.recent_maxima.push_back(max_rate);
        self.window_counts.iter_mut().for_each(|c| *c = 0);
        self.window_start = self.now;
        self.push(
            self.now + secs(self.p.cfg.rm.sample_window_s),
            Event::WindowClose,
        );
    }

    /// Forecast for this monitor tick, sanity-clamped: a pre-trained
    /// model queried far out of its training distribution must not
    /// over-provision more than 2x the recently observed peak (§8
    /// "Design Limitations"). `None` when the policy built no predictor.
    fn clamped_forecast(&mut self) -> Option<f64> {
        let recent_max = self
            .recent_maxima
            .iter()
            .copied()
            .fold(0.0f64, f64::max);
        self.predictor
            .as_mut()
            .map(|p| p.forecast().min((2.0 * recent_max).max(1.0)))
    }

    fn on_monitor(&mut self) {
        let forecast = self.clamped_forecast();
        let mut pol = self.policy.take().expect("policy present");
        let plan = pol.on_monitor(&self.view(forecast));
        self.policy = Some(pol);
        self.execute_plan(plan);
    }

    fn on_scan(&mut self) {
        let mut pol = self.policy.take().expect("policy present");
        let retire = pol.on_scan(&self.view(None));
        self.policy = Some(pol);
        for cid in retire {
            if self.store.remove(cid).is_some() {
                self.recorder.container_retired(cid, self.now);
            }
        }
        self.settle_energy(self.now);
        self.recorder
            .energy_series
            .push((self.now, self.energy.total_wh()));
    }

    fn settle_energy(&mut self, t: Micros) {
        let loads = self.store.node_loads();
        for (i, (busy, alloc)) in loads.into_iter().enumerate() {
            self.energy.nodes[i].update(t, busy, alloc, &self.p.cfg.cluster);
        }
    }

    fn spawn_container(&mut self, ms_id: MsId, cold: bool) -> Option<u64> {
        // capacity guard: one stage may hold at most max_stage_fraction of
        // the cluster's container slots (see RmConfig docs)
        let cap = scaling::stage_cap(
            self.p.cfg.cluster.max_containers(),
            self.p.cfg.rm.max_stage_fraction,
        );
        if self.store.stage_containers(ms_id) >= cap {
            return None;
        }
        let batch = self.plan.batch_for(ms_id);
        let latency = if cold {
            self.cold
                .sample(&self.cat.microservices[ms_id], &mut self.rng)
                .total()
        } else {
            0
        };
        let cid = match self.store.spawn(ms_id, batch, self.now, latency, cold) {
            Some(cid) => cid,
            None => {
                // Cluster full. Rebalance by evicting the globally
                // longest-idle container, but only when this stage is
                // genuinely underwater — containerless (startup
                // starvation), or its whole warm pool saturated with
                // nothing starting — and only a victim that has been idle
                // past a grace period (an over-provisioned pool member,
                // not a hot-pool straggler). Otherwise fail: requests
                // queue on the stage's warm pool, as on a full
                // Kubernetes cluster (pods pend, running pods serve).
                let starved = self.store.stage_containers(ms_id) == 0
                    || (self.store.warm_free_slots(ms_id) == 0
                        && self.store.starting_slots(ms_id) == 0);
                if !starved {
                    return None;
                }
                let grace = secs((self.p.cfg.rm.idle_timeout_s / 2.0).min(30.0));
                let victim = self.store.lru_idle_since(self.now.saturating_sub(grace))?;
                if self.store.get(victim).map(|c| c.ms_id) == Some(ms_id) {
                    return None;
                }
                self.store.remove(victim);
                self.recorder.container_retired(victim, self.now);
                self.store.spawn(ms_id, batch, self.now, latency, cold)?
            }
        };
        self.recorder.container_spawned(cid, ms_id, self.now, cold);
        if latency > 0 {
            self.push(self.now + latency, Event::SpawnDone { cid });
        } else {
            self.try_dispatch(ms_id);
        }
        Some(cid)
    }

    // ------------------------------------------------------------------
    // invariant checks (used by property tests)
    // ------------------------------------------------------------------

    /// Total requests conserved: every arrival is queued, in-flight, or done.
    pub fn check_conservation(&self) -> Result<(), String> {
        let queued: usize = self.queues.values().map(|q| q.len()).sum();
        let in_flight: usize = self.store.iter().map(|c| c.local.len()).sum();
        let done = self.jobs.iter().filter(|j| j.done).count();
        // jobs between stages are accounted at enqueue, so:
        let total = self.jobs.len();
        let accounted = queued + in_flight + done;
        if accounted != total {
            return Err(format!(
                "conservation violated: queued {queued} + in-flight {in_flight} + done {done} != {total}"
            ));
        }
        Ok(())
    }

    /// No node over capacity; all store indexes and aggregates consistent.
    pub fn check_store(&self) -> Result<(), String> {
        for n in &self.store.nodes {
            if n.alloc_cores > n.total_cores + 1e-9 {
                return Err(format!("node {} over capacity", n.id));
            }
        }
        self.store.check_consistency()
    }
}

/// Convenience: run one simulation for a registered policy and summarize.
pub fn run_sim(p: SimParams) -> (Recorder, crate::metrics::Summary) {
    let pol = p.cfg.rm.policy.build();
    run_sim_with(p, pol)
}

/// Run one simulation and summarize jobs arriving at or after `warmup`
/// (µs) — the shared plumbing behind `experiments::run_policy` and the
/// scenario sweep runner, so the steady-state cutoff is applied the same
/// way everywhere.
pub fn run_summarized(p: SimParams, warmup: Micros) -> (Recorder, crate::metrics::Summary) {
    let cat = Catalog::paper();
    let rec = Engine::new(p).run();
    let sum = rec.summarize_after(&cat, warmup);
    (rec, sum)
}

/// Run one simulation under an arbitrary [`SchedulerPolicy`] — the
/// public entry point for user-defined policies (no registry edit
/// needed; see `examples/custom_policy.rs`).
pub fn run_sim_with(
    p: SimParams,
    pol: Box<dyn SchedulerPolicy>,
) -> (Recorder, crate::metrics::Summary) {
    let cat = Catalog::paper();
    let rec = Engine::with_policy(p, pol).run();
    let sum = rec.summarize(&cat);
    (rec, sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, SystemConfig};

    fn params(policy: Policy, lambda: f64, dur: usize) -> SimParams {
        let cat = Catalog::paper();
        let mut cfg = SystemConfig::prototype(policy);
        cfg.rm.idle_timeout_s = 60.0;
        SimParams {
            cfg,
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(lambda, dur),
            drain_s: 30.0,
        }
    }

    #[test]
    fn fifer_completes_all_jobs_under_light_load() {
        let (rec, sum) = run_sim(params(Policy::Fifer, 5.0, 60));
        assert!(sum.jobs > 100, "jobs {}", sum.jobs);
        // all arrivals completed within the drain window
        assert_eq!(
            rec.jobs.len() as u64,
            sum.jobs,
            "recorder consistency"
        );
        assert!(sum.median_ms > 0.0);
        assert!(sum.total_spawned > 0);
    }

    #[test]
    fn all_policies_run_and_record() {
        for policy in Policy::ALL {
            let (rec, sum) = run_sim(params(policy, 5.0, 40));
            assert!(sum.jobs > 50, "{}: jobs {}", policy.name(), sum.jobs);
            assert!(
                rec.containers.len() as u64 == sum.total_spawned,
                "{}",
                policy.name()
            );
            assert!(sum.energy_wh > 0.0, "{}: energy", policy.name());
        }
    }

    #[test]
    fn bline_spawns_more_containers_than_fifer() {
        let (_, bline) = run_sim(params(Policy::Bline, 20.0, 120));
        let (_, fifer) = run_sim(params(Policy::Fifer, 20.0, 120));
        assert!(
            fifer.avg_containers < bline.avg_containers,
            "fifer {} vs bline {}",
            fifer.avg_containers,
            bline.avg_containers
        );
    }

    #[test]
    fn batching_increases_median_latency_at_steady_state() {
        // paper §6.1.2: batching RMs trade median latency for containers.
        // Compare steady state (past the cold-start transient).
        let cat = Catalog::paper();
        let run = |policy| {
            let rec = Engine::new(params(policy, 50.0, 400)).run();
            rec.summarize_after(&cat, secs(200.0))
        };
        let bline = run(Policy::Bline);
        let fifer = run(Policy::Fifer);
        assert!(
            fifer.median_ms >= bline.median_ms,
            "fifer {} vs bline {}",
            fifer.median_ms,
            bline.median_ms
        );
        assert!(fifer.slo_violation_pct <= bline.slo_violation_pct + 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, a) = run_sim(params(Policy::Fifer, 10.0, 60));
        let (_, b) = run_sim(params(Policy::Fifer, 10.0, 60));
        assert_eq!(a.jobs, b.jobs);
        assert_eq!(a.total_spawned, b.total_spawned);
        assert!((a.median_ms - b.median_ms).abs() < 1e-12);
    }

    #[test]
    fn engine_invariants_midway() {
        // invariant probes every 100 events across the whole run
        let eng = Engine::new(params(Policy::RScale, 10.0, 30));
        eng.check_store().unwrap();
        let rec = Engine::new(params(Policy::RScale, 10.0, 30))
            .run_checked(100)
            .unwrap();
        assert!(!rec.jobs.is_empty());
    }

    #[test]
    fn custom_policy_runs_through_engine() {
        // a do-nothing policy (never spawns, never reclaims) still
        // produces a consistent run: arrivals queue forever, nothing
        // completes, conservation holds throughout
        struct Noop;
        impl SchedulerPolicy for Noop {
            fn name(&self) -> &'static str {
                "Noop"
            }
            fn on_scan(&mut self, _view: &PolicyView) -> Vec<u64> {
                Vec::new()
            }
        }
        let rec = Engine::with_policy(params(Policy::Fifer, 5.0, 20), Box::new(Noop))
            .run_checked(50)
            .unwrap();
        assert!(rec.jobs.is_empty(), "no containers -> no completions");
        assert!(rec.containers.is_empty());
    }
}
