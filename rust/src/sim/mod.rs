//! High-fidelity event-driven cluster simulator (paper §5.2): the
//! **virtual-time driver** over the shared coordinator engine.
//!
//! Simulates the full serverless stack — request arrival, per-stage global
//! queues, container local queues, cold starts (spawn + image pull +
//! runtime init), serial in-container execution, greedy placement, idle
//! scale-in, node power — at microsecond resolution. The entire control
//! loop (queues, state store, slack batching, predictor windows, every
//! policy hook) lives in [`EngineCore`]
//! (`crate::coordinator::engine`); this module contributes only the
//! [`VirtualDriver`]: modeled cold-start and execution latencies sampled
//! from the seeded PCG and scheduled on the core's event heap, with the
//! whole trace preloaded as arrival events. The live server
//! (`crate::server`) drives the *same* core in wall-clock time, so the
//! paper's §5.2 sim-vs-prototype validation is structural here —
//! `rust/tests/test_driver_differential.rs` runs every policy through
//! both drivers.
//!
//! All *policy* decisions (spawning, scaling, reclamation, queue
//! ordering) are delegated to a [`SchedulerPolicy`] trait object — the
//! engine owns mechanics only and contains no per-policy branches. Use
//! [`run_sim`] for a registered policy, [`run_sim_with`] /
//! `Engine::with_policy` to inject your own implementation.
//!
//! Events are processed from a binary heap ordered by (time, seq); all
//! randomness flows from one seeded PCG, so runs are exactly reproducible.

pub mod sharded;

use crate::config::SystemConfig;
use crate::coordinator::engine::{Driver, EffectCtx, EngineCore, SpawnEffect};
use crate::coordinator::policy::SchedulerPolicy;
use crate::coordinator::state::BatchStart;
use crate::metrics::Recorder;
use crate::model::{Catalog, ChainId, MsId};
use crate::obs::{ObsConfig, ObsReport};
use crate::trace::Trace;
use crate::util::{secs, Micros};

/// Simulation parameters beyond the [`SystemConfig`].
#[derive(Debug, Clone)]
pub struct SimParams {
    pub cfg: SystemConfig,
    /// Chains of the workload mix (jobs pick uniformly).
    pub chains: Vec<ChainId>,
    pub trace: Trace,
    /// Drain window after the trace ends (s).
    pub drain_s: f64,
}

/// The virtual-time [`Driver`]: every effect is a modeled latency drawn
/// from the core's seeded PCG and scheduled on the event heap, so a run
/// is a pure function of its seed. Holds the workload source (trace +
/// drain window) that `Engine::run` feeds into the core.
pub struct VirtualDriver {
    trace: Trace,
    drain_s: f64,
}

impl Driver for VirtualDriver {
    /// Cold starts: spawn + image pull + runtime init, sampled from the
    /// calibrated model via the shared `EffectCtx::sample_cold_start`.
    fn begin_spawn(&mut self, ms_id: MsId, cold: bool, mut ctx: EffectCtx<'_>) -> SpawnEffect {
        let latency = if cold { ctx.sample_cold_start(ms_id) } else { 0 };
        SpawnEffect::Ready(latency)
    }

    /// Batched execution: the shared `EffectCtx::sample_batch_exec`
    /// model, completing virtually after that long.
    fn exec_batch(&mut self, _cid: u64, b: &BatchStart, mut ctx: EffectCtx<'_>) -> Option<Micros> {
        Some(ctx.sample_batch_exec(b))
    }
}

/// The simulator: the shared coordinator core under the virtual-time
/// driver. All state-machine behavior (and its documentation) lives on
/// [`EngineCore`]; the methods below add trace seeding and the
/// virtual-time run loop.
pub type Engine = EngineCore<VirtualDriver>;

impl EngineCore<VirtualDriver> {
    /// Build an engine for the policy registered under `cfg.rm.policy`.
    pub fn new(p: SimParams) -> Engine {
        let pol = p.cfg.rm.policy.build();
        Engine::with_policy(p, pol)
    }

    /// Build an engine driven by an arbitrary [`SchedulerPolicy`] — the
    /// extension point for policies outside the registry (see
    /// `examples/custom_policy.rs`).
    pub fn with_policy(p: SimParams, pol: Box<dyn SchedulerPolicy>) -> Engine {
        let avg_rate = p.trace.avg_rate();
        let driver = VirtualDriver {
            trace: p.trace,
            drain_s: p.drain_s,
        };
        EngineCore::build(p.cfg, p.chains, avg_rate, pol, driver)
    }

    /// Run the full simulation; returns the populated recorder.
    pub fn run(self) -> Recorder {
        self.run_checked(0)
            .expect("run without invariant checks cannot fail")
    }

    /// Run the full simulation, verifying conservation and store
    /// invariants every `check_every` events (0 = never). Used by the
    /// policy-conformance suite to certify arbitrary policies.
    pub fn run_checked(self, check_every: u64) -> Result<Recorder, String> {
        self.run_collecting(check_every, None).map(|(rec, _)| rec)
    }

    /// [`run_checked`](Self::run_checked) with an optional observability
    /// collector: when `obs` is `Some`, the returned [`ObsReport`]
    /// carries the virtual-time SLO timeline in the exact schema the
    /// live `/metrics` endpoints serve — one contract, two drivers —
    /// and, like everything else here, is a pure function of the seed.
    pub fn run_collecting(
        self,
        check_every: u64,
        obs: Option<ObsConfig>,
    ) -> Result<(Recorder, Option<ObsReport>), String> {
        self.run_collecting_full(check_every, obs, false)
            .map(|(rec, report, _)| (rec, report))
    }

    /// [`run_collecting`](Self::run_collecting) plus an optional
    /// invocation log for the offline optimality-gap estimators
    /// (`crate::estimator`). Like the obs collector, the log tap is a
    /// pure observer: enabling it cannot change scheduling decisions,
    /// so the recorder is byte-identical either way.
    pub fn run_collecting_full(
        mut self,
        check_every: u64,
        obs: Option<ObsConfig>,
        invocation_log: bool,
    ) -> Result<(Recorder, Option<ObsReport>, Option<crate::estimator::InvocationLog>), String>
    {
        if let Some(cfg) = obs {
            self.enable_obs(cfg);
        }
        if invocation_log {
            self.enable_invocation_log();
        }
        let horizon = secs(self.driver.trace.duration_s() as f64);
        let end = horizon + secs(self.driver.drain_s);
        // seed arrivals (heap + job table sized once, up front)
        let mut arr_rng = self.rng.fork(0xa221);
        let arrivals = self.driver.trace.arrivals(&mut arr_rng);
        self.reserve_workload(arrivals.len());
        let nchains = self.chains.len();
        for (i, t) in arrivals.into_iter().enumerate() {
            let chain = self.chains[i % nchains.max(1)];
            self.schedule_arrival(t, chain);
        }
        // initial provisioning + periodic events, then drain the heap
        self.bootstrap(horizon, end);
        self.run_events(check_every)?;
        let (recorder, _driver, report, log) = self.into_parts_full();
        Ok((recorder, report, log))
    }
}

/// Convenience: run one simulation for a registered policy and summarize.
pub fn run_sim(p: SimParams) -> (Recorder, crate::metrics::Summary) {
    let pol = p.cfg.rm.policy.build();
    run_sim_with(p, pol)
}

/// Run one simulation and summarize jobs arriving at or after `warmup`
/// (µs) — the shared plumbing behind `experiments::run_policy` and the
/// scenario sweep runner, so the steady-state cutoff is applied the same
/// way everywhere.
pub fn run_summarized(p: SimParams, warmup: Micros) -> (Recorder, crate::metrics::Summary) {
    let (rec, sum, _) = run_summarized_obs(p, warmup, None);
    (rec, sum)
}

/// [`run_summarized`] plus an optional virtual-time observability
/// timeline — the plumbing behind `fifer scenario run --slo-timeline`.
pub fn run_summarized_obs(
    p: SimParams,
    warmup: Micros,
    obs: Option<ObsConfig>,
) -> (Recorder, crate::metrics::Summary, Option<ObsReport>) {
    run_summarized_full(p, warmup, obs, false)
}

/// [`run_summarized_obs`] plus the offline optimality-gap analysis:
/// when `optimality` is set, the engine records its invocation log and
/// the summary's `optimality` block carries the three lower-bound
/// estimators' verdict against the run's achieved cost — the plumbing
/// behind `fifer scenario run --optimality` (see `crate::estimator`).
pub fn run_summarized_full(
    p: SimParams,
    warmup: Micros,
    obs: Option<ObsConfig>,
    optimality: bool,
) -> (Recorder, crate::metrics::Summary, Option<ObsReport>) {
    let cat = Catalog::paper();
    let (rec, report, log) = Engine::new(p)
        .run_collecting_full(0, obs, optimality)
        .expect("run without invariant checks cannot fail");
    let mut sum = rec.summarize_after(&cat, warmup);
    if let Some(log) = log {
        sum.optimality = Some(crate::estimator::analyze(&log, &rec));
    }
    (rec, sum, report)
}

/// Run one simulation under an arbitrary [`SchedulerPolicy`] — the
/// public entry point for user-defined policies (no registry edit
/// needed; see `examples/custom_policy.rs`).
pub fn run_sim_with(
    p: SimParams,
    pol: Box<dyn SchedulerPolicy>,
) -> (Recorder, crate::metrics::Summary) {
    let cat = Catalog::paper();
    let rec = Engine::with_policy(p, pol).run();
    let sum = rec.summarize(&cat);
    (rec, sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, SystemConfig};
    use crate::coordinator::policy::PolicyView;

    fn params(policy: Policy, lambda: f64, dur: usize) -> SimParams {
        let cat = Catalog::paper();
        let mut cfg = SystemConfig::prototype(policy);
        cfg.rm.idle_timeout_s = 60.0;
        SimParams {
            cfg,
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(lambda, dur),
            drain_s: 30.0,
        }
    }

    #[test]
    fn fifer_completes_all_jobs_under_light_load() {
        let (rec, sum) = run_sim(params(Policy::Fifer, 5.0, 60));
        assert!(sum.jobs > 100, "jobs {}", sum.jobs);
        // all arrivals completed within the drain window
        assert_eq!(
            rec.jobs.len() as u64,
            sum.jobs,
            "recorder consistency"
        );
        assert!(sum.median_ms > 0.0);
        assert!(sum.total_spawned > 0);
    }

    #[test]
    fn all_policies_run_and_record() {
        for policy in Policy::ALL {
            let (rec, sum) = run_sim(params(policy, 5.0, 40));
            assert!(sum.jobs > 50, "{}: jobs {}", policy.name(), sum.jobs);
            assert!(
                rec.containers.len() as u64 == sum.total_spawned,
                "{}",
                policy.name()
            );
            assert!(sum.energy_wh > 0.0, "{}: energy", policy.name());
        }
    }

    #[test]
    fn bline_spawns_more_containers_than_fifer() {
        let (_, bline) = run_sim(params(Policy::Bline, 20.0, 120));
        let (_, fifer) = run_sim(params(Policy::Fifer, 20.0, 120));
        assert!(
            fifer.avg_containers < bline.avg_containers,
            "fifer {} vs bline {}",
            fifer.avg_containers,
            bline.avg_containers
        );
    }

    #[test]
    fn batching_increases_median_latency_at_steady_state() {
        // paper §6.1.2: batching RMs trade median latency for containers.
        // Compare steady state (past the cold-start transient).
        let cat = Catalog::paper();
        let run = |policy| {
            let rec = Engine::new(params(policy, 50.0, 400)).run();
            rec.summarize_after(&cat, secs(200.0))
        };
        let bline = run(Policy::Bline);
        let fifer = run(Policy::Fifer);
        assert!(
            fifer.median_ms >= bline.median_ms,
            "fifer {} vs bline {}",
            fifer.median_ms,
            bline.median_ms
        );
        assert!(fifer.slo_violation_pct <= bline.slo_violation_pct + 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (_, a) = run_sim(params(Policy::Fifer, 10.0, 60));
        let (_, b) = run_sim(params(Policy::Fifer, 10.0, 60));
        assert_eq!(a.jobs, b.jobs);
        assert_eq!(a.total_spawned, b.total_spawned);
        assert!((a.median_ms - b.median_ms).abs() < 1e-12);
    }

    #[test]
    fn engine_invariants_midway() {
        // invariant probes every 100 events across the whole run
        let eng = Engine::new(params(Policy::RScale, 10.0, 30));
        eng.check_store().unwrap();
        let rec = Engine::new(params(Policy::RScale, 10.0, 30))
            .run_checked(100)
            .unwrap();
        assert!(!rec.jobs.is_empty());
    }

    #[test]
    fn custom_policy_runs_through_engine() {
        // a do-nothing policy (never spawns, never reclaims) still
        // produces a consistent run: arrivals queue forever, nothing
        // completes, conservation holds throughout
        struct Noop;
        impl SchedulerPolicy for Noop {
            fn name(&self) -> &'static str {
                "Noop"
            }
            fn on_scan(&mut self, _view: &PolicyView) -> Vec<u64> {
                Vec::new()
            }
        }
        let rec = Engine::with_policy(params(Policy::Fifer, 5.0, 20), Box::new(Noop))
            .run_checked(50)
            .unwrap();
        assert!(rec.jobs.is_empty(), "no containers -> no completions");
        assert!(rec.containers.is_empty());
    }
}
