//! Sharded virtual-time runs: N [`EngineCore`] shards advanced in
//! lockstep epochs, with chain-hash routing and capacity rebalancing
//! (see `crate::coordinator::sharded` for the routing/rebalancing
//! pieces and docs/DESIGN.md §Sharding for the contract).
//!
//! Determinism contract: for a fixed shard count, a sharded run is a
//! pure function of the seed — the router is splitmix64 (no platform
//! hashers), shards advance in index order at epoch boundaries bounded
//! by the monitor period, and the rebalancer is pure arithmetic. With
//! `shards = 1` the run is **byte-identical** to the unsharded engine:
//! the single shard sees the same config, the same rng fork sequence,
//! and the same arrival stream, and every merge step passes a single
//! part through unchanged (pinned by `rust/tests/test_sharded.rs` for
//! every registered policy).
//!
//! Lockstep rule: shards advance together to each monitor-period
//! boundary `t < horizon` (processing all events ≤ t), then the
//! rebalancer runs; after the last boundary each shard drains its
//! remaining events to the end of its drain window independently —
//! there is no cross-shard interaction after the trace ends, so the
//! final-drain order cannot affect results.

use crate::coordinator::engine::EngineCore;
use crate::coordinator::sharded::{partition_count, RebalancerConfig, ShardedCoordinator};
use crate::estimator::InvocationLog;
use crate::metrics::{Recorder, Summary};
use crate::model::{Catalog, ChainId};
use crate::obs::{merge_reports, ObsConfig, ObsReport};
use crate::sim::{SimParams, VirtualDriver};
use crate::util::{secs, Micros};

/// Everything a sharded sim run produces: the merged cluster-wide
/// recorder/report/log (shapes identical to an unsharded run) plus the
/// per-shard slices the shard-sweep evaluation reports.
pub struct ShardedRun {
    pub recorder: Recorder,
    pub report: Option<ObsReport>,
    pub log: Option<InvocationLog>,
    /// Arrivals routed to each shard (chain-hash, so per-chain totals
    /// never split across shards).
    pub shard_arrivals: Vec<usize>,
    /// Per-shard probed decision latencies (ns; empty unless
    /// `FIFER_DECISION_PROBE` is armed).
    pub shard_decision_ns: Vec<Vec<u64>>,
    /// Provisioned cores per shard at the end of the run (reflects any
    /// migrations).
    pub shard_capacity_cores: Vec<f64>,
    /// Capacity migrations the rebalancer performed.
    pub migrations: u64,
}

fn merge_logs(mut parts: Vec<InvocationLog>) -> Option<InvocationLog> {
    let mut out = match parts.len() {
        0 => return None,
        _ => parts.remove(0),
    };
    // gamma/overhead/batch_cap are identical across shards (every shard
    // is built from the same config and full chain list)
    for p in parts {
        out.entries.extend(p.entries);
    }
    Some(out)
}

/// Run one sharded simulation. Mirrors
/// [`EngineCore::run_collecting_full`] shard-by-shard:
/// build N engines over a node partition, fork each engine's rng the
/// way the unsharded path does (so shard 0's stream is bit-identical to
/// the unsharded stream), route the shared arrival stream by chain
/// hash, advance in lockstep epochs with a rebalance tick per epoch,
/// drain, and merge. `check_every > 0` additionally verifies
/// conservation and store invariants per shard at every epoch (and
/// cluster-wide capacity conservation), then per-event during the
/// drain.
pub fn run_sharded_collecting_full(
    p: SimParams,
    nshards: usize,
    check_every: u64,
    obs: Option<ObsConfig>,
    invocation_log: bool,
    rcfg: RebalancerConfig,
) -> Result<ShardedRun, String> {
    let nshards = nshards.max(1);
    let total_nodes = p.cfg.cluster.nodes;
    if nshards > total_nodes {
        return Err(format!(
            "shards ({nshards}) must not exceed cluster nodes ({total_nodes}): \
             every shard needs at least one node of capacity"
        ));
    }
    let seed = p.cfg.seed;

    // one engine per shard: same seed, same full chain list (routing is
    // by hash, not by chain partition), a near-even slice of the nodes,
    // and the load hint scaled to the shard's expected share
    let mut engines: Vec<EngineCore<VirtualDriver>> = Vec::with_capacity(nshards);
    for k in 0..nshards {
        let mut cfg = p.cfg.clone();
        cfg.cluster.nodes = partition_count(total_nodes, nshards, k);
        let pol = cfg.rm.policy.build();
        let driver = VirtualDriver {
            trace: p.trace.clone(),
            drain_s: p.drain_s,
        };
        let avg_rate = p.trace.avg_rate() / nshards as f64;
        let mut eng = EngineCore::build(cfg, p.chains.clone(), avg_rate, pol, driver);
        if let Some(c) = obs {
            eng.enable_obs(c);
        }
        if invocation_log {
            eng.enable_invocation_log();
        }
        engines.push(eng);
    }

    // fork EVERY shard's rng exactly as the unsharded path forks its
    // one engine — all engines share the seed, so all forks (and the
    // post-fork engine rng states) are identical; shard 0's fork
    // generates the one shared arrival stream
    let mut forks: Vec<_> = engines.iter_mut().map(|e| e.rng.fork(0xa221)).collect();
    let arrivals = p.trace.arrivals(&mut forks[0]);

    let mut sc = ShardedCoordinator::new(engines, seed, rcfg);
    let nchains = p.chains.len();
    let mut routed: Vec<(Micros, ChainId, usize)> = Vec::with_capacity(arrivals.len());
    let mut shard_arrivals = vec![0usize; nshards];
    for (i, t) in arrivals.into_iter().enumerate() {
        let chain = p.chains[i % nchains.max(1)];
        let k = sc.route(chain);
        shard_arrivals[k] += 1;
        routed.push((t, chain, k));
    }
    for (k, eng) in sc.shards_mut().iter_mut().enumerate() {
        eng.reserve_workload(shard_arrivals[k]);
    }
    for (t, chain, k) in routed {
        sc.shard_mut(k).schedule_arrival(t, chain);
    }

    let horizon = secs(p.trace.duration_s() as f64);
    let end = horizon + secs(p.drain_s);
    for eng in sc.shards_mut() {
        eng.bootstrap(horizon, end);
    }
    let initial_capacity: f64 = sc.shards().iter().map(|e| e.capacity_cores()).sum();

    // lockstep epochs bounded by the monitor period, rebalancing at
    // each boundary; strictly below the horizon so the final drain (and
    // each engine's final `now`) is computed exactly as unsharded
    let epoch = secs(p.cfg.rm.monitor_interval_s.max(1e-3));
    let mut t = epoch;
    while t < horizon {
        for eng in sc.shards_mut() {
            eng.advance_to(t);
        }
        sc.rebalance_once();
        if check_every > 0 {
            for (k, eng) in sc.shards().iter().enumerate() {
                eng.check_conservation()
                    .map_err(|e| format!("shard {k} @ {t}us: {e}"))?;
                eng.check_store()
                    .map_err(|e| format!("shard {k} @ {t}us: {e}"))?;
            }
            let cap: f64 = sc.shards().iter().map(|e| e.capacity_cores()).sum();
            if (cap - initial_capacity).abs() > 1e-6 {
                return Err(format!(
                    "capacity not conserved @ {t}us: {cap} != {initial_capacity}"
                ));
            }
        }
        t += epoch;
    }
    for eng in sc.shards_mut() {
        eng.run_events(check_every)?;
    }

    let migrations = sc.migrations();
    let shard_capacity_cores: Vec<f64> = sc.shards().iter().map(|e| e.capacity_cores()).collect();
    let mut recs = Vec::with_capacity(nshards);
    let mut reports = Vec::new();
    let mut logs = Vec::new();
    let mut shard_decision_ns = Vec::with_capacity(nshards);
    for eng in sc.into_shards() {
        let (rec, _driver, report, log) = eng.into_parts_full();
        shard_decision_ns.push(rec.decision_ns.clone());
        recs.push(rec);
        if let Some(r) = report {
            reports.push(r);
        }
        if let Some(l) = log {
            logs.push(l);
        }
    }
    Ok(ShardedRun {
        recorder: Recorder::merge(recs),
        report: merge_reports(reports),
        log: merge_logs(logs),
        shard_arrivals,
        shard_decision_ns,
        shard_capacity_cores,
        migrations,
    })
}

/// [`run_sharded_collecting_full`] plus the warm-up-aware summary and
/// (optionally) the optimality-gap analysis over the merged log — the
/// sharded counterpart of `sim::run_summarized_full`, and the entry
/// point the scenario runner uses for cells with `shards > 1`.
pub fn run_sharded_summarized(
    p: SimParams,
    nshards: usize,
    warmup: Micros,
    obs: Option<ObsConfig>,
    optimality: bool,
) -> Result<(ShardedRun, Summary), String> {
    let cat = Catalog::paper();
    let run = run_sharded_collecting_full(
        p,
        nshards,
        0,
        obs,
        optimality,
        RebalancerConfig::default(),
    )?;
    let mut sum = run.recorder.summarize_after(&cat, warmup);
    if let Some(log) = &run.log {
        sum.optimality = Some(crate::estimator::analyze(log, &run.recorder));
    }
    Ok((run, sum))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, SystemConfig};
    use crate::trace::Trace;

    fn params(policy: Policy, seed: u64, lambda: f64, dur: usize) -> SimParams {
        let cat = Catalog::paper();
        let mut cfg = SystemConfig::prototype(policy);
        cfg.seed = seed;
        SimParams {
            cfg,
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(lambda, dur),
            drain_s: 30.0,
        }
    }

    #[test]
    fn one_shard_is_byte_identical_to_unsharded() {
        let (rec, report, log) = crate::sim::Engine::new(params(Policy::Fifer, 42, 10.0, 60))
            .run_collecting_full(0, Some(ObsConfig::default()), true)
            .unwrap();
        let run = run_sharded_collecting_full(
            params(Policy::Fifer, 42, 10.0, 60),
            1,
            0,
            Some(ObsConfig::default()),
            true,
            RebalancerConfig::default(),
        )
        .unwrap();
        assert_eq!(run.migrations, 0, "one shard can never migrate");
        assert_eq!(run.recorder.jobs, rec.jobs);
        assert_eq!(run.recorder.containers, rec.containers);
        assert_eq!(run.recorder.energy_series, rec.energy_series);
        assert_eq!(run.recorder.cold_starts, rec.cold_starts);
        assert_eq!(
            run.report.unwrap().timeline_json().to_string(),
            report.unwrap().timeline_json().to_string()
        );
        assert_eq!(run.log.unwrap().entries.len(), log.unwrap().entries.len());
    }

    #[test]
    fn sharded_run_is_deterministic_and_conserves_capacity() {
        let run_once = || {
            run_sharded_collecting_full(
                params(Policy::Fifer, 7, 40.0, 80),
                2,
                100,
                None,
                false,
                RebalancerConfig {
                    pressure_ratio: 1.0,
                    min_gap: 0.0,
                    hysteresis_ticks: 1,
                    cooldown_ticks: 0,
                },
            )
            .unwrap()
        };
        let a = run_once();
        let b = run_once();
        assert_eq!(a.recorder.jobs, b.recorder.jobs);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.shard_capacity_cores, b.shard_capacity_cores);
        // completed jobs never exceed routed arrivals
        assert!(a.recorder.jobs.len() <= a.shard_arrivals.iter().sum::<usize>());
        // capacity conservation was asserted per-epoch by check_every;
        // assert the endpoint too
        let total: f64 = a.shard_capacity_cores.iter().sum();
        let expected = (SystemConfig::prototype(Policy::Fifer).cluster.nodes
            * SystemConfig::prototype(Policy::Fifer).cluster.cores_per_node)
            as f64;
        assert!((total - expected).abs() < 1e-6, "{total} != {expected}");
    }

    #[test]
    fn too_many_shards_is_an_error() {
        let e = run_sharded_collecting_full(
            params(Policy::Fifer, 42, 5.0, 20),
            64,
            0,
            None,
            false,
            RebalancerConfig::default(),
        );
        assert!(e.is_err());
    }
}
