//! PJRT runtime: load AOT artifacts, compile once, execute on the hot path.
//!
//! This is the only place the `xla` crate is touched. The build-time Python
//! pipeline (`python/compile/aot.py`) lowers every (microservice × batch
//! size) inference graph and the predictor networks to **HLO text**
//! (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos — see
//! docs/DESIGN.md); here we parse that text, compile it on the PJRT CPU client
//! once per executable, and run batched inference with zero Python on the
//! request path.
//!
//! Weights are runtime parameters (not baked constants) so the HLO stays
//! small: `execute(w1, b1, ..., wn, bn, x)`. The weight literals are
//! created once at load and reused for every call.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::MsId;
use crate::util::json::Json;

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub slo_ms: f64,
    pub batch_sizes: Vec<usize>,
    pub microservices: HashMap<String, MsEntry>,
    pub predictors: HashMap<String, PredictorEntry>,
    pub traces: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct MsEntry {
    pub name: String,
    pub paper_exec_ms: f64,
    pub input_dim: usize,
    pub output_dim: usize,
    /// layer shapes: (w_shape, b_len) in order
    pub layers: Vec<((usize, usize), usize)>,
    pub weights_path: String,
    /// batch size -> hlo file
    pub batches: HashMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct PredictorEntry {
    pub path: String,
    pub window: usize,
    pub scale: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .context("loading artifacts manifest (run `make artifacts`?)")?;
        let mut microservices = HashMap::new();
        for (name, e) in j.get("microservices")?.as_obj()? {
            let mut layers = Vec::new();
            for l in e.get("weights")?.get("layers")?.as_arr()? {
                let w = l.get("w")?.as_f64_vec()?;
                let b = l.get("b")?.as_f64_vec()?;
                if w.len() != 2 || b.len() != 1 {
                    bail!("bad layer shape entry for {name}");
                }
                layers.push(((w[0] as usize, w[1] as usize), b[0] as usize));
            }
            let mut batches = HashMap::new();
            for (b, f) in e.get("batches")?.as_obj()? {
                batches.insert(b.parse::<usize>()?, f.as_str()?.to_string());
            }
            microservices.insert(
                name.clone(),
                MsEntry {
                    name: name.clone(),
                    paper_exec_ms: e.get("paper_exec_ms")?.as_f64()?,
                    input_dim: e.get("input_dim")?.as_usize()?,
                    output_dim: e.get("output_dim")?.as_usize()?,
                    layers,
                    weights_path: e.get("weights")?.get("path")?.as_str()?.to_string(),
                    batches,
                },
            );
        }
        let mut predictors = HashMap::new();
        for (name, e) in j.get("predictors")?.as_obj()? {
            predictors.insert(
                name.clone(),
                PredictorEntry {
                    path: e.get("path")?.as_str()?.to_string(),
                    window: e.get("window")?.as_usize()?,
                    scale: e.get("scale")?.as_f64()?,
                },
            );
        }
        let mut traces = HashMap::new();
        for (name, e) in j.get("traces")?.as_obj()? {
            traces.insert(name.clone(), e.get("path")?.as_str()?.to_string());
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            slo_ms: j.get("slo_ms")?.as_f64()?,
            batch_sizes: j
                .get("batch_sizes")?
                .as_f64_vec()?
                .into_iter()
                .map(|b| b as usize)
                .collect(),
            microservices,
            predictors,
            traces,
        })
    }

    /// Smallest compiled batch size >= `n` (falls back to the largest).
    pub fn pick_batch(&self, n: usize) -> usize {
        let mut sizes = self.batch_sizes.clone();
        sizes.sort_unstable();
        sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| sizes.last().copied().unwrap_or(1))
    }
}

/// Load an f32-LE weight binary into per-layer (w, b) flat vectors.
pub fn load_weights_bin(
    path: &Path,
    layers: &[((usize, usize), usize)],
) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let total: usize = layers.iter().map(|((r, c), b)| r * c + b).sum();
    if raw.len() != 4 * total {
        bail!(
            "weight file {} is {} bytes, expected {}",
            path.display(),
            raw.len(),
            4 * total
        );
    }
    let mut floats = Vec::with_capacity(total);
    for chunk in raw.chunks_exact(4) {
        floats.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    let mut out = Vec::new();
    let mut off = 0usize;
    for &((r, c), blen) in layers {
        let w = floats[off..off + r * c].to_vec();
        off += r * c;
        let b = floats[off..off + blen].to_vec();
        off += blen;
        out.push((w, b));
    }
    Ok(out)
}

/// One compiled (microservice, batch) executable.
struct ModelExec {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    input_dim: usize,
    output_dim: usize,
}

/// The PJRT runtime: one CPU client, executables compiled lazily and
/// cached. Keep one `Runtime` per executor thread (or a dedicated runtime
/// thread fed by channels) — the live server does the latter.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    execs: HashMap<(String, usize), ModelExec>,
    weights: HashMap<String, Vec<xla::Literal>>,
    predictor_execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            execs: HashMap::new(),
            weights: HashMap::new(),
            predictor_execs: HashMap::new(),
        })
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Ensure weights for a microservice are loaded as literals.
    fn ensure_weights(&mut self, name: &str) -> Result<()> {
        if self.weights.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .microservices
            .get(name)
            .ok_or_else(|| anyhow!("unknown microservice {name}"))?
            .clone();
        let flat =
            load_weights_bin(&self.manifest.dir.join(&entry.weights_path), &entry.layers)?;
        let mut lits = Vec::new();
        for (((r, c), blen), (w, b)) in entry.layers.iter().zip(flat) {
            let wl = xla::Literal::vec1(&w)
                .reshape(&[*r as i64, *c as i64])
                .map_err(|e| anyhow!("reshape w: {e:?}"))?;
            let bl = xla::Literal::vec1(&b[..*blen]);
            lits.push(wl);
            lits.push(bl);
        }
        self.weights.insert(name.to_string(), lits);
        Ok(())
    }

    /// Ensure the (microservice, batch) executable is compiled.
    pub fn ensure_model(&mut self, name: &str, batch: usize) -> Result<()> {
        if self.execs.contains_key(&(name.to_string(), batch)) {
            return Ok(());
        }
        self.ensure_weights(name)?;
        let entry = self
            .manifest
            .microservices
            .get(name)
            .ok_or_else(|| anyhow!("unknown microservice {name}"))?;
        let file = entry
            .batches
            .get(&batch)
            .ok_or_else(|| anyhow!("{name} has no batch-{batch} artifact"))?
            .clone();
        let (input_dim, output_dim) = (entry.input_dim, entry.output_dim);
        let exe = self.compile(&file)?;
        self.execs.insert(
            (name.to_string(), batch),
            ModelExec {
                exe,
                batch,
                input_dim,
                output_dim,
            },
        );
        Ok(())
    }

    /// Run batched inference: `x` is row-major (rows, input_dim) with any
    /// rows >= 1 (padded up to the nearest compiled batch internally).
    /// Returns the first `rows` rows of the (batch, output_dim) output.
    pub fn infer(&mut self, name: &str, rows: usize, x: &[f32]) -> Result<Vec<f32>> {
        let batch = self.manifest.pick_batch(rows.max(1));
        self.ensure_model(name, batch)?;
        let me = &self.execs[&(name.to_string(), batch)];
        if x.len() != rows * me.input_dim {
            bail!(
                "input len {} != rows {rows} x input_dim {}",
                x.len(),
                me.input_dim
            );
        }
        let mut padded = vec![0.0f32; me.batch * me.input_dim];
        let n = x.len().min(padded.len());
        padded[..n].copy_from_slice(&x[..n]);
        let xl = xla::Literal::vec1(&padded)
            .reshape(&[me.batch as i64, me.input_dim as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let mut args: Vec<&xla::Literal> = self.weights[name].iter().collect();
        args.push(&xl);
        let result = me
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {name}/b{batch}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let tuple = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let full: Vec<f32> = tuple.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(full[..(rows * me.output_dim).min(full.len())].to_vec())
    }

    /// Load the predictor weight literals in the artifact's parameter
    /// order (see python/compile/aot.py lower_lstm / lower_ff).
    fn predictor_weight_literals(&self, which: &str) -> Result<Vec<xla::Literal>> {
        let j = Json::parse_file(&self.manifest.dir.join("predictor_weights.json"))?;
        let lit2 = |v: &Json, rows: usize, cols: usize| -> Result<xla::Literal> {
            let flat = v.as_f32_flat()?;
            if flat.len() != rows * cols {
                bail!("predictor tensor size mismatch");
            }
            xla::Literal::vec1(&flat)
                .reshape(&[rows as i64, cols as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))
        };
        let hidden = j.get("hidden")?.as_usize()?;
        let window = j.get("window")?.as_usize()?;
        let mut lits = Vec::new();
        match which {
            "lstm" => {
                let mut in_dim = 1usize;
                for l in j.get("layers")?.as_arr()? {
                    lits.push(lit2(l.get("wx")?, in_dim, 4 * hidden)?);
                    lits.push(lit2(l.get("wh")?, hidden, 4 * hidden)?);
                    lits.push(xla::Literal::vec1(&l.get("b")?.as_f32_flat()?));
                    in_dim = hidden;
                }
                lits.push(lit2(j.get("w_out")?, hidden, 1)?);
                lits.push(xla::Literal::vec1(&j.get("b_out")?.as_f32_flat()?));
            }
            "ff" => {
                let mut in_dim = window;
                for l in j.get("ff")?.as_arr()? {
                    let b = l.get("b")?.as_f32_flat()?;
                    lits.push(lit2(l.get("w")?, in_dim, b.len())?);
                    lits.push(xla::Literal::vec1(&b));
                    in_dim = b.len();
                }
            }
            other => bail!("unknown predictor {other}"),
        }
        Ok(lits)
    }

    /// Run a predictor artifact: normalized window -> normalized forecast.
    pub fn predict(&mut self, which: &str, window_norm: &[f32]) -> Result<f32> {
        let entry = self
            .manifest
            .predictors
            .get(which)
            .ok_or_else(|| anyhow!("unknown predictor {which}"))?
            .clone();
        if window_norm.len() != entry.window {
            bail!("window len {} != {}", window_norm.len(), entry.window);
        }
        if !self.predictor_execs.contains_key(which) {
            let exe = self.compile(&entry.path)?;
            self.predictor_execs.insert(which.to_string(), exe);
            let w = self.predictor_weight_literals(which)?;
            self.weights.insert(format!("__pred_{which}"), w);
        }
        let xl = xla::Literal::vec1(window_norm)
            .reshape(&[1, entry.window as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let mut args: Vec<&xla::Literal> =
            self.weights[&format!("__pred_{which}")].iter().collect();
        args.push(&xl);
        let exe = &self.predictor_execs[which];
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute predictor: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let tuple = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let v: Vec<f32> = tuple.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(v[0])
    }

    /// Map a catalog MsId to its manifest entry name.
    pub fn ms_name(cat: &crate::model::Catalog, ms_id: MsId) -> &'static str {
        cat.microservices[ms_id].name
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.execs.len() + self.predictor_execs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn manifest_loads() {
        let Some(dir) = art() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.slo_ms, 1000.0);
        assert_eq!(m.microservices.len(), 10);
        assert!(m.predictors.contains_key("lstm"));
        assert!(m.traces.contains_key("wits"));
    }

    #[test]
    fn pick_batch_rounds_up() {
        let Some(dir) = art() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_batch(1), 1);
        assert_eq!(m.pick_batch(3), 4);
        assert_eq!(m.pick_batch(17), 32);
        assert_eq!(m.pick_batch(1000), 32); // clamp to largest
    }

    #[test]
    fn weights_bin_shapes() {
        let Some(dir) = art() else { return };
        let m = Manifest::load(&dir).unwrap();
        let e = &m.microservices["POS"];
        let w = load_weights_bin(&dir.join(&e.weights_path), &e.layers).unwrap();
        assert_eq!(w.len(), e.layers.len());
        assert_eq!(w[0].0.len(), e.layers[0].0 .0 * e.layers[0].0 .1);
    }

    #[test]
    fn weights_bin_rejects_wrong_size() {
        let Some(dir) = art() else { return };
        let m = Manifest::load(&dir).unwrap();
        let e = &m.microservices["POS"];
        // deliberately wrong layer spec
        let bad = vec![((1usize, 1usize), 1usize)];
        assert!(load_weights_bin(&dir.join(&e.weights_path), &bad).is_err());
    }

    // Full PJRT execution tests live in rust/tests/test_runtime.rs
    // (integration) so unit tests stay client-free.
}
