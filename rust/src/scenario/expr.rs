//! Trace-expression language for scenario files.
//!
//! A `[trace.<name>]` section's `expr` key holds one expression that
//! builds a [`Trace`] from generators and composition operators:
//!
//! ```text
//! overlay(noise(wits, sigma=0.05, seed=9), flashcrowd(amp=800, start=300, width=45))
//! ```
//!
//! Grammar (whitespace-insensitive):
//!
//! ```text
//! expr  := ident | ident '(' args ')'
//! args  := ( arg (',' arg)* )?
//! arg   := ident '=' number        named numeric parameter
//!        | expr                    positional trace argument
//! ```
//!
//! A bare identifier references another defined trace or a built-in
//! workload name (resolved by the [`TraceResolver`]); a call is either a
//! generator (no trace arguments) or an operator (one or more trace
//! arguments). Generators default their length to the resolver's
//! scenario duration; pass `duration=<secs>` to override per-generator.
//!
//! | call | kind | parameters (default) |
//! |------|------|----------------------|
//! | `poisson(...)` | generator | `rate` (50), `duration` |
//! | `wiki(...)` | generator | `seed` (2025), `duration` |
//! | `wits(...)` | generator | `seed` (1316), `duration` |
//! | `azure(...)` | generator | `seed` (1), `duration` |
//! | `flashcrowd(...)` | generator | `base` (0), `amp` (500), `start` (duration/3), `width` (duration/10), `duration` |
//! | `overlay(a, b, ...)` | operator | — (element-wise sum, 2+ traces) |
//! | `splice(a, b, at=S)` | operator | `at` (required) |
//! | `ramp(a, ...)` | operator | `from` (0), `to` (1) |
//! | `noise(a, ...)` | operator | `sigma` (0.1), `seed` (1) |
//! | `scale(a, by=F)` | operator | `by` (required) |
//! | `resize(a, to=S)` | operator | `to` (required) |

use anyhow::{anyhow, bail, Result};

use crate::trace::Trace;

/// Every function name the evaluator understands (generators first,
/// then operators).
pub const FUNCTIONS: [&str; 11] = [
    "poisson",
    "wiki",
    "wits",
    "azure",
    "flashcrowd",
    "overlay",
    "splice",
    "ramp",
    "noise",
    "scale",
    "resize",
];

/// Resolves bare identifiers to traces and supplies the default
/// generator duration. Implemented by the scenario spec (which also
/// performs cycle detection across `[trace.*]` definitions).
pub trait TraceResolver {
    fn resolve(&mut self, name: &str) -> Result<Trace>;
    fn duration_s(&self) -> usize;
}

/// Parsed expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Bare identifier: a defined or built-in trace name.
    Ref(String),
    /// `func(args..., key=value...)`.
    Call {
        func: String,
        args: Vec<Expr>,
        params: Vec<(String, f64)>,
    },
}

// ---------------------------------------------------------------------
// lexer + parser
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Num(f64),
    LParen,
    RParen,
    Comma,
    Eq,
}

fn lex(src: &str) -> Result<Vec<Tok>> {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match c {
            c if c.is_whitespace() => i += 1,
            '(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            ',' => {
                toks.push(Tok::Comma);
                i += 1;
            }
            '=' => {
                toks.push(Tok::Eq);
                i += 1;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                toks.push(Tok::Ident(chars[start..i].iter().collect()));
            }
            c if c.is_ascii_digit() || c == '-' || c == '.' => {
                let start = i;
                i += 1;
                while i < chars.len()
                    && (chars[i].is_ascii_digit()
                        || matches!(chars[i], '.' | 'e' | 'E' | '+' | '-'))
                {
                    // '+'/'-' only continue a number right after an exponent
                    if matches!(chars[i], '+' | '-') && !matches!(chars[i - 1], 'e' | 'E') {
                        break;
                    }
                    i += 1;
                }
                let s: String = chars[start..i].iter().collect();
                let n: f64 = s
                    .parse()
                    .map_err(|_| anyhow!("bad number {s:?} in trace expression"))?;
                toks.push(Tok::Num(n));
            }
            other => bail!("unexpected character {other:?} in trace expression"),
        }
    }
    Ok(toks)
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expr(&mut self) -> Result<Expr> {
        match self.bump() {
            Some(Tok::Ident(name)) => {
                if self.peek() == Some(&Tok::LParen) {
                    self.pos += 1;
                    let (args, params) = self.args()?;
                    match self.bump() {
                        Some(Tok::RParen) => Ok(Expr::Call {
                            func: name,
                            args,
                            params,
                        }),
                        other => bail!("expected ')' closing {name}(...), got {other:?}"),
                    }
                } else {
                    Ok(Expr::Ref(name))
                }
            }
            other => bail!("expected a trace name or call, got {other:?}"),
        }
    }

    fn args(&mut self) -> Result<(Vec<Expr>, Vec<(String, f64)>)> {
        let mut args = Vec::new();
        let mut params = Vec::new();
        if self.peek() == Some(&Tok::RParen) {
            return Ok((args, params));
        }
        loop {
            // named parameter: Ident '=' Num — otherwise a trace expr
            let named = matches!(
                (self.toks.get(self.pos), self.toks.get(self.pos + 1)),
                (Some(Tok::Ident(_)), Some(Tok::Eq))
            );
            if named {
                let Some(Tok::Ident(key)) = self.bump() else { unreachable!("peeked ident") };
                self.pos += 1; // '='
                match self.bump() {
                    Some(Tok::Num(v)) => params.push((key, v)),
                    other => bail!("parameter {key}= expects a number, got {other:?}"),
                }
            } else {
                args.push(self.expr()?);
            }
            if self.peek() == Some(&Tok::Comma) {
                self.pos += 1;
            } else {
                break;
            }
        }
        Ok((args, params))
    }
}

/// Parse one trace expression into an [`Expr`] tree.
pub fn parse(src: &str) -> Result<Expr> {
    let toks = lex(src)?;
    if toks.is_empty() {
        bail!("empty trace expression");
    }
    let mut p = Parser { toks, pos: 0 };
    let e = p.expr()?;
    if p.pos != p.toks.len() {
        bail!("trailing tokens after trace expression: {:?}", &p.toks[p.pos..]);
    }
    Ok(e)
}

// ---------------------------------------------------------------------
// evaluation
// ---------------------------------------------------------------------

fn num(params: &[(String, f64)], key: &str, default: f64) -> f64 {
    params
        .iter()
        .rev()
        .find(|(k, _)| k == key)
        .map(|(_, v)| *v)
        .unwrap_or(default)
}

fn num_req(func: &str, params: &[(String, f64)], key: &str) -> Result<f64> {
    params
        .iter()
        .rev()
        .find(|(k, _)| k == key)
        .map(|(_, v)| *v)
        .ok_or_else(|| anyhow!("{func}() requires the {key}= parameter"))
}

fn check_keys(func: &str, params: &[(String, f64)], allowed: &[&str]) -> Result<()> {
    for (k, _) in params {
        if !allowed.contains(&k.as_str()) {
            bail!("{func}() has no parameter {k:?} (allowed: {})", allowed.join(", "));
        }
    }
    Ok(())
}

fn check_arity(func: &str, args: &[Expr], n: usize) -> Result<()> {
    if args.len() != n {
        bail!("{func}() expects {n} trace argument(s), got {}", args.len());
    }
    Ok(())
}

fn as_secs(func: &str, key: &str, v: f64) -> Result<usize> {
    if !v.is_finite() || v < 0.0 {
        bail!("{func}() {key}= must be a non-negative number of seconds, got {v}");
    }
    Ok(v as usize)
}

/// Evaluate an expression tree against a resolver.
pub fn eval(e: &Expr, r: &mut dyn TraceResolver) -> Result<Trace> {
    match e {
        Expr::Ref(name) => r.resolve(name),
        Expr::Call { func, args, params } => {
            let dur = r.duration_s();
            let gen_dur = |params: &[(String, f64)]| -> Result<usize> {
                let d = num(params, "duration", dur as f64);
                let d = as_secs(func, "duration", d)?;
                if d == 0 {
                    bail!("{func}() duration must be at least 1 s");
                }
                Ok(d)
            };
            match func.as_str() {
                // generators -------------------------------------------
                "poisson" => {
                    check_arity(func, args, 0)?;
                    check_keys(func, params, &["rate", "duration"])?;
                    Ok(Trace::poisson(num(params, "rate", 50.0), gen_dur(params)?))
                }
                "wiki" => {
                    check_arity(func, args, 0)?;
                    check_keys(func, params, &["seed", "duration"])?;
                    Ok(Trace::wiki(gen_dur(params)?, num(params, "seed", 2025.0) as u64))
                }
                "wits" => {
                    check_arity(func, args, 0)?;
                    check_keys(func, params, &["seed", "duration"])?;
                    Ok(Trace::wits(gen_dur(params)?, num(params, "seed", 1316.0) as u64))
                }
                "azure" => {
                    check_arity(func, args, 0)?;
                    check_keys(func, params, &["seed", "duration"])?;
                    Ok(Trace::azure(gen_dur(params)?, num(params, "seed", 1.0) as u64))
                }
                "flashcrowd" => {
                    check_arity(func, args, 0)?;
                    check_keys(func, params, &["base", "amp", "start", "width", "duration"])?;
                    let d = gen_dur(params)?;
                    let start = as_secs(func, "start", num(params, "start", (d / 3) as f64))?;
                    let width = num(params, "width", (d / 10).max(1) as f64);
                    let width = as_secs(func, "width", width)?;
                    Ok(Trace::flashcrowd(
                        d,
                        num(params, "base", 0.0),
                        num(params, "amp", 500.0),
                        start,
                        width,
                    ))
                }
                // operators --------------------------------------------
                "overlay" => {
                    check_keys(func, params, &[])?;
                    if args.len() < 2 {
                        bail!("overlay() expects at least 2 trace arguments, got {}", args.len());
                    }
                    let mut acc = eval(&args[0], r)?;
                    for a in &args[1..] {
                        let t = eval(a, r)?;
                        acc = acc.overlay(&t);
                    }
                    Ok(acc)
                }
                "splice" => {
                    check_arity(func, args, 2)?;
                    check_keys(func, params, &["at"])?;
                    let at = as_secs(func, "at", num_req(func, params, "at")?)?;
                    let a = eval(&args[0], r)?;
                    let b = eval(&args[1], r)?;
                    Ok(a.splice(&b, at))
                }
                "ramp" => {
                    check_arity(func, args, 1)?;
                    check_keys(func, params, &["from", "to"])?;
                    let t = eval(&args[0], r)?;
                    Ok(t.ramp(num(params, "from", 0.0), num(params, "to", 1.0)))
                }
                "noise" => {
                    check_arity(func, args, 1)?;
                    check_keys(func, params, &["sigma", "seed"])?;
                    let t = eval(&args[0], r)?;
                    Ok(t.noise(num(params, "sigma", 0.1), num(params, "seed", 1.0) as u64))
                }
                "scale" => {
                    check_arity(func, args, 1)?;
                    check_keys(func, params, &["by"])?;
                    let t = eval(&args[0], r)?;
                    Ok(t.scaled(num_req(func, params, "by")?))
                }
                "resize" => {
                    check_arity(func, args, 1)?;
                    check_keys(func, params, &["to"])?;
                    let to = as_secs(func, "to", num_req(func, params, "to")?)?;
                    let t = eval(&args[0], r)?;
                    Ok(t.resized(to))
                }
                other => {
                    bail!("unknown trace function {other:?} (known: {})", FUNCTIONS.join(", "))
                }
            }
        }
    }
}

/// Validate every call in the tree statically — known function name,
/// trace-argument arity, parameter keys, required parameters — so
/// scenario files fail at load time, not mid-sweep. (Value-range checks
/// like `duration >= 1` still happen at evaluation.)
pub fn check_funcs(e: &Expr) -> Result<()> {
    let Expr::Call { func, args, params } = e else {
        return Ok(());
    };
    let spec: (usize, usize, &[&str], &[&str]) = match func.as_str() {
        "poisson" => (0, 0, &["rate", "duration"], &[]),
        "wiki" | "wits" | "azure" => (0, 0, &["seed", "duration"], &[]),
        "flashcrowd" => (0, 0, &["base", "amp", "start", "width", "duration"], &[]),
        "overlay" => (2, usize::MAX, &[], &[]),
        "splice" => (2, 2, &["at"], &["at"]),
        "ramp" => (1, 1, &["from", "to"], &[]),
        "noise" => (1, 1, &["sigma", "seed"], &[]),
        "scale" => (1, 1, &["by"], &["by"]),
        "resize" => (1, 1, &["to"], &["to"]),
        other => {
            bail!("unknown trace function {other:?} (known: {})", FUNCTIONS.join(", "))
        }
    };
    let (lo, hi, allowed, required) = spec;
    if args.len() < lo || args.len() > hi {
        if lo == hi {
            bail!("{func}() expects {lo} trace argument(s), got {}", args.len());
        }
        bail!("{func}() expects at least {lo} trace arguments, got {}", args.len());
    }
    check_keys(func, params, allowed)?;
    for key in required {
        num_req(func, params, key)?;
    }
    for a in args {
        check_funcs(a)?;
    }
    Ok(())
}

/// Every bare-identifier reference in the tree (for eager validation of
/// scenario files — undefined names fail at parse time, not mid-sweep).
pub fn refs(e: &Expr) -> Vec<&str> {
    let mut out = Vec::new();
    fn walk<'a>(e: &'a Expr, out: &mut Vec<&'a str>) {
        match e {
            Expr::Ref(name) => out.push(name.as_str()),
            Expr::Call { args, .. } => {
                for a in args {
                    walk(a, out);
                }
            }
        }
    }
    walk(e, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    struct FixedResolver;

    impl TraceResolver for FixedResolver {
        fn resolve(&mut self, name: &str) -> Result<Trace> {
            match name {
                "base" => Ok(Trace::poisson(10.0, 10)),
                other => bail!("unknown trace {other:?}"),
            }
        }
        fn duration_s(&self) -> usize {
            10
        }
    }

    fn run(src: &str) -> Result<Trace> {
        eval(&parse(src)?, &mut FixedResolver)
    }

    #[test]
    fn parses_nested_calls_and_params() {
        let e = parse("overlay(noise(base, sigma=0.2, seed=7), flashcrowd(amp=80))").unwrap();
        let Expr::Call { func, args, params } = &e else {
            panic!("expected call")
        };
        assert_eq!(func, "overlay");
        assert_eq!(args.len(), 2);
        assert!(params.is_empty());
        assert_eq!(refs(&e), vec!["base"]);
    }

    #[test]
    fn evaluates_generators_at_context_duration() {
        let t = run("poisson(rate=4)").unwrap();
        assert_eq!(t.duration_s(), 10);
        assert_eq!(t.rate_per_s[0], 4.0);
        let t = run("poisson(rate=4, duration=25)").unwrap();
        assert_eq!(t.duration_s(), 25);
    }

    #[test]
    fn evaluates_operators() {
        let t = run("overlay(base, flashcrowd(base=0, amp=90, start=2, width=3))").unwrap();
        assert_eq!(t.rate_per_s[0], 10.0);
        assert_eq!(t.rate_per_s[2], 100.0);
        assert_eq!(t.rate_per_s[5], 10.0);
        let t = run("scale(base, by=3)").unwrap();
        assert_eq!(t.rate_per_s[0], 30.0);
        let t = run("splice(base, poisson(rate=1), at=2)").unwrap();
        assert_eq!(t.duration_s(), 12);
        let t = run("resize(base, to=4)").unwrap();
        assert_eq!(t.duration_s(), 4);
    }

    #[test]
    fn deterministic_evaluation() {
        let a = run("noise(azure(seed=3), sigma=0.2, seed=5)").unwrap();
        let b = run("noise(azure(seed=3), sigma=0.2, seed=5)").unwrap();
        assert_eq!(a.rate_per_s, b.rate_per_s);
    }

    #[test]
    fn rejects_malformed_expressions() {
        assert!(parse("").is_err());
        assert!(parse("overlay(base,").is_err());
        assert!(parse("overlay base").is_err());
        assert!(parse("5(base)").is_err());
        assert!(parse("noise(base, sigma=oops)").is_err());
        assert!(parse("base extra").is_err());
    }

    #[test]
    fn static_call_checks() {
        let bad = [
            "noise(frob(), sigma=1)", // unknown function, nested
            "overlay(base)",          // arity
            "splice(base, base)",     // missing required at=
            "scale(base)",            // missing required by=
            "noise(base, sgima=0.1)", // typo'd key
            "poisson(base)",          // generator takes no traces
        ];
        for src in bad {
            assert!(check_funcs(&parse(src).unwrap()).is_err(), "{src}");
        }
        // refs are not checked here, only call shapes
        assert!(check_funcs(&parse("noise(ghost, sigma=1)").unwrap()).is_ok());
        assert!(check_funcs(&parse("overlay(a, b, c)").unwrap()).is_ok());
    }

    #[test]
    fn rejects_semantic_errors() {
        assert!(run("frobnicate(base)").is_err());
        assert!(run("overlay(base)").is_err()); // arity
        assert!(run("poisson(base)").is_err()); // generator takes no traces
        assert!(run("noise(base, sgima=0.1)").is_err()); // typo'd key
        assert!(run("scale(base)").is_err()); // missing required by=
        assert!(run("splice(base, base, at=-3)").is_err());
        assert!(run("nope").is_err()); // unresolved reference
        assert!(run("poisson(duration=0)").is_err());
    }
}
