//! Sharded parallel execution of a scenario's sweep matrix.
//!
//! Workers pull cell indices from a shared atomic counter, so load
//! balances regardless of per-cell cost; results land in a slot vector
//! indexed by cell, so output order is matrix order no matter which
//! worker ran what. Every cell is an independent, fully-seeded
//! simulation (its own `Pcg` stream from the cell seed; traces prebuilt
//! and shared read-only), so the parallel sweep is byte-identical to the
//! serial one.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::{Cell, CellResult, ScenarioSpec};
use crate::config::{RmConfig, SystemConfig};
use crate::model::Catalog;
use crate::obs::ObsConfig;
use crate::sim::sharded::run_sharded_summarized;
use crate::sim::{run_summarized_full, SimParams};
use crate::trace::Trace;

/// Run one cell of the matrix. Identical to `experiments::run_policy`
/// modulo the spec's cluster/RM/warm-up knobs (the built-in grid
/// scenarios pin that equivalence in `rust/tests/test_scenario.rs`).
fn run_cell(
    spec: &ScenarioSpec,
    traces: &BTreeMap<String, Trace>,
    cell: &Cell,
    obs: Option<ObsConfig>,
    optimality: bool,
) -> CellResult {
    let cat = Catalog::paper();
    let mut rm = RmConfig::paper(cell.policy);
    rm.apply_doc(&spec.rm_overrides)
        .expect("rm overrides validated at parse time");
    let cfg = SystemConfig {
        cluster: spec.cluster.clone(),
        rm,
        artifacts_dir: spec.artifacts_dir.clone(),
        seed: cell.seed,
    };
    let chains = cat
        .mix(&cell.mix)
        .expect("mix validated at parse time")
        .chains
        .clone();
    let trace = traces[&cell.trace].clone();
    let warmup = spec.warmup_for(trace.duration_s());
    let params = SimParams {
        cfg,
        chains,
        trace,
        drain_s: spec.drain_s,
    };
    if cell.shards > 1 {
        // sharded engine: same workload, chain-hash partitioned across
        // `cell.shards` EngineCores in deterministic lockstep
        let (run, summary) = run_sharded_summarized(params, cell.shards, warmup, obs, optimality)
            .expect("shard count validated at parse time");
        return CellResult {
            cell: cell.clone(),
            summary,
            obs: run.report,
        };
    }
    let (_, summary, report) = run_summarized_full(params, warmup, obs, optimality);
    CellResult {
        cell: cell.clone(),
        summary,
        obs: report,
    }
}

/// Execute the full sweep matrix, sharded across `threads` workers
/// (clamped to [1, #cells]; 1 = serial). Results come back in matrix
/// order and are byte-identical for any thread count.
pub fn run_scenario(spec: &ScenarioSpec, threads: usize) -> Result<Vec<CellResult>> {
    run_scenario_obs(spec, threads, None)
}

/// [`run_scenario`] with an optional per-cell observability collector —
/// the plumbing behind `fifer scenario run --slo-timeline`. Each cell's
/// [`crate::obs::ObsReport`] is a pure function of its seed (virtual
/// time, no clocks), so the sweep stays byte-identical across thread
/// counts even with collection on.
pub fn run_scenario_obs(
    spec: &ScenarioSpec,
    threads: usize,
    obs: Option<ObsConfig>,
) -> Result<Vec<CellResult>> {
    run_scenario_full(spec, threads, obs, false)
}

/// [`run_scenario_obs`] plus per-cell optimality-gap analysis — the
/// plumbing behind `fifer scenario run --optimality`. Each cell's
/// `optimality` block is computed from that cell's own invocation log
/// (a pure observer of the run, itself a pure function of the cell
/// seed), so the sweep stays byte-identical across thread counts with
/// the estimators on.
pub fn run_scenario_full(
    spec: &ScenarioSpec,
    threads: usize,
    obs: Option<ObsConfig>,
    optimality: bool,
) -> Result<Vec<CellResult>> {
    let traces = spec.build_traces()?;
    let cells = spec.cells();
    if cells.is_empty() {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, cells.len());
    if threads == 1 {
        return Ok(cells
            .iter()
            .map(|c| run_cell(spec, &traces, c, obs, optimality))
            .collect());
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<CellResult>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let r = run_cell(spec, &traces, &cells[i], obs, optimality);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    Ok(slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("worker thread did not panic")
                .expect("every cell index was claimed and completed")
        })
        .collect())
}
