//! Declarative scenarios: TOML workload specs, composable traces, and a
//! sharded parallel sweep runner.
//!
//! The paper's evaluation is a fixed grid — three traces × three mixes ×
//! five RMs. A *scenario file* makes that grid (and any other) data: it
//! declares a trace set, a workload-mix set, a policy set, a seed list,
//! and cluster/RM knobs, and the runner executes the full cross product,
//! one simulation per **cell**. The two paper grids ship as built-in
//! scenario files (`prototype-grid`, `macro-grid` — see [`BUILTINS`]),
//! proving the old `experiments::run_prototype` / `run_macro` drivers
//! are special cases; `rust/tests/test_scenario.rs` pins the cell
//! results byte-identical to those drivers.
//!
//! # File format
//!
//! A TOML-subset document (parsed by [`crate::config::toml`]) with one
//! required `[scenario]` section, optional `[cluster]` / `[rm]` override
//! sections (same keys as config files), and any number of
//! `[trace.<name>]` sections defining composed traces:
//!
//! | `[scenario]` key | default | meaning |
//! |------------------|---------|---------|
//! | `name`           | `"unnamed"` | label echoed into results |
//! | `duration_s`     | 600     | generator trace length (s); each cell's horizon and warm-up follow its trace's actual length |
//! | `drain_s`        | 60      | post-trace drain window (s) |
//! | `warmup_frac`    | 0.5     | fraction of the run excluded as warm-up |
//! | `warmup_cap_s`   | 700     | warm-up exclusion cap (s) |
//! | `seeds`          | `[42]`  | one sim per seed per cell |
//! | `traces`         | —       | trace names (required, non-empty) |
//! | `mixes`          | `["Heavy"]` | workload-mix names (Table 5) |
//! | `policies`       | `["all"]` | policy names; `"all"` / `"paper"` expand |
//! | `shards`         | `[1]`   | coordinator shard counts to sweep (docs/DESIGN.md §Sharding) |
//! | `artifacts_dir`  | `"artifacts"` | where exported traces/weights live |
//!
//! Trace names resolve to a `[trace.<name>]` definition or to a built-in
//! workload: `poisson`, `wiki`, `wits` (identical to the experiment
//! drivers', artifact-preferring), `azure`, `flashcrowd`. A definition's
//! `expr` key is a composition expression — see [`expr`] for the full
//! language:
//!
//! ```text
//! [trace.crowd]
//! expr = "overlay(wits, flashcrowd(base=0, amp=900, start=300, width=45))"
//! ```
//!
//! # Determinism
//!
//! Every cell is an independent simulation seeded from its own
//! `(seed → Pcg)` stream: traces are built once up front
//! (deterministically), and no randomness is shared across cells, so
//! [`run_scenario`] produces **byte-identical** JSON/CSV output whether
//! the sweep runs serially or sharded across N worker threads.
//!
//! # Example
//!
//! ```
//! let spec = fifer::scenario::ScenarioSpec::parse(r#"
//! [scenario]
//! name = "demo"
//! duration_s = 120
//! seeds = [7, 42]
//! traces = ["burst"]
//! mixes = ["Heavy"]
//! policies = ["Bline", "Fifer"]
//!
//! [trace.burst]
//! expr = "overlay(poisson(rate=20), flashcrowd(amp=60, start=40, width=10))"
//! "#).unwrap();
//! assert_eq!(spec.cells().len(), 4); // 1 trace x 1 mix x 2 policies x 2 seeds
//! let traces = spec.build_traces().unwrap();
//! assert_eq!(traces["burst"].duration_s(), 120);
//! assert_eq!(traces["burst"].rate_per_s[45], 80.0);
//! ```

pub mod expr;
mod runner;

pub use runner::{run_scenario, run_scenario_full, run_scenario_obs};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::toml::{self, TomlDoc, TomlValue};
use crate::config::{ClusterConfig, Policy, RmConfig, TomlSection};
use crate::experiments::TraceKind;
use crate::metrics::Summary;
use crate::model::Catalog;
use crate::obs::{self, ObsReport};
use crate::trace::Trace;
use crate::util::json::Json;
use crate::util::{secs, Micros, MICROS_PER_S};

/// Built-in workload names usable in `traces = [...]` and expressions
/// without a `[trace.*]` definition.
pub const BUILTIN_TRACES: [&str; 5] = ["poisson", "wiki", "wits", "azure", "flashcrowd"];

/// Built-in scenario files: `(name, toml_text, about)`. The first two
/// re-express the paper's §6.1/§6.2 experiment grids declaratively.
pub const BUILTINS: [(&str, &str, &str); 4] = [
    (
        "prototype-grid",
        include_str!("../../../examples/scenarios/prototype_grid.toml"),
        "§6.1 prototype grid: Poisson λ=50 × every mix × every registered RM",
    ),
    (
        "macro-grid",
        include_str!("../../../examples/scenarios/macro_grid.toml"),
        "§6.2 macro grid: Wiki/WITS on the 2500-core cluster × every mix × every RM",
    ),
    (
        "flashcrowd",
        include_str!("../../../examples/scenarios/flashcrowd.toml"),
        "composed-workload demo: ramped WITS + flash crowd + Azure heavy tail",
    ),
    (
        "shard-sweep",
        include_str!("../../../examples/scenarios/shard_sweep.toml"),
        "sharded-coordinator sweep: quality vs shard count on a flash crowd",
    ),
];

/// Look up a built-in scenario file's TOML text by name.
pub fn builtin(name: &str) -> Option<&'static str> {
    BUILTINS
        .iter()
        .find(|(n, _, _)| n.eq_ignore_ascii_case(name))
        .map(|(_, text, _)| *text)
}

/// One point of the sweep matrix: a single simulation to run.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    /// Position in matrix order (trace-major, seed-minor); results are
    /// always reported in this order regardless of worker scheduling.
    pub index: usize,
    pub trace: String,
    pub mix: String,
    pub policy: Policy,
    pub seed: u64,
    /// Coordinator shard count for this cell (1 = the classic unsharded
    /// engine; >1 routes through [`crate::sim::sharded`]).
    pub shards: usize,
}

/// The completed result of one cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    pub summary: Summary,
    /// Virtual-time SLO timeline, populated only by
    /// [`run_scenario_obs`] (the `--slo-timeline` path) — `None` keeps
    /// the plain sweep free of collector overhead.
    pub obs: Option<ObsReport>,
}

/// A parsed, validated scenario file. All fields are public so callers
/// (tests, sweeps-of-sweeps) can derive variants programmatically.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub duration_s: usize,
    pub drain_s: f64,
    pub warmup_frac: f64,
    pub warmup_cap_s: f64,
    pub seeds: Vec<u64>,
    pub traces: Vec<String>,
    pub mixes: Vec<String>,
    pub policies: Vec<Policy>,
    /// Coordinator shard counts to sweep (default `[1]`); each count
    /// multiplies the matrix like a policy or seed does.
    pub shard_counts: Vec<usize>,
    /// `[trace.<name>]` definitions: name → expression source.
    pub trace_defs: BTreeMap<String, String>,
    pub cluster: ClusterConfig,
    /// Raw `[rm]` section, re-applied on top of each cell's per-policy
    /// `RmConfig::paper` defaults (validated at parse time).
    pub rm_overrides: TomlSection,
    pub artifacts_dir: String,
}

const SCENARIO_KEYS: [&str; 11] = [
    "name",
    "duration_s",
    "drain_s",
    "warmup_frac",
    "warmup_cap_s",
    "seeds",
    "traces",
    "mixes",
    "policies",
    "shards",
    "artifacts_dir",
];

impl ScenarioSpec {
    /// Parse and validate a scenario document from TOML text.
    pub fn parse(text: &str) -> Result<ScenarioSpec> {
        let doc = toml::parse(text)?;
        ScenarioSpec::from_doc(&doc)
    }

    /// Load a scenario file from disk.
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        ScenarioSpec::parse(&text)
            .with_context(|| format!("parsing scenario {}", path.display()))
    }

    /// Build a spec from a parsed document, validating every name and
    /// expression eagerly so errors surface at load time, not mid-sweep.
    pub fn from_doc(doc: &TomlDoc) -> Result<ScenarioSpec> {
        // reject unknown sections (root keys and typo'd section names)
        for (section, keys) in doc {
            let known = section == "scenario"
                || section == "cluster"
                || section == "rm"
                || section.starts_with("trace.");
            if section.is_empty() {
                if let Some(k) = keys.keys().next() {
                    bail!("key {k:?} must live inside the [scenario] section");
                }
            } else if !known {
                bail!(
                    "unknown section [{section}] (expected [scenario], [cluster], [rm] \
                     or [trace.<name>])"
                );
            }
        }
        let sec = doc
            .get("scenario")
            .ok_or_else(|| anyhow!("scenario file needs a [scenario] section"))?;
        for k in sec.keys() {
            if !SCENARIO_KEYS.contains(&k.as_str()) {
                bail!("unknown [scenario] key {k:?} (known: {})", SCENARIO_KEYS.join(", "));
            }
        }

        let duration_s = get_num(sec, "duration_s", 600.0)? as usize;
        if duration_s == 0 {
            bail!("[scenario] duration_s must be at least 1");
        }
        let drain_s = get_num(sec, "drain_s", 60.0)?;
        if !(0.0..=3600.0).contains(&drain_s) {
            bail!("[scenario] drain_s must be in [0, 3600], got {drain_s}");
        }
        let warmup_frac = get_num(sec, "warmup_frac", 0.5)?;
        if !(0.0..=1.0).contains(&warmup_frac) {
            bail!("[scenario] warmup_frac must be in [0, 1], got {warmup_frac}");
        }
        let warmup_cap_s = get_num(sec, "warmup_cap_s", 700.0)?;

        let seeds: Vec<u64> = match sec.get("seeds") {
            None => vec![42],
            Some(v) => num_list(v)?
                .into_iter()
                .map(|x| {
                    if x < 0.0 || x.fract() != 0.0 {
                        bail!("[scenario] seeds must be non-negative integers, got {x}");
                    }
                    Ok(x as u64)
                })
                .collect::<Result<_>>()?,
        };
        if seeds.is_empty() {
            bail!("[scenario] seeds must not be empty");
        }

        let traces = match sec.get("traces") {
            Some(v) => str_list(v)?,
            None => bail!("[scenario] needs a traces = [...] list"),
        };
        if traces.is_empty() {
            bail!("[scenario] traces must not be empty");
        }

        let cat = Catalog::paper();
        let mixes = match sec.get("mixes") {
            Some(v) => str_list(v)?,
            None => vec!["Heavy".to_string()],
        };
        if mixes.is_empty() {
            bail!("[scenario] mixes must not be empty");
        }
        for m in &mixes {
            if cat.mix(m).is_none() {
                let known: Vec<&str> = cat.mixes.iter().map(|x| x.name).collect();
                bail!("unknown mix {m:?} (known: {})", known.join(", "));
            }
        }

        let policy_names = match sec.get("policies") {
            Some(v) => str_list(v)?,
            None => vec!["all".to_string()],
        };
        let mut policies = Vec::new();
        for name in &policy_names {
            match name.to_ascii_lowercase().as_str() {
                "all" => policies.extend(Policy::ALL),
                "paper" => policies.extend(Policy::PAPER),
                _ => policies.push(Policy::from_name(name)?),
            }
        }
        if policies.is_empty() {
            bail!("[scenario] policies must not be empty");
        }

        // [trace.<name>] definitions
        let mut trace_defs: BTreeMap<String, String> = BTreeMap::new();
        for (section, keys) in doc {
            let Some(name) = section.strip_prefix("trace.") else {
                continue;
            };
            // identifier names only: expressions reference them by ident
            // syntax, and CSV rows embed them unquoted
            let ident = !name.is_empty()
                && name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_');
            if !ident {
                bail!(
                    "bad trace name [trace.{name}] — names must be identifiers \
                     (letters, digits, underscores)"
                );
            }
            for k in keys.keys() {
                if k != "expr" {
                    bail!("[trace.{name}] has unknown key {k:?} (only expr = \"...\")");
                }
            }
            let src = keys
                .get("expr")
                .ok_or_else(|| anyhow!("[trace.{name}] needs an expr = \"...\" key"))?
                .as_str()?
                .to_string();
            trace_defs.insert(name.to_string(), src);
        }
        // every expression parses, every call names a known function,
        // and every reference resolves
        for (name, src) in &trace_defs {
            let ast = expr::parse(src).with_context(|| format!("[trace.{name}] expr"))?;
            expr::check_funcs(&ast).with_context(|| format!("[trace.{name}] expr"))?;
            for r in expr::refs(&ast) {
                if !trace_defs.contains_key(r) && !BUILTIN_TRACES.contains(&r) {
                    bail!("[trace.{name}] references unknown trace {r:?}");
                }
            }
        }
        for t in &traces {
            if !trace_defs.contains_key(t.as_str()) && !BUILTIN_TRACES.contains(&t.as_str()) {
                bail!(
                    "[scenario] traces lists {t:?}, which is neither a [trace.{t}] \
                     definition nor a built-in ({})",
                    BUILTIN_TRACES.join(", ")
                );
            }
        }

        // cluster + rm overrides (validated now — including unknown-key
        // typos, which config files tolerate but sweeps must not —
        // applied per cell)
        let mut cluster = ClusterConfig::prototype();
        if let Some(c) = doc.get("cluster") {
            for k in c.keys() {
                if !ClusterConfig::DOC_KEYS.contains(&k.as_str()) {
                    bail!(
                        "unknown [cluster] key {k:?} (known: {})",
                        ClusterConfig::DOC_KEYS.join(", ")
                    );
                }
            }
            if let Some(v) = c.get("preset") {
                cluster = ClusterConfig::preset(v.as_str()?)?;
            }
            cluster.apply_doc(c)?;
        }
        let rm_overrides = doc.get("rm").cloned().unwrap_or_default();
        for k in rm_overrides.keys() {
            if !RmConfig::DOC_KEYS.contains(&k.as_str()) {
                bail!("unknown [rm] key {k:?} (known: {})", RmConfig::DOC_KEYS.join(", "));
            }
        }
        RmConfig::paper(Policy::Fifer).apply_doc(&rm_overrides)?;

        // shard counts are validated against the (already-overridden)
        // cluster: every shard needs at least one node of capacity
        let shard_counts: Vec<usize> = match sec.get("shards") {
            None => vec![1],
            Some(v) => num_list(v)?
                .into_iter()
                .map(|x| {
                    if x < 1.0 || x.fract() != 0.0 {
                        bail!("[scenario] shards must be positive integers, got {x}");
                    }
                    let n = x as usize;
                    if n > cluster.nodes {
                        bail!(
                            "[scenario] shards = {n} exceeds cluster nodes ({}): \
                             every shard needs at least one node",
                            cluster.nodes
                        );
                    }
                    Ok(n)
                })
                .collect::<Result<_>>()?,
        };
        if shard_counts.is_empty() {
            bail!("[scenario] shards must not be empty");
        }

        Ok(ScenarioSpec {
            name: get_str(sec, "name", "unnamed")?,
            duration_s,
            drain_s,
            warmup_frac,
            warmup_cap_s,
            seeds,
            traces,
            mixes,
            policies,
            shard_counts,
            trace_defs,
            cluster,
            rm_overrides,
            artifacts_dir: get_str(sec, "artifacts_dir", "artifacts")?,
        })
    }

    /// Expand the sweep matrix in deterministic order: traces (major) ×
    /// mixes × policies × seeds × shard counts (minor).
    pub fn cells(&self) -> Vec<Cell> {
        let mut out = Vec::new();
        for trace in &self.traces {
            for mix in &self.mixes {
                for &policy in &self.policies {
                    for &seed in &self.seeds {
                        for &shards in &self.shard_counts {
                            out.push(Cell {
                                index: out.len(),
                                trace: trace.clone(),
                                mix: mix.clone(),
                                policy,
                                seed,
                                shards,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Steady-state cutoff (µs) for a trace of `trace_duration_s`
    /// seconds: jobs arriving before this are excluded from summaries —
    /// same rule as `experiments::run_policy`. Cells compute it from
    /// each trace's *actual* length, since expressions (`resize`,
    /// `splice`, `duration=`) may deviate from `duration_s`.
    pub fn warmup_for(&self, trace_duration_s: usize) -> Micros {
        secs((trace_duration_s as f64 * self.warmup_frac).min(self.warmup_cap_s))
    }

    /// [`ScenarioSpec::warmup_for`] at the nominal `duration_s` (what
    /// every built-in generator trace uses).
    pub fn warmup(&self) -> Micros {
        self.warmup_for(self.duration_s)
    }

    /// Build every workload trace once, in name order. Cells clone from
    /// this map, so trace construction cost is paid once per sweep and
    /// parallel workers share identical inputs.
    pub fn build_traces(&self) -> Result<BTreeMap<String, Trace>> {
        let mut out = BTreeMap::new();
        for name in &self.traces {
            if !out.contains_key(name) {
                let t = self.build_trace(name, &mut Vec::new())?;
                out.insert(name.clone(), t);
            }
        }
        Ok(out)
    }

    /// Resolve one trace name (definition or built-in), detecting
    /// definition cycles via the resolution stack.
    fn build_trace(&self, name: &str, stack: &mut Vec<String>) -> Result<Trace> {
        if stack.iter().any(|s| s == name) {
            stack.push(name.to_string());
            bail!(
                "trace {name:?} is defined in terms of itself ({})",
                stack.join(" -> ")
            );
        }
        if let Some(src) = self.trace_defs.get(name) {
            stack.push(name.to_string());
            let ast = expr::parse(src).with_context(|| format!("[trace.{name}] expr"))?;
            let mut resolver = SpecResolver {
                spec: self,
                stack: &mut *stack,
            };
            let mut t = expr::eval(&ast, &mut resolver).with_context(|| format!("[trace.{name}]"))?;
            stack.pop();
            t.name = name.to_string();
            Ok(t)
        } else {
            self.builtin_trace(name)
        }
    }

    /// The built-in workloads. `poisson` / `wiki` / `wits` go through
    /// [`TraceKind::build`], i.e. they prefer the Python-exported
    /// artifact and fall back to the seeded generator — exactly what the
    /// experiment drivers run, which is what makes the built-in grid
    /// scenarios byte-identical to `run_prototype` / `run_macro`.
    fn builtin_trace(&self, name: &str) -> Result<Trace> {
        match name {
            "poisson" => Ok(TraceKind::Poisson.build(self.duration_s, &self.artifacts_dir)),
            "wiki" => Ok(TraceKind::Wiki.build(self.duration_s, &self.artifacts_dir)),
            "wits" => Ok(TraceKind::Wits.build(self.duration_s, &self.artifacts_dir)),
            "azure" => Ok(Trace::azure(self.duration_s, 1)),
            "flashcrowd" => Ok(Trace::flashcrowd(
                self.duration_s,
                0.0,
                500.0,
                self.duration_s / 3,
                (self.duration_s / 10).max(1),
            )),
            other => bail!("unknown trace {other:?}"),
        }
    }
}

struct SpecResolver<'a, 'b> {
    spec: &'a ScenarioSpec,
    stack: &'b mut Vec<String>,
}

impl expr::TraceResolver for SpecResolver<'_, '_> {
    fn resolve(&mut self, name: &str) -> Result<Trace> {
        self.spec.build_trace(name, self.stack)
    }

    fn duration_s(&self) -> usize {
        self.spec.duration_s
    }
}

// ---------------------------------------------------------------------
// result emission
// ---------------------------------------------------------------------

/// Render sweep results as one JSON document (cells in matrix order).
/// Byte-deterministic: object keys are sorted by the writer. The
/// `warmup_s` header is the nominal (duration_s-length) cutoff; cells
/// whose trace expressions change the horizon scale it accordingly.
pub fn results_json(spec: &ScenarioSpec, results: &[CellResult]) -> Json {
    let cells = results
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("trace", Json::Str(r.cell.trace.clone())),
                ("mix", Json::Str(r.cell.mix.clone())),
                ("policy", Json::Str(r.cell.policy.name().to_string())),
                ("seed", Json::Num(r.cell.seed as f64)),
            ];
            // unsharded sweeps stay byte-identical to pre-sharding output
            if r.cell.shards != 1 {
                fields.push(("shards", Json::Num(r.cell.shards as f64)));
            }
            fields.push(("summary", r.summary.to_json()));
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("scenario", Json::Str(spec.name.clone())),
        ("duration_s", Json::Num(spec.duration_s as f64)),
        ("warmup_s", Json::Num(spec.warmup() as f64 / MICROS_PER_S as f64)),
        ("cells", Json::Arr(cells)),
    ])
}

/// Render an observability sweep (from [`run_scenario_obs`]) as one
/// JSON document: per cell, the same `{history, summary}` timeline
/// payload the live `/metrics` endpoints serve, keyed by the cell
/// coordinates. Byte-deterministic for a fixed spec regardless of
/// `--threads`, which `rust/tests/test_obs.rs` pins.
pub fn results_obs_json(spec: &ScenarioSpec, results: &[CellResult]) -> Json {
    let cells = results
        .iter()
        .map(|r| {
            let timeline = match &r.obs {
                Some(report) => report.timeline_json(),
                None => Json::Null,
            };
            Json::obj(vec![
                ("trace", Json::Str(r.cell.trace.clone())),
                ("mix", Json::Str(r.cell.mix.clone())),
                ("policy", Json::Str(r.cell.policy.name().to_string())),
                ("seed", Json::Num(r.cell.seed as f64)),
                ("timeline", timeline),
            ])
        })
        .collect();
    Json::obj(vec![
        ("scenario", Json::Str(spec.name.clone())),
        ("cells", Json::Arr(cells)),
    ])
}

/// Render a trace-enabled sweep (from [`run_scenario_obs`] with
/// `ObsConfig::trace_sample > 0`) as one merged Chrome trace-event
/// document (`--trace-out`): each cell becomes a process (`pid` =
/// matrix position + 1, labeled `trace/mix/policy/seed` via
/// `process_name` metadata) holding its sampled request span trees and
/// monitor-decision spans. Loadable in `chrome://tracing` / Perfetto.
/// Byte-deterministic for a fixed spec regardless of `--threads` —
/// sampling is seeded per cell and cells are emitted in matrix order.
pub fn results_trace_json(spec: &ScenarioSpec, results: &[CellResult]) -> Json {
    let mut events = Vec::new();
    for (i, r) in results.iter().enumerate() {
        let pid = (i + 1) as u64;
        let c = &r.cell;
        let label = format!("{}/{}/{} seed={}", c.trace, c.mix, c.policy.name(), c.seed);
        events.push(obs::trace::process_meta(pid, &label));
        if let Some(report) = &r.obs {
            report.trace_events(pid, None, &mut events);
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("scenario", Json::Str(spec.name.clone())),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Render sweep results as CSV: one header line, one row per cell.
pub fn results_csv(results: &[CellResult]) -> String {
    use std::fmt::Write as _;
    let mut s = String::from("trace,mix,policy,seed,");
    s.push_str(&Summary::CSV_FIELDS.join(","));
    s.push('\n');
    for r in results {
        let c = &r.cell;
        let _ = write!(s, "{},{},{},{},", c.trace, c.mix, c.policy.name(), c.seed);
        s.push_str(&r.summary.csv_row());
        s.push('\n');
    }
    s
}

// ---------------------------------------------------------------------
// toml helpers
// ---------------------------------------------------------------------

fn get_str(sec: &TomlSection, key: &str, default: &str) -> Result<String> {
    match sec.get(key) {
        Some(v) => Ok(v.as_str()?.to_string()),
        None => Ok(default.to_string()),
    }
}

fn get_num(sec: &TomlSection, key: &str, default: f64) -> Result<f64> {
    match sec.get(key) {
        Some(v) => v.as_f64().with_context(|| format!("[scenario] {key}")),
        None => Ok(default),
    }
}

fn str_list(v: &TomlValue) -> Result<Vec<String>> {
    match v {
        TomlValue::Arr(items) => items.iter().map(|i| Ok(i.as_str()?.to_string())).collect(),
        TomlValue::Str(s) => Ok(vec![s.clone()]),
        other => bail!("expected a list of strings, got {other:?}"),
    }
}

fn num_list(v: &TomlValue) -> Result<Vec<f64>> {
    match v {
        TomlValue::Arr(items) => items.iter().map(|i| i.as_f64()).collect(),
        TomlValue::Num(n) => Ok(vec![*n]),
        other => bail!("expected a list of numbers, got {other:?}"),
    }
}
