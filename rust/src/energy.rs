//! Cluster energy model (Intel Power Gadget substitute, paper §5.3/§6.1.4).
//!
//! The paper's energy win comes from *consolidation*: greedy bin-packing
//! keeps active containers on few nodes, so the remaining nodes go fully
//! idle and can be powered down. We model node power with the standard
//! linear form
//!
//! ```text
//! P(node) = 0                                   if powered off
//!         = P_idle + (P_peak - P_idle) · u      otherwise
//! ```
//!
//! where `u` is the busy-core fraction, and integrate over time as the
//! simulator/server transitions utilization levels. A node powers off after
//! `node_off_after_s` with zero allocated containers, and pays nothing
//! while off (power-on is folded into container cold-start spawn time).

use crate::config::ClusterConfig;
use crate::util::{to_secs, Micros};

/// Per-node energy integrator.
#[derive(Debug, Clone)]
pub struct NodeEnergy {
    /// Wh accumulated so far.
    energy_wh: f64,
    /// Time of the last state transition.
    last_t: Micros,
    /// Busy cores at the current level (actively executing containers × share).
    busy_cores: f64,
    /// Allocated cores (warm containers × share) — keeps the node on.
    alloc_cores: f64,
    /// Time since which the node has had zero allocation (None = active).
    idle_since: Option<Micros>,
    powered: bool,
}

impl NodeEnergy {
    pub fn new() -> NodeEnergy {
        NodeEnergy {
            energy_wh: 0.0,
            last_t: 0,
            busy_cores: 0.0,
            alloc_cores: 0.0,
            idle_since: Some(0),
            powered: false, // nodes start powered off until first placement
        }
    }

    fn power_watts(&self, cfg: &ClusterConfig) -> f64 {
        if !self.powered {
            return 0.0;
        }
        let u = (self.busy_cores / cfg.cores_per_node as f64).clamp(0.0, 1.0);
        cfg.idle_watts + (cfg.peak_watts - cfg.idle_watts) * u
    }

    /// Integrate energy up to `now`, applying power-off if the idle window
    /// has elapsed, then record the new utilization level.
    pub fn update(&mut self, now: Micros, busy_cores: f64, alloc_cores: f64, cfg: &ClusterConfig) {
        debug_assert!(now >= self.last_t);
        let off_after = crate::util::secs(cfg.node_off_after_s);
        // Did a power-off boundary fall inside [last_t, now]?
        if self.powered {
            if let Some(idle0) = self.idle_since {
                let off_at = idle0.saturating_add(off_after);
                if off_at < now {
                    // integrate idle power until off_at, nothing after
                    let dt_h = to_secs(off_at.saturating_sub(self.last_t)) / 3600.0;
                    self.energy_wh += self.power_watts(cfg) * dt_h;
                    self.powered = false;
                    self.last_t = off_at;
                }
            }
        }
        let dt_h = to_secs(now.saturating_sub(self.last_t)) / 3600.0;
        self.energy_wh += self.power_watts(cfg) * dt_h;
        self.last_t = now;
        self.busy_cores = busy_cores;
        self.alloc_cores = alloc_cores;
        if alloc_cores > 0.0 {
            self.powered = true;
            self.idle_since = None;
        } else if self.idle_since.is_none() {
            self.idle_since = Some(now);
        }
    }

    pub fn energy_wh(&self) -> f64 {
        self.energy_wh
    }

    pub fn is_powered(&self) -> bool {
        self.powered
    }
}

impl Default for NodeEnergy {
    fn default() -> Self {
        Self::new()
    }
}

/// Whole-cluster energy tracking.
#[derive(Debug, Clone)]
pub struct ClusterEnergy {
    pub nodes: Vec<NodeEnergy>,
}

impl ClusterEnergy {
    pub fn new(n: usize) -> ClusterEnergy {
        ClusterEnergy {
            nodes: (0..n).map(|_| NodeEnergy::new()).collect(),
        }
    }

    pub fn total_wh(&self) -> f64 {
        self.nodes.iter().map(|n| n.energy_wh()).sum()
    }

    pub fn powered_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_powered()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::secs;

    fn cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 1,
            cores_per_node: 10,
            cpu_per_container: 0.5,
            idle_watts: 100.0,
            peak_watts: 300.0,
            node_off_after_s: 60.0,
        }
    }

    #[test]
    fn off_node_consumes_nothing() {
        let mut n = NodeEnergy::new();
        n.update(secs(3600.0), 0.0, 0.0, &cfg());
        assert_eq!(n.energy_wh(), 0.0);
        assert!(!n.is_powered());
    }

    #[test]
    fn idle_power_integrates() {
        let mut n = NodeEnergy::new();
        n.update(0, 0.0, 1.0, &cfg()); // node on, fully idle
        n.update(secs(3600.0), 0.0, 1.0, &cfg());
        assert!((n.energy_wh() - 100.0).abs() < 1e-6, "{}", n.energy_wh());
    }

    #[test]
    fn busy_power_scales_linearly() {
        let mut n = NodeEnergy::new();
        n.update(0, 5.0, 5.0, &cfg()); // 50% busy
        n.update(secs(3600.0), 5.0, 5.0, &cfg());
        assert!((n.energy_wh() - 200.0).abs() < 1e-6, "{}", n.energy_wh());
    }

    #[test]
    fn full_load_hits_peak() {
        let mut n = NodeEnergy::new();
        n.update(0, 10.0, 10.0, &cfg());
        n.update(secs(1800.0), 10.0, 10.0, &cfg());
        assert!((n.energy_wh() - 150.0).abs() < 1e-6);
    }

    #[test]
    fn powers_off_after_idle_window() {
        let mut n = NodeEnergy::new();
        n.update(0, 0.0, 1.0, &cfg()); // on
        n.update(secs(100.0), 0.0, 0.0, &cfg()); // deallocated at t=100
        // 2 hours later: only 100s (until t=100) + 60s idle window at 100W
        n.update(secs(7300.0), 0.0, 0.0, &cfg());
        let expected = 100.0 * (160.0 / 3600.0);
        assert!((n.energy_wh() - expected).abs() < 1e-3, "{}", n.energy_wh());
        assert!(!n.is_powered());
    }

    #[test]
    fn reallocation_cancels_power_off() {
        let mut n = NodeEnergy::new();
        n.update(0, 0.0, 1.0, &cfg());
        n.update(secs(30.0), 0.0, 0.0, &cfg()); // idle at t=30
        n.update(secs(50.0), 0.0, 2.0, &cfg()); // reallocated before off
        n.update(secs(3650.0), 0.0, 2.0, &cfg());
        assert!(n.is_powered());
        // continuously idle-powered for the whole 3650 s
        let expected = 100.0 * 3650.0 / 3600.0;
        assert!((n.energy_wh() - expected).abs() < 1e-3, "{}", n.energy_wh());
    }

    #[test]
    fn cluster_rollup() {
        let c = cfg();
        let mut ce = ClusterEnergy::new(3);
        for n in &mut ce.nodes {
            n.update(0, 0.0, 1.0, &c);
            n.update(secs(3600.0), 0.0, 1.0, &c);
        }
        assert!((ce.total_wh() - 300.0).abs() < 1e-6);
        assert_eq!(ce.powered_nodes(), 3);
    }
}
