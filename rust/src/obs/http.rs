//! Dependency-free HTTP/1.1 responder for the metrics endpoints.
//!
//! A single accept-loop thread over `std::net::TcpListener` (the build
//! is vendored-only — no hyper/axum) serving read-only snapshots:
//!
//! | endpoint | payload |
//! |----------|---------|
//! | `GET /metrics` | current snapshot: totals + current bucket row |
//! | `GET /metrics/summary` | the SLO contract block |
//! | `GET /metrics/history?minutes=N` | last N minutes of timeline rows (default 60) |
//! | `GET /metrics/prom` | the same snapshot in Prometheus text exposition |
//! | `GET /traces?last=N` | last N sampled request span trees as Chrome trace-event JSON (default 100) |
//!
//! The responder never touches the engine: the serve loop publishes
//! [`ObsReport`] snapshots into a [`SharedSnapshot`] slot (at most once
//! per engine second) and the responder renders whatever snapshot is
//! current. Before the first publish every endpoint answers
//! `503 {"error":"no snapshot yet"}`. Requests are handled serially —
//! this is a scrape target, not a serving path — so misbehaving
//! clients are cut off early: a connection that sends no complete
//! request line within the 2 s read timeout gets `408`, and a request
//! line that overflows the 2 KiB head buffer gets `431`.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::obs::{prom, ObsReport};

/// The publish slot shared between the serve loop (writer) and the
/// responder thread (reader).
pub type SharedSnapshot = Arc<Mutex<Option<ObsReport>>>;

/// Default `last=N` for `GET /traces`.
const DEFAULT_TRACES_LAST: usize = 100;

const JSON: &str = "application/json";

/// Handle to a running metrics responder thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`; port 0 picks a free port)
    /// and start the responder thread serving from `shared`.
    pub fn start(addr: &str, shared: SharedSnapshot) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow!("metrics bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fifer-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // per-connection errors (timeouts, resets, bad
                        // requests) must not take the responder down
                        let _ = handle_conn(stream, &shared);
                    }
                }
            })?;
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the responder to exit and join it. The accept loop blocks
    /// in `accept()`, so a self-connection wakes it after the stop flag
    /// is set.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        let _ = handle.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(mut stream: TcpStream, shared: &SharedSnapshot) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read until the end of the request line; headers and body are
    // irrelevant for GET-only routes (the response closes the
    // connection, so unread bytes are simply discarded). Slow and
    // oversized clients are answered — not just dropped — so a curl
    // stuck in a middlebox sees *why* it was cut off.
    let mut buf = [0u8; 2048];
    let mut n = 0;
    loop {
        match stream.read(&mut buf[n..]) {
            Ok(0) => break,
            Ok(r) => {
                n += r;
                if buf[..n].windows(2).any(|w| w == b"\r\n") {
                    break;
                }
                if n == buf.len() {
                    return respond(
                        &mut stream,
                        431,
                        "Request Header Fields Too Large",
                        JSON,
                        "{\"error\":\"request line too long\"}",
                    );
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return respond(
                    &mut stream,
                    408,
                    "Request Timeout",
                    JSON,
                    "{\"error\":\"request timeout\"}",
                );
            }
            Err(e) => return Err(e),
        }
    }
    let head = String::from_utf8_lossy(&buf[..n]);
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => {
            return respond(
                &mut stream,
                400,
                "Bad Request",
                JSON,
                "{\"error\":\"bad request\"}",
            )
        }
    };
    if method != "GET" {
        return respond(
            &mut stream,
            405,
            "Method Not Allowed",
            JSON,
            "{\"error\":\"GET only\"}",
        );
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    // validate query parameters before the snapshot check so malformed
    // requests get 400 even pre-publish
    let minutes = match path {
        "/metrics/history" => match parse_count(query, "minutes") {
            Ok(m) => m,
            Err(()) => {
                return respond(
                    &mut stream,
                    400,
                    "Bad Request",
                    JSON,
                    "{\"error\":\"minutes must be a positive integer\"}",
                )
            }
        },
        _ => None,
    };
    let last = match path {
        "/traces" => match parse_count(query, "last") {
            Ok(l) => l,
            Err(()) => {
                return respond(
                    &mut stream,
                    400,
                    "Bad Request",
                    JSON,
                    "{\"error\":\"last must be a positive integer\"}",
                )
            }
        },
        _ => None,
    };

    let snapshot = shared.lock().expect("metrics snapshot lock").clone();
    let Some(report) = snapshot else {
        return respond(
            &mut stream,
            503,
            "Service Unavailable",
            JSON,
            "{\"error\":\"no snapshot yet\"}",
        );
    };
    let body = match path {
        "/metrics" => report.metrics_json().to_string(),
        "/metrics/summary" => report.summary_json().to_string(),
        // default window: the last hour of rows
        "/metrics/history" => report.history_json(Some(minutes.unwrap_or(60))).to_string(),
        "/metrics/prom" => {
            return respond(&mut stream, 200, "OK", prom::CONTENT_TYPE, &prom::render(&report))
        }
        "/traces" => {
            let last = last.map_or(DEFAULT_TRACES_LAST, |l| l as usize);
            report.trace_json(Some(last)).to_string()
        }
        _ => {
            return respond(
                &mut stream,
                404,
                "Not Found",
                JSON,
                "{\"error\":\"not found\"}",
            )
        }
    };
    respond(&mut stream, 200, "OK", JSON, &body)
}

/// Parse `key=N` (N a *positive* integer) from a query string.
/// `Ok(None)` when absent, `Err(())` on a malformed value (non-numeric,
/// zero, overflow) or an unparseable parameter shape. Unknown `k=v`
/// params are ignored (scrapers add cache-busters).
fn parse_count(query: &str, key: &str) -> Result<Option<u64>, ()> {
    if query.is_empty() {
        return Ok(None);
    }
    let mut found = None;
    for pair in query.split('&') {
        match pair.split_once('=') {
            Some((k, v)) if k == key => {
                let n = v.parse::<u64>().map_err(|_| ())?;
                if n == 0 {
                    return Err(());
                }
                found = Some(n);
            }
            Some(_) => {}
            None => return Err(()),
        }
    }
    Ok(found)
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_params_validate_strictly() {
        assert_eq!(parse_count("", "minutes"), Ok(None));
        assert_eq!(parse_count("minutes=5", "minutes"), Ok(Some(5)));
        assert_eq!(parse_count("cache=1&minutes=7", "minutes"), Ok(Some(7)));
        // other keys are ignored, including the other endpoint's param
        assert_eq!(parse_count("last=3", "minutes"), Ok(None));
        // zero, negatives, junk, empty values, bare tokens: all 400
        assert_eq!(parse_count("minutes=0", "minutes"), Err(()));
        assert_eq!(parse_count("minutes=-1", "minutes"), Err(()));
        assert_eq!(parse_count("minutes=abc", "minutes"), Err(()));
        assert_eq!(parse_count("minutes=", "minutes"), Err(()));
        assert_eq!(parse_count("minutes", "minutes"), Err(()));
        assert_eq!(parse_count("minutes=99999999999999999999", "minutes"), Err(()));
        assert_eq!(parse_count("last=0", "last"), Err(()));
        assert_eq!(parse_count("last=12", "last"), Ok(Some(12)));
    }
}
