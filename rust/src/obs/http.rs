//! Dependency-free HTTP/1.1 responder for the metrics endpoints.
//!
//! A single accept-loop thread over `std::net::TcpListener` (the build
//! is vendored-only — no hyper/axum) serving read-only JSON:
//!
//! | endpoint | payload |
//! |----------|---------|
//! | `GET /metrics` | current snapshot: totals + current bucket row |
//! | `GET /metrics/summary` | the SLO contract block |
//! | `GET /metrics/history?minutes=N` | last N minutes of timeline rows (default 60) |
//!
//! The responder never touches the engine: the serve loop publishes
//! [`ObsReport`] snapshots into a [`SharedSnapshot`] slot (at most once
//! per engine second) and the responder renders whatever snapshot is
//! current. Before the first publish every endpoint answers
//! `503 {"error":"no snapshot yet"}`. Requests are handled serially —
//! this is a scrape target, not a serving path.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::obs::ObsReport;

/// The publish slot shared between the serve loop (writer) and the
/// responder thread (reader).
pub type SharedSnapshot = Arc<Mutex<Option<ObsReport>>>;

/// Handle to a running metrics responder thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9090`; port 0 picks a free port)
    /// and start the responder thread serving from `shared`.
    pub fn start(addr: &str, shared: SharedSnapshot) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow!("metrics bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("fifer-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // per-connection errors (timeouts, resets, bad
                        // requests) must not take the responder down
                        let _ = handle_conn(stream, &shared);
                    }
                }
            })?;
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the responder to exit and join it. The accept loop blocks
    /// in `accept()`, so a self-connection wakes it after the stop flag
    /// is set.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        let _ = handle.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(mut stream: TcpStream, shared: &SharedSnapshot) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read until the end of the request line; headers and body are
    // irrelevant for GET-only routes (the response closes the
    // connection, so unread bytes are simply discarded).
    let mut buf = [0u8; 2048];
    let mut n = 0;
    loop {
        let r = stream.read(&mut buf[n..])?;
        if r == 0 {
            break;
        }
        n += r;
        if buf[..n].windows(2).any(|w| w == b"\r\n") || n == buf.len() {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..n]);
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => return respond(&mut stream, 400, "Bad Request", "{\"error\":\"bad request\"}"),
    };
    if method != "GET" {
        return respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "{\"error\":\"GET only\"}",
        );
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    let minutes = match path {
        "/metrics/history" => match parse_minutes(query) {
            Ok(m) => m,
            Err(()) => {
                return respond(
                    &mut stream,
                    400,
                    "Bad Request",
                    "{\"error\":\"minutes must be a non-negative integer\"}",
                )
            }
        },
        _ => None,
    };

    let snapshot = shared.lock().expect("metrics snapshot lock").clone();
    let Some(report) = snapshot else {
        return respond(
            &mut stream,
            503,
            "Service Unavailable",
            "{\"error\":\"no snapshot yet\"}",
        );
    };
    let body = match path {
        "/metrics" => report.metrics_json(),
        "/metrics/summary" => report.summary_json(),
        // default window: the last hour of rows
        "/metrics/history" => report.history_json(Some(minutes.unwrap_or(60))),
        _ => return respond(&mut stream, 404, "Not Found", "{\"error\":\"not found\"}"),
    };
    respond(&mut stream, 200, "OK", &body.to_string())
}

/// Parse `minutes=N` from a query string. `Ok(None)` when absent,
/// `Err(())` on a malformed value or unknown parameter shape.
fn parse_minutes(query: &str) -> Result<Option<u64>, ()> {
    if query.is_empty() {
        return Ok(None);
    }
    let mut minutes = None;
    for pair in query.split('&') {
        match pair.split_once('=') {
            Some(("minutes", v)) => {
                minutes = Some(v.parse::<u64>().map_err(|_| ())?);
            }
            // unknown params are ignored (scrapers add cache-busters)
            Some(_) => {}
            None => return Err(()),
        }
    }
    Ok(minutes)
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}
