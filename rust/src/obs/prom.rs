//! Prometheus text-exposition renderer over [`ObsReport`]
//! (`GET /metrics/prom`).
//!
//! Same snapshot, standard format: cumulative counters from
//! [`Totals`], bucket-averaged gauges from the current timeline row,
//! the end-to-end latency histogram re-emitted as cumulative
//! `_bucket`/`_sum`/`_count` series (text exposition format 0.0.4),
//! and the SLO contract as `fifer_slo_attained` / `fifer_slo_value` /
//! `fifer_slo_target` / `fifer_slo_burn_rate{window=...}` gauges. The
//! renderer is a pure function of the report — no clock, no state —
//! so a sim-driven exposition is as deterministic as the report it
//! reads.
//!
//! Format invariants the CI smoke asserts: every sample line's metric
//! has a `# TYPE` declaration, no series (name + label set) repeats,
//! histogram buckets are cumulative (monotone non-decreasing) and end
//! in `+Inf`, and no value is NaN.

use std::fmt::Write as _;

use super::timeline::LatencyHist;
use super::{ObsReport, Totals, WindowStats};
use crate::util::MICROS_PER_S;

/// Content-Type for the text exposition.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Render a value the exposition will accept: Prometheus has no NaN
/// use here, and our sources guard infinities already — but belt and
/// braces, non-finite renders as 0.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {}", num(v));
}

/// Emit one histogram family: cumulative `_bucket` series over the
/// geometric bounds, then `_sum` and `_count`.
fn histogram(out: &mut String, name: &str, help: &str, hist: &LatencyHist, sum: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &c) in hist.counts().iter().enumerate() {
        cum += c;
        match LatencyHist::bucket_bound(i) {
            Some(b) => {
                let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", num(b));
            }
            None => {
                let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
        }
    }
    let _ = writeln!(out, "{name}_sum {}", num(sum));
    let _ = writeln!(out, "{name}_count {}", hist.total());
}

/// Render the full exposition document for one snapshot.
pub fn render(r: &ObsReport) -> String {
    let mut out = String::with_capacity(8192);

    gauge(
        &mut out,
        "fifer_engine_now_seconds",
        "Engine clock at snapshot time (virtual or monotonic seconds).",
        r.now as f64 / MICROS_PER_S as f64,
    );
    let _ = writeln!(
        &mut out,
        "# HELP fifer_info Static run labels (value is always 1)."
    );
    let _ = writeln!(&mut out, "# TYPE fifer_info gauge");
    let _ = writeln!(&mut out, "fifer_info{{policy=\"{}\"}} 1", r.policy);

    // -- cumulative counters (collector totals + ring bookkeeping) -----
    let Totals {
        arrivals,
        dispatches,
        completions,
        slo_ok,
        slo_violations,
        cold_hit_jobs,
        spawns_cold,
        spawns_warm,
        retirements,
        batches,
        batched_jobs,
    } = r.totals.clone();
    counter(&mut out, "fifer_arrivals_total", "Requests entering the system.", arrivals);
    counter(&mut out, "fifer_dispatches_total", "Stage dispatches onto containers.", dispatches);
    counter(&mut out, "fifer_completions_total", "Completed chain requests.", completions);
    counter(&mut out, "fifer_slo_ok_total", "Completions within their chain SLO.", slo_ok);
    counter(&mut out, "fifer_slo_violations_total", "Completions past their SLO.", slo_violations);
    counter(&mut out, "fifer_cold_hit_jobs_total", "Jobs hit by cold-start wait.", cold_hit_jobs);
    counter(&mut out, "fifer_spawns_cold_total", "Cold container spawns.", spawns_cold);
    counter(&mut out, "fifer_spawns_warm_total", "Warm-pool container reuses.", spawns_warm);
    counter(&mut out, "fifer_retirements_total", "Containers retired.", retirements);
    counter(&mut out, "fifer_batches_total", "Batched execution passes.", batches);
    counter(&mut out, "fifer_batched_jobs_total", "Jobs carried by batched passes.", batched_jobs);
    counter(&mut out, "fifer_dropped_buckets_total", "Timeline rows evicted.", r.dropped_buckets);
    counter(&mut out, "fifer_dropped_traces_total", "Request traces evicted.", r.dropped_traces);

    // -- gauges: the current bucket's tick-averaged cluster state ------
    let (containers, warm_free, starting, queue_depth, util) = match r.rows.last() {
        Some(row) if row.ticks > 0 => {
            let t = row.ticks as f64;
            (
                row.containers_sum as f64 / t,
                row.warm_free_slots_sum as f64 / t,
                row.starting_slots_sum as f64 / t,
                row.queue_depth_sum as f64 / t,
                row.utilization(),
            )
        }
        _ => (0.0, 0.0, 0.0, 0.0, 0.0),
    };
    gauge(&mut out, "fifer_containers", "Containers alive (bucket average).", containers);
    gauge(&mut out, "fifer_warm_free_slots", "Idle warm slots (bucket average).", warm_free);
    gauge(&mut out, "fifer_starting_slots", "Cold-starting slots (bucket average).", starting);
    gauge(&mut out, "fifer_queue_depth", "Queued jobs (bucket average).", queue_depth);
    gauge(&mut out, "fifer_utilization", "Busy cores / allocated cores (bucket average).", util);

    // -- latency distributions -----------------------------------------
    let full = WindowStats::from_rows(&r.rows);
    let lat_sum: f64 = r.rows.iter().map(|row| row.lat_sum_ms).sum();
    histogram(
        &mut out,
        "fifer_e2e_latency_ms",
        "End-to-end request latency (ms) over the retained window.",
        &full.hist,
        lat_sum,
    );
    histogram(
        &mut out,
        "fifer_decision_latency_us",
        "Host-time dispatch decision latency (us); empty unless FIFER_DECISION_PROBE is armed.",
        &r.decision.hist,
        r.decision.sum_us,
    );

    // -- the SLO contract as labeled gauges ----------------------------
    let evals = r.contract();
    let slo_family = |out: &mut String, name: &str, help: &str| {
        let _ = writeln!(out, "# HELP fifer_slo_{name} {help}");
        let _ = writeln!(out, "# TYPE fifer_slo_{name} gauge");
    };
    slo_family(&mut out, "attained", "1 when the objective meets its target (full window).");
    for e in &evals {
        let _ = writeln!(
            &mut out,
            "fifer_slo_attained{{slo=\"{}\"}} {}",
            e.name,
            u8::from(e.ok())
        );
    }
    slo_family(&mut out, "value", "Observed objective value over the full window.");
    for e in &evals {
        let _ = writeln!(&mut out, "fifer_slo_value{{slo=\"{}\"}} {}", e.name, num(e.value));
    }
    slo_family(&mut out, "target", "Configured objective target.");
    for e in &evals {
        let _ = writeln!(&mut out, "fifer_slo_target{{slo=\"{}\"}} {}", e.name, num(e.target));
    }
    slo_family(
        &mut out,
        "burn_rate",
        "Normalized error-budget burn (>= 1 past the burn-alert line) per window.",
    );
    for e in &evals {
        let _ = writeln!(
            &mut out,
            "fifer_slo_burn_rate{{slo=\"{}\",window=\"fast\"}} {}",
            e.name,
            num(e.burn_fast)
        );
        let _ = writeln!(
            &mut out,
            "fifer_slo_burn_rate{{slo=\"{}\",window=\"slow\"}} {}",
            e.name,
            num(e.burn_slow)
        );
    }
    slo_family(&mut out, "alerting", "1 when both burn windows are past 1 (page condition).");
    for e in &evals {
        let _ = writeln!(
            &mut out,
            "fifer_slo_alerting{{slo=\"{}\"}} {}",
            e.name,
            u8::from(e.alerting())
        );
    }

    // -- per-shard series (merged sharded runs only) --------------------
    // Dedicated `fifer_shard_*` families rather than a `shard` label on
    // the existing names: unsharded expositions stay byte-identical,
    // and the aggregate series keep their meaning under sharding.
    if !r.shards.is_empty() {
        let _ = writeln!(
            &mut out,
            "# HELP fifer_shard_decision_latency_us Per-shard dispatch decision latency (us)."
        );
        let _ = writeln!(&mut out, "# TYPE fifer_shard_decision_latency_us histogram");
        for s in &r.shards {
            let mut cum = 0u64;
            for (i, &c) in s.decision.hist.counts().iter().enumerate() {
                cum += c;
                match LatencyHist::bucket_bound(i) {
                    Some(b) => {
                        let _ = writeln!(
                            &mut out,
                            "fifer_shard_decision_latency_us_bucket{{shard=\"{}\",le=\"{}\"}} {cum}",
                            s.shard,
                            num(b)
                        );
                    }
                    None => {
                        let _ = writeln!(
                            &mut out,
                            "fifer_shard_decision_latency_us_bucket{{shard=\"{}\",le=\"+Inf\"}} {cum}",
                            s.shard
                        );
                    }
                }
            }
            let _ = writeln!(
                &mut out,
                "fifer_shard_decision_latency_us_sum{{shard=\"{}\"}} {}",
                s.shard,
                num(s.decision.sum_us)
            );
            let _ = writeln!(
                &mut out,
                "fifer_shard_decision_latency_us_count{{shard=\"{}\"}} {}",
                s.shard,
                s.decision.hist.total()
            );
        }
        let shard_gauge = |out: &mut String, name: &str, help: &str| {
            let _ = writeln!(out, "# HELP fifer_shard_{name} {help}");
            let _ = writeln!(out, "# TYPE fifer_shard_{name} gauge");
        };
        shard_gauge(&mut out, "busy_cores", "Busy cores per shard (tick average over the run).");
        for s in &r.shards {
            let _ = writeln!(
                &mut out,
                "fifer_shard_busy_cores{{shard=\"{}\"}} {}",
                s.shard,
                num(s.busy_cores)
            );
        }
        shard_gauge(&mut out, "alloc_cores", "Allocated cores per shard (tick average over the run).");
        for s in &r.shards {
            let _ = writeln!(
                &mut out,
                "fifer_shard_alloc_cores{{shard=\"{}\"}} {}",
                s.shard,
                num(s.alloc_cores)
            );
        }
        shard_gauge(&mut out, "utilization", "Busy / allocated cores per shard.");
        for s in &r.shards {
            let util = if s.alloc_cores <= 0.0 {
                0.0
            } else {
                (s.busy_cores / s.alloc_cores).clamp(0.0, 1.0)
            };
            let _ = writeln!(
                &mut out,
                "fifer_shard_utilization{{shard=\"{}\"}} {}",
                s.shard,
                num(util)
            );
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Collector, Gauges, ObsConfig};
    use crate::util::secs;

    fn report() -> ObsReport {
        let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Fifer");
        for i in 0..50u64 {
            c.on_arrival(secs(i as f64));
            let rec = crate::metrics::JobRecord {
                chain: 0,
                arrival: secs(i as f64),
                completion: secs(i as f64 + 0.1 * (i % 7) as f64),
                stages: Vec::new(),
            };
            c.on_job_complete(rec.completion, i, &rec, i % 10 != 0);
        }
        c.on_tick(
            secs(49.0),
            Gauges {
                containers: 4,
                warm_free_slots: 2,
                starting_slots: 1,
                queue_depth: 3,
                busy_cores: 2.0,
                alloc_cores: 4.0,
            },
        );
        c.on_decision_latency(12_300);
        c.report(secs(50.0))
    }

    #[test]
    fn exposition_is_well_formed() {
        let text = render(&report());
        assert!(text.ends_with('\n'));
        let mut types = std::collections::BTreeSet::new();
        let mut series = std::collections::BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split(' ').next().unwrap().to_string();
                assert!(types.insert(name.clone()), "duplicate TYPE {name}");
            } else if !line.starts_with('#') {
                let (key, value) = line.rsplit_once(' ').unwrap();
                assert!(value.parse::<f64>().unwrap().is_finite(), "{line}");
                assert!(series.insert(key.to_string()), "duplicate series {key}");
                let base = key.split('{').next().unwrap();
                let base = base
                    .trim_end_matches("_bucket")
                    .trim_end_matches("_sum")
                    .trim_end_matches("_count");
                assert!(types.contains(base), "sample {key} has no TYPE");
            }
        }
        assert!(text.contains("fifer_slo_attained{slo=\"request_success_rate\"}"));
        assert!(text.contains("fifer_slo_burn_rate{slo=\"e2e_p95_ms\",window=\"fast\"}"));
        // deterministic re-render
        assert_eq!(text, render(&report()));
    }

    #[test]
    fn sharded_report_adds_labeled_families_only() {
        // an unsharded report never emits fifer_shard_* …
        let plain = render(&report());
        assert!(!plain.contains("fifer_shard_"));
        // … and a merged two-shard report adds the labeled families
        // while keeping the exposition well-formed (unique TYPEs and
        // series, every sample typed)
        let merged = crate::obs::merge_reports(vec![report(), report()]).unwrap();
        assert_eq!(merged.shards.len(), 2);
        let text = render(&merged);
        for needle in [
            "# TYPE fifer_shard_decision_latency_us histogram",
            "fifer_shard_decision_latency_us_count{shard=\"0\"}",
            "fifer_shard_decision_latency_us_count{shard=\"1\"}",
            "fifer_shard_busy_cores{shard=\"1\"}",
            "fifer_shard_alloc_cores{shard=\"0\"}",
            "fifer_shard_utilization{shard=\"1\"}",
        ] {
            assert!(text.contains(needle), "missing {needle}");
        }
        let mut types = std::collections::BTreeSet::new();
        let mut series = std::collections::BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split(' ').next().unwrap().to_string();
                assert!(types.insert(name.clone()), "duplicate TYPE {name}");
            } else if !line.starts_with('#') {
                let (key, value) = line.rsplit_once(' ').unwrap();
                assert!(value.parse::<f64>().unwrap().is_finite(), "{line}");
                assert!(series.insert(key.to_string()), "duplicate series {key}");
                let base = key.split('{').next().unwrap();
                let base = base
                    .trim_end_matches("_bucket")
                    .trim_end_matches("_sum")
                    .trim_end_matches("_count");
                assert!(types.contains(base), "sample {key} has no TYPE");
            }
        }
        // aggregate decision count doubles in the merged report
        assert!(text.contains("fifer_decision_latency_us_count 2"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_close_at_inf() {
        let text = render(&report());
        let mut prev = 0.0;
        let mut last = 0.0;
        let mut saw_inf = false;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("fifer_e2e_latency_ms_bucket") {
                let v: f64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= prev, "non-monotone bucket: {line}");
                prev = v;
                last = v;
                saw_inf = rest.contains("le=\"+Inf\"");
            }
        }
        assert!(saw_inf, "+Inf bucket must be last");
        let count_line = text
            .lines()
            .find(|l| l.starts_with("fifer_e2e_latency_ms_count"))
            .unwrap();
        let count: f64 = count_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert_eq!(count, last, "+Inf bucket must equal _count");
        assert_eq!(count, 50.0);
    }
}
