//! The explicit SLO contract: targets, burn-alert thresholds, and
//! fast/slow burn-rate evaluation over retained timeline windows.
//!
//! Four service-level objectives make up the contract (modeled on the
//! SLO block of SNIPPETS.md §2, grounded in the paper's evaluation
//! axes):
//!
//! * `request_success_rate` — fraction of completed requests inside
//!   their chain's end-to-end SLO (higher is better).
//! * `e2e_p95_ms` — end-to-end p95 latency vs the per-chain SLO from
//!   the slack plan (`coordinator::slack`); the strictest active chain
//!   sets the default target (lower is better).
//! * `container_utilization` — busy-core fraction of allocated
//!   container capacity, the paper's headline underutilization metric
//!   (higher is better).
//! * `cold_start_ratio` — fraction of completed requests that absorbed
//!   any cold-start wait (lower is better).
//!
//! Each SLO carries a `target` (the contract) and a `burn_alert`
//! threshold (the level at which error budget is burning unacceptably
//! fast). The **burn rate** is the normalized distance past target
//! toward the alert threshold: `0` at/inside target, `1.0` exactly at
//! `burn_alert`, `>1` beyond it. Following multi-window burn-rate
//! practice, an SLO is *alerting* only when both the fast window (last
//! [`FAST_WINDOW_S`]) and the slow window (last [`SLOW_WINDOW_S`]) burn
//! at ≥ 1 — a transient spike trips neither, a sustained regression
//! trips both.

use crate::obs::timeline::{BucketRow, LatencyHist};
use crate::util::json::Json;

/// Fast burn-rate window (seconds of retained timeline).
pub const FAST_WINDOW_S: u64 = 300;
/// Slow burn-rate window (seconds of retained timeline).
pub const SLOW_WINDOW_S: u64 = 3600;

/// Contract targets and burn-alert thresholds for the four SLOs.
#[derive(Debug, Clone, Copy)]
pub struct SloTargets {
    pub success_rate_target: f64,
    pub success_rate_burn: f64,
    /// `None` derives the target from the strictest active chain SLO.
    pub p95_target_ms: Option<f64>,
    /// `None` derives the alert threshold as 2x the p95 target.
    pub p95_burn_ms: Option<f64>,
    pub utilization_target: f64,
    pub utilization_burn: f64,
    pub cold_ratio_target: f64,
    pub cold_ratio_burn: f64,
}

impl Default for SloTargets {
    fn default() -> SloTargets {
        SloTargets {
            success_rate_target: 0.95,
            success_rate_burn: 0.90,
            p95_target_ms: None,
            p95_burn_ms: None,
            utilization_target: 0.50,
            utilization_burn: 0.30,
            cold_ratio_target: 0.10,
            cold_ratio_burn: 0.25,
        }
    }
}

/// Which way "good" points for an SLO value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
}

impl Direction {
    fn as_str(self) -> &'static str {
        match self {
            Direction::HigherIsBetter => "higher_is_better",
            Direction::LowerIsBetter => "lower_is_better",
        }
    }
}

/// Normalized burn rate: 0 at/inside target, 1 exactly at `burn_alert`,
/// proportionally beyond. Never negative, never NaN.
pub fn burn_rate(value: f64, target: f64, burn_alert: f64, dir: Direction) -> f64 {
    let (num, denom) = match dir {
        Direction::HigherIsBetter => (target - value, target - burn_alert),
        Direction::LowerIsBetter => (value - target, burn_alert - target),
    };
    if !num.is_finite() {
        return 0.0;
    }
    (num / denom.max(1e-12)).max(0.0)
}

/// One evaluated SLO: full-window value plus fast/slow burn rates.
#[derive(Debug, Clone)]
pub struct SloEval {
    pub name: &'static str,
    pub value: f64,
    pub target: f64,
    pub burn_alert: f64,
    pub direction: Direction,
    /// Samples behind `value` (completions, or gauge ticks for
    /// utilization) — 0 means the value is a vacuous default.
    pub samples: u64,
    pub burn_fast: f64,
    pub burn_slow: f64,
}

impl SloEval {
    /// Full-window value meets the contract target.
    pub fn ok(&self) -> bool {
        match self.direction {
            Direction::HigherIsBetter => self.value >= self.target,
            Direction::LowerIsBetter => self.value <= self.target,
        }
    }

    /// Multi-window burn alert: both fast and slow windows burning at
    /// ≥ 1 (i.e. past `burn_alert`).
    pub fn alerting(&self) -> bool {
        self.burn_fast >= 1.0 && self.burn_slow >= 1.0
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("value", Json::Num(self.value)),
            ("target", Json::Num(self.target)),
            ("burn_alert", Json::Num(self.burn_alert)),
            ("ok", Json::Bool(self.ok())),
            ("alerting", Json::Bool(self.alerting())),
            ("burn_rate_fast", Json::Num(self.burn_fast)),
            ("burn_rate_slow", Json::Num(self.burn_slow)),
            ("direction", Json::Str(self.direction.as_str().to_string())),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }
}

/// Timeline rows folded into one evaluation window.
#[derive(Debug, Clone, Default)]
pub struct WindowStats {
    pub completions: u64,
    pub slo_ok: u64,
    pub cold_hit_jobs: u64,
    pub hist: LatencyHist,
    pub lat_max_ms: f64,
    pub busy_sum: f64,
    pub alloc_sum: f64,
    pub ticks: u64,
}

impl WindowStats {
    pub fn from_rows(rows: &[BucketRow]) -> WindowStats {
        let mut w = WindowStats::default();
        for r in rows {
            w.completions += r.completions;
            w.slo_ok += r.slo_ok;
            w.cold_hit_jobs += r.cold_hit_jobs;
            w.hist.merge(&r.hist);
            w.lat_max_ms = w.lat_max_ms.max(r.lat_max_ms);
            w.busy_sum += r.busy_cores_sum;
            w.alloc_sum += r.alloc_cores_sum;
            w.ticks += r.ticks;
        }
        w
    }

    /// Vacuously 1.0 with no completions — an idle window has burned no
    /// error budget.
    pub fn success_rate(&self) -> f64 {
        if self.completions == 0 {
            1.0
        } else {
            self.slo_ok as f64 / self.completions as f64
        }
    }

    pub fn p95_ms(&self) -> f64 {
        self.hist.percentile(95.0, self.lat_max_ms)
    }

    /// Busy/allocated core fraction; `neutral` (the target) when no
    /// capacity was allocated — an empty cluster is vacuously attaining,
    /// not 0%-utilized.
    pub fn utilization(&self, neutral: f64) -> f64 {
        if self.alloc_sum <= 0.0 {
            neutral
        } else {
            (self.busy_sum / self.alloc_sum).clamp(0.0, 1.0)
        }
    }

    pub fn cold_ratio(&self) -> f64 {
        if self.completions == 0 {
            0.0
        } else {
            self.cold_hit_jobs as f64 / self.completions as f64
        }
    }
}

/// Evaluate the four-SLO contract: values over the full retained
/// window, burn rates over the fast/slow tail windows.
pub fn evaluate(
    t: &SloTargets,
    chain_slo_ms: f64,
    full: &WindowStats,
    fast: &WindowStats,
    slow: &WindowStats,
) -> Vec<SloEval> {
    let p95_target = t.p95_target_ms.unwrap_or(chain_slo_ms);
    let p95_burn = t.p95_burn_ms.unwrap_or(2.0 * p95_target);
    let eval = |name, target, burn, dir, vf: f64, vfast: f64, vslow: f64, samples| SloEval {
        name,
        value: vf,
        target,
        burn_alert: burn,
        direction: dir,
        samples,
        burn_fast: burn_rate(vfast, target, burn, dir),
        burn_slow: burn_rate(vslow, target, burn, dir),
    };
    vec![
        eval(
            "request_success_rate",
            t.success_rate_target,
            t.success_rate_burn,
            Direction::HigherIsBetter,
            full.success_rate(),
            fast.success_rate(),
            slow.success_rate(),
            full.completions,
        ),
        eval(
            "e2e_p95_ms",
            p95_target,
            p95_burn,
            Direction::LowerIsBetter,
            full.p95_ms(),
            fast.p95_ms(),
            slow.p95_ms(),
            full.completions,
        ),
        eval(
            "container_utilization",
            t.utilization_target,
            t.utilization_burn,
            Direction::HigherIsBetter,
            full.utilization(t.utilization_target),
            fast.utilization(t.utilization_target),
            slow.utilization(t.utilization_target),
            full.ticks,
        ),
        eval(
            "cold_start_ratio",
            t.cold_ratio_target,
            t.cold_ratio_burn,
            Direction::LowerIsBetter,
            full.cold_ratio(),
            fast.cold_ratio(),
            slow.cold_ratio(),
            full.completions,
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burn_rate_anchors() {
        // higher-is-better: 0 at target, 1 at burn_alert, linear between
        let d = Direction::HigherIsBetter;
        assert_eq!(burn_rate(0.95, 0.95, 0.90, d), 0.0);
        assert!((burn_rate(0.90, 0.95, 0.90, d) - 1.0).abs() < 1e-9);
        assert!((burn_rate(0.925, 0.95, 0.90, d) - 0.5).abs() < 1e-9);
        assert!(burn_rate(0.80, 0.95, 0.90, d) > 1.0);
        assert_eq!(burn_rate(1.0, 0.95, 0.90, d), 0.0); // over-attaining

        // lower-is-better mirrors
        let d = Direction::LowerIsBetter;
        assert_eq!(burn_rate(0.10, 0.10, 0.25, d), 0.0);
        assert!((burn_rate(0.25, 0.10, 0.25, d) - 1.0).abs() < 1e-9);
        assert!(burn_rate(0.40, 0.10, 0.25, d) > 1.0);
    }

    #[test]
    fn burn_rate_degenerate_thresholds_stay_finite() {
        let b = burn_rate(0.5, 0.9, 0.9, Direction::HigherIsBetter);
        assert!(b.is_finite() && b >= 0.0);
        assert_eq!(burn_rate(f64::NAN, 0.9, 0.8, Direction::HigherIsBetter), 0.0);
    }

    #[test]
    fn empty_window_is_vacuously_attaining() {
        let w = WindowStats::default();
        let evals = evaluate(&SloTargets::default(), 1000.0, &w, &w, &w);
        assert_eq!(evals.len(), 4);
        for e in &evals {
            assert!(e.ok(), "{} not ok on empty window", e.name);
            assert!(!e.alerting(), "{} alerting on empty window", e.name);
            assert_eq!(e.samples, 0);
        }
    }

    #[test]
    fn contract_names_and_derived_p95_target() {
        let w = WindowStats::default();
        let evals = evaluate(&SloTargets::default(), 750.0, &w, &w, &w);
        let names: Vec<&str> = evals.iter().map(|e| e.name).collect();
        assert_eq!(
            names,
            [
                "request_success_rate",
                "e2e_p95_ms",
                "container_utilization",
                "cold_start_ratio"
            ]
        );
        let p95 = &evals[1];
        assert_eq!(p95.target, 750.0);
        assert_eq!(p95.burn_alert, 1500.0);
    }

    #[test]
    fn sustained_violation_alerts_on_both_windows() {
        let mut row = BucketRow::new(0);
        row.completions = 100;
        row.slo_ok = 50; // 50% success, far past the 90% burn threshold
        row.slo_violations = 50;
        let w = WindowStats::from_rows(&[row]);
        let evals = evaluate(&SloTargets::default(), 1000.0, &w, &w, &w);
        let sr = &evals[0];
        assert!(!sr.ok());
        assert!(sr.burn_fast > 1.0 && sr.burn_slow > 1.0);
        assert!(sr.alerting());
    }
}
