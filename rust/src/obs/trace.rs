//! Per-request span recorder: the causal "why" behind the timeline's
//! aggregate "what".
//!
//! Every *sampled* job gets a span tree assembled entirely in engine
//! time: a root request span (arrival → chain completion) with, for
//! each stage, a stage span decomposed into queue-wait / cold-start /
//! batch-wait / exec children, each tagged with the container id, node
//! id, batch size, policy name and cold/warm identity. The recorder is
//! fed from `EngineCore`'s existing decision points through
//! [`super::Collector`], so the sim and live drivers produce the same
//! schema — under the virtual-time driver the whole trace is a pure
//! function of the seed.
//!
//! Sampling is head-based and deterministic: job `j` is kept iff
//! `splitmix64(seed ^ j) % N == 0` (`--trace-sample 1-in-N`). The
//! decision depends only on the seed and the job id — both
//! reproducible across runs and `--threads` — so `--trace-out` files
//! are byte-identical. The unsampled path is one hash + one map probe
//! and allocates nothing, keeping the zero-alloc dispatch pin intact
//! when tracing is enabled but a job is not sampled.
//!
//! Export is Chrome trace-event JSON (`ph:"X"` complete events, `ts`/
//! `dur` in microseconds — the engine's native resolution), loadable
//! in `chrome://tracing` or Perfetto.

use std::collections::{BTreeMap, VecDeque};

use crate::util::json::Json;
use crate::util::Micros;

/// One executed stage of a sampled request, captured when its batch
/// retires. All timestamps are engine time (µs).
#[derive(Debug, Clone)]
pub struct StageSpan {
    /// Microservice short name (catalog-static).
    pub stage: &'static str,
    /// When the job entered this stage's queue.
    pub enqueued: Micros,
    /// When the batch holding the job started executing.
    pub exec_start: Micros,
    /// When the batch retired.
    pub exec_end: Micros,
    /// Portion of the queue wait attributable to a cold container spawn.
    pub cold_wait: Micros,
    /// Container that executed the batch.
    pub container: u64,
    /// Node hosting that container.
    pub node: usize,
    /// Jobs in the batch this stage rode in.
    pub batch: usize,
    /// Whether the container was cold-started (vs. warm-pool reuse).
    pub cold: bool,
}

/// The span tree of one sampled request.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub job_id: u64,
    /// Chain short name (catalog-static).
    pub chain: &'static str,
    pub arrival: Micros,
    /// Chain completion time; equals `arrival` while still open.
    pub completion: Micros,
    pub slo_ok: bool,
    pub stages: Vec<StageSpan>,
}

/// One monitor-tick scaling decision (the §6.1.5 probe as a span).
#[derive(Debug, Clone, Copy)]
pub struct MonitorSpan {
    /// Engine time of the tick.
    pub t: Micros,
    /// Containers the policy's plan asked to spawn this tick.
    pub spawns_planned: u64,
    /// Host-measured decision latency (ns); 0 unless the decision
    /// probe (`FIFER_DECISION_PROBE`) is armed, so deterministic runs
    /// render deterministic zeros.
    pub dur_ns: u64,
}

/// Deterministic head-based sampling decision for `job_id`.
pub fn sampled(seed: u64, sample_n: u64, job_id: u64) -> bool {
    sample_n != 0 && splitmix64(seed ^ job_id) % sample_n == 0
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Bounded recorder of sampled request traces and monitor spans.
///
/// `open` holds in-flight sampled requests; `done` is a ring of
/// finished traces. Both are bounded by `keep` — evictions are counted
/// in `dropped` so a truncated trace is never mistaken for complete.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    sample_n: u64,
    seed: u64,
    keep: usize,
    open: BTreeMap<u64, RequestTrace>,
    done: VecDeque<RequestTrace>,
    monitors: VecDeque<MonitorSpan>,
    dropped: u64,
}

impl TraceRecorder {
    pub fn new(sample_n: u64, keep: usize, seed: u64) -> TraceRecorder {
        TraceRecorder {
            sample_n,
            seed,
            keep: keep.max(1),
            open: BTreeMap::new(),
            done: VecDeque::new(),
            monitors: VecDeque::new(),
            dropped: 0,
        }
    }

    pub fn sample_n(&self) -> u64 {
        self.sample_n
    }

    /// Head-based sampling gate: opens a trace only for sampled jobs.
    /// The unsampled path is hash + early return — no allocation.
    pub fn start(&mut self, job_id: u64, arrival: Micros, chain: &'static str) {
        if !sampled(self.seed, self.sample_n, job_id) {
            return;
        }
        while self.open.len() >= self.keep {
            let oldest = *self.open.keys().next().expect("non-empty open map");
            self.open.remove(&oldest);
            self.dropped += 1;
        }
        self.open.insert(
            job_id,
            RequestTrace {
                job_id,
                chain,
                arrival,
                completion: arrival,
                slo_ok: false,
                stages: Vec::new(),
            },
        );
    }

    /// Records a finished stage for `job_id` if it is being traced
    /// (one map probe and a return otherwise).
    pub fn stage(&mut self, job_id: u64, span: StageSpan) {
        if let Some(t) = self.open.get_mut(&job_id) {
            t.stages.push(span);
        }
    }

    /// Closes the trace at chain completion and moves it to the ring.
    pub fn finish(&mut self, job_id: u64, completion: Micros, slo_ok: bool) {
        if let Some(mut t) = self.open.remove(&job_id) {
            t.completion = completion;
            t.slo_ok = slo_ok;
            self.done.push_back(t);
            while self.done.len() > self.keep {
                self.done.pop_front();
                self.dropped += 1;
            }
        }
    }

    pub fn monitor(&mut self, t: Micros, spawns_planned: u64, dur_ns: u64) {
        self.monitors.push_back(MonitorSpan {
            t,
            spawns_planned,
            dur_ns,
        });
        while self.monitors.len() > self.keep {
            self.monitors.pop_front();
            self.dropped += 1;
        }
    }

    pub fn done(&self) -> &VecDeque<RequestTrace> {
        &self.done
    }

    pub fn monitors(&self) -> &VecDeque<MonitorSpan> {
        &self.monitors
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

// ---------------------------------------------------------------------
// Chrome trace-event rendering
// ---------------------------------------------------------------------

/// A `ph:"X"` complete event. `ts`/`dur` are µs, matching `Micros`.
fn complete(
    name: &str,
    cat: &str,
    pid: u64,
    tid: u64,
    ts: Micros,
    dur: Micros,
    args: Vec<(&str, Json)>,
) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("X".to_string())),
        ("name", Json::Str(name.to_string())),
        ("cat", Json::Str(cat.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts as f64)),
        ("dur", Json::Num(dur as f64)),
        ("args", Json::obj(args)),
    ])
}

/// `process_name` metadata event (one per scenario cell on merge).
pub fn process_meta(pid: u64, label: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("M".to_string())),
        ("name", Json::Str("process_name".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("args", Json::obj(vec![("name", Json::Str(label.to_string()))])),
    ])
}

/// `thread_name` metadata for tid 0, the scheduler/monitor track.
pub fn scheduler_thread_meta(pid: u64) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("M".to_string())),
        ("name", Json::Str("thread_name".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        (
            "args",
            Json::obj(vec![("name", Json::Str("scheduler".to_string()))]),
        ),
    ])
}

impl MonitorSpan {
    pub fn event(&self, pid: u64, policy: &str) -> Json {
        complete(
            "monitor",
            "scheduler",
            pid,
            0,
            self.t,
            self.dur_ns / 1000,
            vec![
                ("policy", Json::Str(policy.to_string())),
                ("spawns_planned", Json::Num(self.spawns_planned as f64)),
            ],
        )
    }
}

impl RequestTrace {
    /// Appends this request's span tree to `out`. Each request gets its
    /// own track (`tid = job_id + 1`; tid 0 is the scheduler); nesting
    /// is by containment, the trace-event convention for `X` events.
    pub fn events(&self, pid: u64, policy: &str, out: &mut Vec<Json>) {
        let tid = self.job_id + 1;
        out.push(complete(
            self.chain,
            "request",
            pid,
            tid,
            self.arrival,
            self.completion.saturating_sub(self.arrival),
            vec![
                ("job", Json::Num(self.job_id as f64)),
                ("policy", Json::Str(policy.to_string())),
                ("slo_ok", Json::Bool(self.slo_ok)),
            ],
        ));
        for s in &self.stages {
            let args = vec![
                ("batch", Json::Num(s.batch as f64)),
                ("cold", Json::Bool(s.cold)),
                ("container", Json::Num(s.container as f64)),
                ("node", Json::Num(s.node as f64)),
                ("policy", Json::Str(policy.to_string())),
            ];
            out.push(complete(
                s.stage,
                "stage",
                pid,
                tid,
                s.enqueued,
                s.exec_end.saturating_sub(s.enqueued),
                args.clone(),
            ));
            let queue_wait = s.exec_start.saturating_sub(s.enqueued);
            if queue_wait > 0 {
                out.push(complete(
                    "queue-wait",
                    "wait",
                    pid,
                    tid,
                    s.enqueued,
                    queue_wait,
                    args.clone(),
                ));
            }
            if s.cold_wait > 0 {
                out.push(complete(
                    "cold-start",
                    "wait",
                    pid,
                    tid,
                    s.enqueued,
                    s.cold_wait,
                    args.clone(),
                ));
            }
            let batch_wait = queue_wait.saturating_sub(s.cold_wait);
            if batch_wait > 0 {
                out.push(complete(
                    "batch-wait",
                    "wait",
                    pid,
                    tid,
                    s.enqueued + s.cold_wait,
                    batch_wait,
                    args.clone(),
                ));
            }
            out.push(complete(
                "exec",
                "exec",
                pid,
                tid,
                s.exec_start,
                s.exec_end.saturating_sub(s.exec_start),
                args,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_and_roughly_one_in_n() {
        let hits: Vec<u64> = (0..1000).filter(|&j| sampled(42, 4, j)).collect();
        let again: Vec<u64> = (0..1000).filter(|&j| sampled(42, 4, j)).collect();
        assert_eq!(hits, again);
        assert!(hits.len() > 100 && hits.len() < 500, "{}", hits.len());
        // N = 1 keeps everything, N = 0 disables
        assert!((0..100).all(|j| sampled(7, 1, j)));
        assert!((0..100).all(|j| !sampled(7, 0, j)));
        // seed changes the sample set
        let other: Vec<u64> = (0..1000).filter(|&j| sampled(43, 4, j)).collect();
        assert_ne!(hits, other);
    }

    #[test]
    fn recorder_bounds_open_and_done_and_counts_drops() {
        let mut r = TraceRecorder::new(1, 2, 0);
        for j in 0..4 {
            r.start(j, j * 10, "c");
        }
        // keep = 2: jobs 0 and 1 were evicted from the open map
        assert_eq!(r.open.len(), 2);
        assert_eq!(r.dropped(), 2);
        r.finish(2, 100, true);
        r.finish(3, 110, false);
        assert_eq!(r.done().len(), 2);
        // finishing an untracked job is a no-op
        r.finish(0, 120, true);
        assert_eq!(r.done().len(), 2);
        assert_eq!(r.done()[0].job_id, 2);
        assert!(r.done()[0].slo_ok);
    }

    #[test]
    fn span_tree_renders_nested_complete_events() {
        let mut r = TraceRecorder::new(1, 16, 0);
        r.start(5, 1_000, "ipa");
        r.stage(
            5,
            StageSpan {
                stage: "nlp",
                enqueued: 1_000,
                exec_start: 4_000,
                exec_end: 9_000,
                cold_wait: 2_000,
                container: 3,
                node: 1,
                batch: 2,
                cold: true,
            },
        );
        r.finish(5, 9_000, true);
        let mut out = Vec::new();
        r.done()[0].events(1, "Fifer", &mut out);
        // request + stage + queue-wait + cold-start + batch-wait + exec
        assert_eq!(out.len(), 6);
        let names: Vec<&str> = out
            .iter()
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(
            names,
            vec!["ipa", "nlp", "queue-wait", "cold-start", "batch-wait", "exec"]
        );
        // batch-wait = queue-wait - cold-wait, starting after the cold span
        let bw = &out[4];
        assert_eq!(bw.get("ts").unwrap().as_f64().unwrap(), 3_000.0);
        assert_eq!(bw.get("dur").unwrap().as_f64().unwrap(), 1_000.0);
        // all spans nest within the request span on the same track
        let root_end = 1_000.0 + out[0].get("dur").unwrap().as_f64().unwrap();
        for e in &out[1..] {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            assert!(ts >= 1_000.0 && ts + dur <= root_end);
        }
    }
}
