//! Observability plane: one telemetry contract, two drivers.
//!
//! This module is the production observability layer for the Fifer
//! coordinator. A [`Collector`] hangs off `EngineCore` decision points
//! (arrivals, dispatches, spawns with their cold/warm tag, completions
//! with per-stage latency decomposition, retirements, monitor-tick
//! gauges) and aggregates them into minute-bucketed
//! [`timeline::BucketRow`]s kept in a bounded in-memory ring
//! ([`ObsConfig::retention_buckets`], default 24 h of one-minute
//! buckets). On top of the rows sits an explicit SLO contract
//! ([`slo`]): four objectives with targets, burn-alert thresholds, and
//! fast/slow burn rates.
//!
//! The same collector serves **both drivers**:
//!
//! * the live server exposes it over a dependency-free HTTP responder
//!   ([`http::MetricsServer`]; `fifer serve --metrics-addr ...`) at
//!   `GET /metrics`, `GET /metrics/summary`,
//!   `GET /metrics/history?minutes=N`, `GET /metrics/prom`
//!   (Prometheus text exposition, [`prom`]) and `GET /traces?last=N`
//!   (sampled request span trees, [`trace`]);
//! * the simulator emits the *identical* timeline/contract schema from
//!   virtual time (`fifer scenario run ... --slo-timeline out.json
//!   --trace-out spans.json`), byte-deterministic from the seed.
//!
//! One contract, two drivers: a live dashboard and a sim sweep are
//! directly diffable. The collector is fed engine time only (virtual or
//! monotonic µs), never reads a clock or RNG, and is disabled
//! (`Option::None` on the engine) unless a caller opts in — so it can
//! neither perturb scheduling decisions nor the byte-identity pins.

pub mod http;
pub mod prom;
pub mod slo;
pub mod timeline;
pub mod trace;

use std::collections::VecDeque;

use crate::metrics::JobRecord;
use crate::util::json::Json;
use crate::util::{to_ms, Micros, MICROS_PER_S};

pub use http::{MetricsServer, SharedSnapshot};
pub use slo::{SloEval, SloTargets, WindowStats, FAST_WINDOW_S, SLOW_WINDOW_S};
pub use timeline::{BucketRow, LatencyHist};
pub use trace::{MonitorSpan, RequestTrace, StageSpan, TraceRecorder};

/// Collector configuration: bucket width, ring retention, the SLO
/// contract thresholds, and per-request trace sampling.
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Timeline bucket width in engine seconds (min 1).
    pub bucket_s: u64,
    /// Buckets retained in the ring (min 1). The default pairs with
    /// `bucket_s = 60` for 24 h of history.
    pub retention_buckets: usize,
    pub targets: SloTargets,
    /// Head-based trace sampling: keep 1 in `trace_sample` requests
    /// (seeded, deterministic under the sim driver). 0 disables the
    /// span recorder entirely — the default, so timelines stay exactly
    /// as cheap as before tracing existed.
    pub trace_sample: u64,
    /// Finished request traces retained (ring; oldest evicted and
    /// counted in `dropped_traces`). Also bounds in-flight traces and
    /// monitor spans.
    pub trace_keep: usize,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            bucket_s: 60,
            retention_buckets: 1440,
            targets: SloTargets::default(),
            trace_sample: 0,
            trace_keep: 512,
        }
    }
}

/// One monitor-tick gauge sample, taken at `on_scan` cadence.
#[derive(Debug, Clone, Copy, Default)]
pub struct Gauges {
    pub containers: u64,
    pub warm_free_slots: u64,
    pub starting_slots: u64,
    pub queue_depth: u64,
    pub busy_cores: f64,
    pub alloc_cores: f64,
}

/// Cumulative counters since collector start (never evicted, unlike the
/// ring rows).
#[derive(Debug, Clone, Default)]
pub struct Totals {
    pub arrivals: u64,
    pub dispatches: u64,
    pub completions: u64,
    pub slo_ok: u64,
    pub slo_violations: u64,
    pub cold_hit_jobs: u64,
    pub spawns_cold: u64,
    pub spawns_warm: u64,
    pub retirements: u64,
    pub batches: u64,
    pub batched_jobs: u64,
}

impl Totals {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("dispatches", Json::Num(self.dispatches as f64)),
            ("completions", Json::Num(self.completions as f64)),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("cold_hit_jobs", Json::Num(self.cold_hit_jobs as f64)),
            ("spawns_cold", Json::Num(self.spawns_cold as f64)),
            ("spawns_warm", Json::Num(self.spawns_warm as f64)),
            ("retirements", Json::Num(self.retirements as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("batched_jobs", Json::Num(self.batched_jobs as f64)),
        ])
    }
}

/// Host-time decision-latency distribution (the §6.1.5 probe folded
/// into the collector): microsecond samples from `try_dispatch` rounds
/// when `FIFER_DECISION_PROBE` is armed. Empty — and rendered as
/// deterministic zeros — in unprobed runs, so it never perturbs the
/// sim byte-identity pins.
#[derive(Debug, Clone, Default)]
pub struct DecisionStats {
    pub hist: LatencyHist,
    pub sum_us: f64,
    pub max_us: f64,
    pub count: u64,
}

impl DecisionStats {
    pub fn observe_ns(&mut self, ns: u64) {
        let us = ns as f64 / 1000.0;
        self.hist.observe(us);
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
        self.count += 1;
    }

    pub fn to_json(&self) -> Json {
        let mean = if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        };
        Json::obj(vec![
            ("p50_us", Json::Num(self.hist.percentile(50.0, self.max_us))),
            ("p95_us", Json::Num(self.hist.percentile(95.0, self.max_us))),
            ("p99_us", Json::Num(self.hist.percentile(99.0, self.max_us))),
            ("max_us", Json::Num(self.max_us)),
            ("mean_us", Json::Num(mean)),
            ("samples", Json::Num(self.count as f64)),
        ])
    }
}

/// Per-shard slice of a merged sharded report: the shard's own
/// decision-latency distribution plus its tick-averaged busy/allocated
/// cores. Present only on reports produced by [`merge_reports`] from
/// more than one shard — unsharded reports keep `shards` empty and
/// render byte-identically to before sharding existed.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    pub decision: DecisionStats,
    /// Mean busy cores per monitor tick over the shard's whole run.
    pub busy_cores: f64,
    /// Mean allocated cores per monitor tick over the shard's run.
    pub alloc_cores: f64,
}

impl ShardStats {
    pub fn to_json(&self) -> Json {
        let util = if self.alloc_cores <= 0.0 {
            0.0
        } else {
            (self.busy_cores / self.alloc_cores).clamp(0.0, 1.0)
        };
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("decision_latency_us", self.decision.to_json()),
            ("busy_cores", Json::Num(self.busy_cores)),
            ("alloc_cores", Json::Num(self.alloc_cores)),
            ("utilization", Json::Num(util)),
        ])
    }
}

/// Fold per-shard [`ObsReport`]s into one cluster-wide report (the
/// sharded drivers' merge step). A single report passes through
/// unchanged — the shards=1 byte-identity contract. With more, rows
/// merge by bucket start ([`BucketRow::merge`]), totals and decision
/// stats fold, traces/monitor spans concatenate in shard order, and
/// `shards` carries each shard's own decision stats and tick-averaged
/// load for per-shard rendering (`/metrics/summary`, `/metrics/prom`).
pub fn merge_reports(reports: Vec<ObsReport>) -> Option<ObsReport> {
    if reports.len() <= 1 {
        return reports.into_iter().next();
    }
    let shards: Vec<ShardStats> = reports
        .iter()
        .enumerate()
        .map(|(k, r)| {
            let (mut ticks, mut busy, mut alloc) = (0u64, 0.0f64, 0.0f64);
            for row in &r.rows {
                ticks += row.ticks;
                busy += row.busy_cores_sum;
                alloc += row.alloc_cores_sum;
            }
            let t = ticks.max(1) as f64;
            ShardStats {
                shard: k,
                decision: r.decision.clone(),
                busy_cores: busy / t,
                alloc_cores: alloc / t,
            }
        })
        .collect();
    let mut it = reports.into_iter();
    let mut out = it.next().expect("len > 1");
    let mut rows: std::collections::BTreeMap<Micros, BucketRow> =
        out.rows.drain(..).map(|r| (r.start, r)).collect();
    for r in it {
        out.now = out.now.max(r.now);
        out.dropped_buckets += r.dropped_buckets;
        out.totals.arrivals += r.totals.arrivals;
        out.totals.dispatches += r.totals.dispatches;
        out.totals.completions += r.totals.completions;
        out.totals.slo_ok += r.totals.slo_ok;
        out.totals.slo_violations += r.totals.slo_violations;
        out.totals.cold_hit_jobs += r.totals.cold_hit_jobs;
        out.totals.spawns_cold += r.totals.spawns_cold;
        out.totals.spawns_warm += r.totals.spawns_warm;
        out.totals.retirements += r.totals.retirements;
        out.totals.batches += r.totals.batches;
        out.totals.batched_jobs += r.totals.batched_jobs;
        out.dropped_traces += r.dropped_traces;
        out.traces.extend(r.traces);
        out.monitor_spans.extend(r.monitor_spans);
        out.decision.hist.merge(&r.decision.hist);
        out.decision.sum_us += r.decision.sum_us;
        out.decision.max_us = out.decision.max_us.max(r.decision.max_us);
        out.decision.count += r.decision.count;
        for row in r.rows {
            match rows.entry(row.start) {
                std::collections::btree_map::Entry::Vacant(v) => {
                    v.insert(row);
                }
                std::collections::btree_map::Entry::Occupied(mut o) => o.get_mut().merge(&row),
            }
        }
    }
    out.rows = rows.into_values().collect();
    out.shards = shards;
    Some(out)
}

/// Driver-agnostic telemetry collector fed from `EngineCore` taps.
///
/// All methods take the engine clock (`now`, µs); the collector holds
/// no clock of its own. Bucket rollover is lazy — the row for `now`'s
/// bucket materializes on the first tap that touches it, with empty
/// rows filling any gap so the timeline stays contiguous.
#[derive(Debug)]
pub struct Collector {
    cfg: ObsConfig,
    /// Strictest end-to-end SLO (ms) across the active chains — the
    /// default `e2e_p95_ms` contract target.
    chain_slo_ms: f64,
    /// Active policy short name, stamped on spans and the exposition.
    policy: &'static str,
    ring: VecDeque<BucketRow>,
    /// Rows evicted by retention (history endpoints report this so a
    /// truncated timeline is never mistaken for a complete one).
    dropped: u64,
    totals: Totals,
    /// Per-request span recorder; `None` unless `trace_sample > 0`, so
    /// the untraced tap cost is a single branch.
    trace: Option<TraceRecorder>,
    decision: DecisionStats,
}

impl Collector {
    pub fn new(cfg: ObsConfig, chain_slo_ms: f64, seed: u64, policy: &'static str) -> Collector {
        let mut cfg = cfg;
        cfg.bucket_s = cfg.bucket_s.max(1);
        cfg.retention_buckets = cfg.retention_buckets.max(1);
        let trace = (cfg.trace_sample > 0)
            .then(|| TraceRecorder::new(cfg.trace_sample, cfg.trace_keep, seed));
        Collector {
            cfg,
            chain_slo_ms,
            policy,
            ring: VecDeque::with_capacity(32),
            dropped: 0,
            totals: Totals::default(),
            trace,
            decision: DecisionStats::default(),
        }
    }

    /// Whether the span recorder is armed (i.e. `trace_sample > 0`).
    pub fn tracing(&self) -> bool {
        self.trace.is_some()
    }

    /// A job entered the system — open a trace if it is sampled.
    /// Unsampled jobs cost one hash; no allocation.
    pub fn on_trace_start(&mut self, job_id: u64, now: Micros, chain: &'static str) {
        if let Some(t) = self.trace.as_mut() {
            t.start(job_id, now, chain);
        }
    }

    /// A stage of `job_id` finished executing (batch retired).
    pub fn on_stage_span(&mut self, job_id: u64, span: StageSpan) {
        if let Some(t) = self.trace.as_mut() {
            t.stage(job_id, span);
        }
    }

    /// A monitor tick ran the policy's scaling decision.
    pub fn on_monitor_decision(&mut self, now: Micros, spawns_planned: u64, dur_ns: u64) {
        if let Some(t) = self.trace.as_mut() {
            t.monitor(now, spawns_planned, dur_ns);
        }
    }

    /// One probed `try_dispatch` round's host-time latency.
    pub fn on_decision_latency(&mut self, ns: u64) {
        self.decision.observe_ns(ns);
    }

    fn width(&self) -> Micros {
        self.cfg.bucket_s * MICROS_PER_S
    }

    /// Advance the ring so its back row covers `now`, filling gaps with
    /// empty rows and evicting past retention. A jump farther than the
    /// whole retention window drops the ring outright instead of
    /// looping through it.
    pub fn roll_to(&mut self, now: Micros) {
        let width = self.width();
        let start = now / width * width;
        let back_start = match self.ring.back() {
            Some(b) => b.start,
            None => {
                self.ring.push_back(BucketRow::new(start));
                return;
            }
        };
        if start <= back_start {
            return;
        }
        let gap = ((start - back_start) / width) as usize;
        if gap > self.cfg.retention_buckets {
            self.dropped += self.ring.len() as u64;
            self.ring.clear();
            self.ring.push_back(BucketRow::new(start));
            return;
        }
        let mut t = back_start;
        while t < start {
            t += width;
            if self.ring.len() >= self.cfg.retention_buckets {
                self.ring.pop_front();
                self.dropped += 1;
            }
            self.ring.push_back(BucketRow::new(t));
        }
    }

    fn cur(&mut self, now: Micros) -> &mut BucketRow {
        self.roll_to(now);
        self.ring.back_mut().expect("ring non-empty after roll")
    }

    pub fn on_arrival(&mut self, now: Micros) {
        self.totals.arrivals += 1;
        self.cur(now).arrivals += 1;
    }

    pub fn on_dispatch(&mut self, now: Micros) {
        self.totals.dispatches += 1;
        self.cur(now).dispatches += 1;
    }

    pub fn on_spawn(&mut self, now: Micros, cold: bool) {
        if cold {
            self.totals.spawns_cold += 1;
            self.cur(now).spawns_cold += 1;
        } else {
            self.totals.spawns_warm += 1;
            self.cur(now).spawns_warm += 1;
        }
    }

    pub fn on_retire(&mut self, now: Micros) {
        self.totals.retirements += 1;
        self.cur(now).retirements += 1;
    }

    /// One batched execution pass finished, carrying `jobs` requests.
    pub fn on_batch(&mut self, now: Micros, jobs: u64) {
        self.totals.batches += 1;
        self.totals.batched_jobs += jobs;
        let row = self.cur(now);
        row.batches += 1;
        row.batched_jobs += jobs;
    }

    /// A request completed its whole chain. `slo_ok` is the engine's
    /// verdict against the job's own chain SLO; the per-stage latency
    /// decomposition comes straight from the job record. Also closes
    /// the job's span tree if it was sampled.
    pub fn on_job_complete(&mut self, now: Micros, job_id: u64, rec: &JobRecord, slo_ok: bool) {
        if let Some(t) = self.trace.as_mut() {
            t.finish(job_id, now, slo_ok);
        }
        let resp_ms = to_ms(rec.response());
        let cold_hit = rec.cold_total() > 0;
        self.totals.completions += 1;
        if slo_ok {
            self.totals.slo_ok += 1;
        } else {
            self.totals.slo_violations += 1;
        }
        if cold_hit {
            self.totals.cold_hit_jobs += 1;
        }
        let exec_ms = to_ms(rec.exec_total());
        let cold_ms = to_ms(rec.cold_total());
        let batch_ms = to_ms(rec.batch_total());
        let row = self.cur(now);
        row.completions += 1;
        if slo_ok {
            row.slo_ok += 1;
        } else {
            row.slo_violations += 1;
        }
        if cold_hit {
            row.cold_hit_jobs += 1;
        }
        row.hist.observe(resp_ms);
        row.lat_sum_ms += resp_ms;
        row.lat_max_ms = row.lat_max_ms.max(resp_ms);
        row.exec_sum_ms += exec_ms;
        row.cold_sum_ms += cold_ms;
        row.batch_wait_sum_ms += batch_ms;
    }

    /// Monitor-tick gauge sample (warm/starting slots, queue depth,
    /// node load); averaged per bucket over its tick count.
    pub fn on_tick(&mut self, now: Micros, g: Gauges) {
        let row = self.cur(now);
        row.ticks += 1;
        row.containers_sum += g.containers;
        row.warm_free_slots_sum += g.warm_free_slots;
        row.starting_slots_sum += g.starting_slots;
        row.queue_depth_sum += g.queue_depth;
        row.busy_cores_sum += g.busy_cores;
        row.alloc_cores_sum += g.alloc_cores;
    }

    /// Immutable snapshot for rendering/serving. Call [`roll_to`]
    /// (or any tap) first so the rows cover `now`.
    ///
    /// [`roll_to`]: Collector::roll_to
    pub fn report(&self, now: Micros) -> ObsReport {
        ObsReport {
            now,
            bucket_s: self.cfg.bucket_s,
            retention_buckets: self.cfg.retention_buckets,
            dropped_buckets: self.dropped,
            chain_slo_ms: self.chain_slo_ms,
            targets: self.cfg.targets,
            totals: self.totals.clone(),
            rows: self.ring.iter().cloned().collect(),
            policy: self.policy,
            trace_sample: self.cfg.trace_sample,
            dropped_traces: self.trace.as_ref().map_or(0, |t| t.dropped()),
            traces: self
                .trace
                .as_ref()
                .map_or_else(Vec::new, |t| t.done().iter().cloned().collect()),
            monitor_spans: self
                .trace
                .as_ref()
                .map_or_else(Vec::new, |t| t.monitors().iter().copied().collect()),
            decision: self.decision.clone(),
            shards: Vec::new(),
        }
    }
}

/// A point-in-time snapshot of the collector: the retained timeline
/// plus everything needed to evaluate the SLO contract. This is the
/// unit the HTTP responder serves, the live `ServeReport` embeds, and
/// `--slo-timeline` writes — all through the same render methods, so
/// the schema cannot drift between surfaces.
#[derive(Debug, Clone)]
pub struct ObsReport {
    /// Engine time of the snapshot (µs).
    pub now: Micros,
    pub bucket_s: u64,
    pub retention_buckets: usize,
    pub dropped_buckets: u64,
    pub chain_slo_ms: f64,
    pub targets: SloTargets,
    pub totals: Totals,
    pub rows: Vec<BucketRow>,
    /// Active policy short name (stamped on spans and the exposition).
    pub policy: &'static str,
    /// 1-in-N trace sampling rate (0 = tracing off).
    pub trace_sample: u64,
    /// Traces/spans evicted by the recorder's bounds.
    pub dropped_traces: u64,
    /// Finished sampled request traces, completion order.
    pub traces: Vec<RequestTrace>,
    /// Monitor-tick scaling-decision spans, tick order.
    pub monitor_spans: Vec<MonitorSpan>,
    /// Probed dispatch decision latency (zeros unless the probe is on).
    pub decision: DecisionStats,
    /// Per-shard stats when this report was merged from a sharded run
    /// ([`merge_reports`]); empty — and absent from every rendering —
    /// for unsharded runs, preserving their output byte-for-byte.
    pub shards: Vec<ShardStats>,
}

impl ObsReport {
    /// Fold the last `seconds` of retained rows into one window.
    fn tail_window(&self, seconds: u64) -> WindowStats {
        let n = (seconds.div_ceil(self.bucket_s).max(1)) as usize;
        let skip = self.rows.len().saturating_sub(n);
        WindowStats::from_rows(&self.rows[skip..])
    }

    /// Evaluate the four-SLO contract: values over the full retained
    /// window, burn rates over the fast/slow tails.
    pub fn contract(&self) -> Vec<SloEval> {
        let full = WindowStats::from_rows(&self.rows);
        let fast = self.tail_window(FAST_WINDOW_S);
        let slow = self.tail_window(SLOW_WINDOW_S);
        slo::evaluate(&self.targets, self.chain_slo_ms, &full, &fast, &slow)
    }

    fn now_s(&self) -> Json {
        Json::Num(self.now as f64 / MICROS_PER_S as f64)
    }

    /// `GET /metrics` — cumulative totals plus the current bucket row.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("now_s", self.now_s()),
            ("bucket_s", Json::Num(self.bucket_s as f64)),
            ("buckets", Json::Num(self.rows.len() as f64)),
            (
                "retention_buckets",
                Json::Num(self.retention_buckets as f64),
            ),
            ("dropped_buckets", Json::Num(self.dropped_buckets as f64)),
            ("totals", self.totals.to_json()),
            (
                "current",
                self.rows.last().map_or(Json::Null, |r| r.to_json()),
            ),
        ])
    }

    /// `GET /metrics/summary` — the SLO contract block.
    pub fn summary_json(&self) -> Json {
        let evals = self.contract();
        let mut slo_obj = Vec::with_capacity(evals.len());
        let mut alerts = Vec::new();
        for e in &evals {
            slo_obj.push((e.name, e.to_json()));
            if e.alerting() {
                alerts.push(Json::Str(e.name.to_string()));
            }
        }
        let mut fields = vec![
            ("now_s", self.now_s()),
            ("bucket_s", Json::Num(self.bucket_s as f64)),
            ("buckets", Json::Num(self.rows.len() as f64)),
            ("dropped_buckets", Json::Num(self.dropped_buckets as f64)),
            ("chain_slo_ms", Json::Num(self.chain_slo_ms)),
            ("policy", Json::Str(self.policy.to_string())),
            (
                "windows",
                Json::obj(vec![
                    ("full_buckets", Json::Num(self.rows.len() as f64)),
                    ("fast_s", Json::Num(FAST_WINDOW_S as f64)),
                    ("slow_s", Json::Num(SLOW_WINDOW_S as f64)),
                ]),
            ),
            ("slo", Json::obj(slo_obj)),
            ("alerts", Json::Arr(alerts)),
            ("decision_latency_us", self.decision.to_json()),
        ];
        // only merged sharded reports carry this — unsharded output is
        // byte-for-byte what it was before sharding existed
        if !self.shards.is_empty() {
            fields.push((
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            ));
        }
        Json::obj(fields)
    }

    /// `GET /metrics/history?minutes=N` — the last N minutes of rows
    /// (`None` = the whole retained window).
    pub fn history_json(&self, minutes: Option<u64>) -> Json {
        let rows = match minutes {
            None => &self.rows[..],
            Some(m) => {
                let n = ((m * 60).div_ceil(self.bucket_s).max(1)) as usize;
                &self.rows[self.rows.len().saturating_sub(n)..]
            }
        };
        Json::obj(vec![
            ("bucket_s", Json::Num(self.bucket_s as f64)),
            ("dropped_buckets", Json::Num(self.dropped_buckets as f64)),
            (
                "rows",
                Json::Arr(rows.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// The `--slo-timeline` cell payload: full history + contract in
    /// one object. Byte-deterministic under the sim driver.
    pub fn timeline_json(&self) -> Json {
        Json::obj(vec![
            ("history", self.history_json(None)),
            ("summary", self.summary_json()),
        ])
    }

    /// Append this snapshot's spans as Chrome trace events under
    /// process `pid`: the scheduler-track metadata, the last `last`
    /// monitor spans, and the last `last` request span trees (`None` =
    /// all retained). Ordering is ring order, so output is
    /// deterministic whenever the engine is.
    pub fn trace_events(&self, pid: u64, last: Option<usize>, out: &mut Vec<Json>) {
        out.push(trace::scheduler_thread_meta(pid));
        let skip = |len: usize| last.map_or(0, |n| len.saturating_sub(n));
        for m in &self.monitor_spans[skip(self.monitor_spans.len())..] {
            out.push(m.event(pid, self.policy));
        }
        for t in &self.traces[skip(self.traces.len())..] {
            t.events(pid, self.policy, out);
        }
    }

    /// `GET /traces?last=N` and single-run `--trace-out`: a complete
    /// Chrome trace-event document (Perfetto / `chrome://tracing`).
    pub fn trace_json(&self, last: Option<usize>) -> Json {
        let mut events = Vec::new();
        self.trace_events(1, last, &mut events);
        Json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("traceSample", Json::Num(self.trace_sample as f64)),
            ("droppedTraces", Json::Num(self.dropped_traces as f64)),
            ("traceEvents", Json::Arr(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::secs;

    fn rec(arrival: Micros, completion: Micros) -> JobRecord {
        JobRecord {
            chain: 0,
            arrival,
            completion,
            stages: Vec::new(),
        }
    }

    #[test]
    fn buckets_roll_and_fill_gaps() {
        let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Test");
        c.on_arrival(secs(5.0));
        c.on_arrival(secs(59.0));
        // jump 3 buckets forward — the gap rows must exist and be empty
        c.on_arrival(secs(185.0));
        let r = c.report(secs(185.0));
        assert_eq!(r.rows.len(), 4);
        assert_eq!(r.rows[0].arrivals, 2);
        assert_eq!(r.rows[1].arrivals, 0);
        assert_eq!(r.rows[2].arrivals, 0);
        assert_eq!(r.rows[3].arrivals, 1);
        assert_eq!(r.totals.arrivals, 3);
        for (i, row) in r.rows.iter().enumerate() {
            assert_eq!(row.start, secs(60.0) * i as u64);
        }
    }

    #[test]
    fn retention_evicts_and_counts_dropped() {
        let cfg = ObsConfig {
            bucket_s: 1,
            retention_buckets: 3,
            ..ObsConfig::default()
        };
        let mut c = Collector::new(cfg, 1000.0, 0, "Test");
        for s in 0..10 {
            c.on_arrival(secs(s as f64));
        }
        let r = c.report(secs(9.0));
        assert_eq!(r.rows.len(), 3);
        assert_eq!(r.dropped_buckets, 7);
        assert_eq!(r.rows[0].start, secs(7.0));
        // totals survive eviction
        assert_eq!(r.totals.arrivals, 10);
    }

    #[test]
    fn far_jump_resets_instead_of_looping() {
        let cfg = ObsConfig {
            bucket_s: 1,
            retention_buckets: 5,
            ..ObsConfig::default()
        };
        let mut c = Collector::new(cfg, 1000.0, 0, "Test");
        c.on_arrival(0);
        c.on_arrival(secs(1_000_000.0)); // ~11 days past a 5s ring
        let r = c.report(secs(1_000_000.0));
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].start, secs(1_000_000.0));
        assert_eq!(r.dropped_buckets, 1);
    }

    #[test]
    fn completions_classify_and_decompose() {
        let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Test");
        c.on_job_complete(secs(1.0), 0, &rec(0, secs(0.5)), true);
        c.on_job_complete(secs(2.0), 1, &rec(0, secs(2.0)), false);
        c.on_batch(secs(2.0), 2);
        c.on_spawn(secs(2.0), true);
        c.on_spawn(secs(2.0), false);
        c.on_dispatch(secs(2.0));
        c.on_retire(secs(3.0));
        let r = c.report(secs(3.0));
        assert_eq!(r.totals.completions, 2);
        assert_eq!(r.totals.slo_ok, 1);
        assert_eq!(r.totals.slo_violations, 1);
        assert_eq!(r.totals.spawns_cold, 1);
        assert_eq!(r.totals.spawns_warm, 1);
        assert_eq!(r.totals.batches, 1);
        assert_eq!(r.totals.batched_jobs, 2);
        assert_eq!(r.totals.retirements, 1);
        let row = &r.rows[0];
        assert_eq!(row.completions, 2);
        assert!(row.lat_max_ms >= 2000.0 - 1e-9);
    }

    #[test]
    fn report_json_has_contract_shape_and_is_deterministic() {
        let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Test");
        for i in 0..20 {
            c.on_arrival(secs(i as f64));
            let done = secs(i as f64 + 0.4);
            c.on_job_complete(done, i, &rec(secs(i as f64), done), true);
        }
        c.on_tick(
            secs(19.0),
            Gauges {
                containers: 4,
                warm_free_slots: 2,
                starting_slots: 1,
                queue_depth: 0,
                busy_cores: 2.0,
                alloc_cores: 4.0,
            },
        );
        let r = c.report(secs(20.0));
        let s = r.summary_json().to_string();
        for name in [
            "request_success_rate",
            "e2e_p95_ms",
            "container_utilization",
            "cold_start_ratio",
        ] {
            assert!(s.contains(&format!("\"{name}\":")), "missing {name} in {s}");
        }
        for field in ["\"value\":", "\"target\":", "\"burn_alert\":"] {
            assert!(s.contains(field));
        }
        // re-render is byte-identical
        assert_eq!(s, r.summary_json().to_string());
        assert_eq!(
            r.timeline_json().to_string(),
            r.timeline_json().to_string()
        );
        // history slicing: 0 minutes still returns at least one row
        let h = r.history_json(Some(0)).to_string();
        assert!(h.contains("\"rows\":["));
    }
}
