//! Minute-bucketed timeline rows and the fixed-shape latency histogram
//! they embed.
//!
//! A [`BucketRow`] is one retained time bucket of telemetry: pure
//! counters (arrivals, dispatches, spawns, retirements), completion
//! outcomes (SLO ok/violated, cold-hit), a latency histogram for
//! percentile estimation, and gauge *sums* sampled on monitor ticks
//! (divide by `ticks` to get the bucket average). Rows are fixed-size —
//! the only heap payload is the histogram's inline array — so the
//! retention ring holds `retention_buckets` of them with a bounded,
//! predictable footprint.
//!
//! Everything here is driven by **engine time** (virtual or monotonic
//! µs) and is free of wall clocks, host randomness, and hash iteration,
//! so a simulator-fed timeline is byte-deterministic from the seed.

use crate::util::json::Json;
use crate::util::{Micros, MICROS_PER_S};

/// Number of geometric latency buckets. Bucket 0 holds `< 1 ms`;
/// bucket `i` holds `[RATIO^(i-1), RATIO^i)` ms; the last bucket is
/// open-ended.
pub const HIST_BUCKETS: usize = 64;

/// Geometric growth ratio between adjacent latency buckets (~26%
/// worst-case relative error on a percentile estimate, which is plenty
/// for burn-rate alerting; exact max/mean are tracked beside the
/// histogram).
pub const HIST_RATIO: f64 = 1.3;

/// Fixed-shape geometric latency histogram (milliseconds).
///
/// Mergeable across rows (bucket-wise sum), so SLO windows of any width
/// are evaluated by folding row histograms together — no raw samples
/// are retained.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: [u64; HIST_BUCKETS],
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist {
            counts: [0; HIST_BUCKETS],
        }
    }
}

impl LatencyHist {
    /// Record one latency sample (ms). Non-finite samples count into
    /// bucket 0 rather than poisoning percentiles with NaN.
    pub fn observe(&mut self, ms: f64) {
        let v = if ms.is_finite() { ms.max(0.0) } else { 0.0 };
        let mut bound = 1.0;
        let mut i = 0;
        while i < HIST_BUCKETS - 1 && v >= bound {
            bound *= HIST_RATIO;
            i += 1;
        }
        self.counts[i] += 1;
    }

    /// Bucket-wise sum — used to fold rows into an evaluation window.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Estimate the `q`-th percentile (0..=100) as the upper bound of
    /// the bucket where the cumulative count crosses the rank, capped at
    /// the exactly-tracked window maximum. Returns 0 for an empty
    /// histogram.
    pub fn percentile(&self, q: f64, max_ms: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        let mut bound = 1.0;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return if i == HIST_BUCKETS - 1 {
                    max_ms
                } else {
                    bound.min(max_ms)
                };
            }
            bound *= HIST_RATIO;
        }
        max_ms
    }

    /// Raw per-bucket counts, in bound order — the Prometheus renderer
    /// re-emits these as cumulative `_bucket` series.
    pub fn counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.counts
    }

    /// Upper bound of bucket `i` (ms): bucket 0 is `< 1`, bucket `i` is
    /// `< RATIO^i`; the final bucket is open-ended (`+Inf` in the
    /// exposition) and has no finite bound.
    pub fn bucket_bound(i: usize) -> Option<f64> {
        if i >= HIST_BUCKETS - 1 {
            return None;
        }
        let mut bound = 1.0;
        for _ in 0..i {
            bound *= HIST_RATIO;
        }
        Some(bound)
    }
}

/// One retained time bucket (`bucket_s` of engine time).
///
/// Counter fields are incremented by the collector taps; `*_sum` gauge
/// fields accumulate one sample per monitor tick and are averaged over
/// `ticks` when rendered.
#[derive(Debug, Clone)]
pub struct BucketRow {
    /// Bucket start (engine µs, aligned to the bucket width).
    pub start: Micros,
    pub arrivals: u64,
    pub dispatches: u64,
    pub completions: u64,
    /// Completions within their chain's end-to-end SLO.
    pub slo_ok: u64,
    pub slo_violations: u64,
    /// Completions whose latency includes any cold-start wait.
    pub cold_hit_jobs: u64,
    pub spawns_cold: u64,
    pub spawns_warm: u64,
    pub retirements: u64,
    /// Batched execution passes completed.
    pub batches: u64,
    /// Requests those passes carried (avg batch = batched_jobs/batches).
    pub batched_jobs: u64,
    /// End-to-end latency histogram over this bucket's completions.
    pub hist: LatencyHist,
    pub lat_sum_ms: f64,
    pub lat_max_ms: f64,
    /// Per-stage latency decomposition, summed over completions: pure
    /// execution, cold-start wait, and batching/queuing delay.
    pub exec_sum_ms: f64,
    pub cold_sum_ms: f64,
    pub batch_wait_sum_ms: f64,
    /// Monitor-tick gauge samples accumulated into this bucket.
    pub ticks: u64,
    pub busy_cores_sum: f64,
    pub alloc_cores_sum: f64,
    pub containers_sum: u64,
    pub warm_free_slots_sum: u64,
    pub starting_slots_sum: u64,
    pub queue_depth_sum: u64,
}

impl BucketRow {
    pub fn new(start: Micros) -> BucketRow {
        BucketRow {
            start,
            arrivals: 0,
            dispatches: 0,
            completions: 0,
            slo_ok: 0,
            slo_violations: 0,
            cold_hit_jobs: 0,
            spawns_cold: 0,
            spawns_warm: 0,
            retirements: 0,
            batches: 0,
            batched_jobs: 0,
            hist: LatencyHist::default(),
            lat_sum_ms: 0.0,
            lat_max_ms: 0.0,
            exec_sum_ms: 0.0,
            cold_sum_ms: 0.0,
            batch_wait_sum_ms: 0.0,
            ticks: 0,
            busy_cores_sum: 0.0,
            alloc_cores_sum: 0.0,
            containers_sum: 0,
            warm_free_slots_sum: 0,
            starting_slots_sum: 0,
            queue_depth_sum: 0,
        }
    }

    /// Fold another row covering the same bucket into this one (used
    /// when merging per-shard timelines): counters and sums add, the
    /// histograms merge bucket-wise, and the tracked max takes the max.
    /// Note `ticks` adds too — a merged row's gauge averages are
    /// per-shard-tick, i.e. mean load of one shard, not of the cluster;
    /// SLO ratios (counter/counter) are unaffected.
    pub fn merge(&mut self, other: &BucketRow) {
        self.arrivals += other.arrivals;
        self.dispatches += other.dispatches;
        self.completions += other.completions;
        self.slo_ok += other.slo_ok;
        self.slo_violations += other.slo_violations;
        self.cold_hit_jobs += other.cold_hit_jobs;
        self.spawns_cold += other.spawns_cold;
        self.spawns_warm += other.spawns_warm;
        self.retirements += other.retirements;
        self.batches += other.batches;
        self.batched_jobs += other.batched_jobs;
        self.hist.merge(&other.hist);
        self.lat_sum_ms += other.lat_sum_ms;
        self.lat_max_ms = self.lat_max_ms.max(other.lat_max_ms);
        self.exec_sum_ms += other.exec_sum_ms;
        self.cold_sum_ms += other.cold_sum_ms;
        self.batch_wait_sum_ms += other.batch_wait_sum_ms;
        self.ticks += other.ticks;
        self.busy_cores_sum += other.busy_cores_sum;
        self.alloc_cores_sum += other.alloc_cores_sum;
        self.containers_sum += other.containers_sum;
        self.warm_free_slots_sum += other.warm_free_slots_sum;
        self.starting_slots_sum += other.starting_slots_sum;
        self.queue_depth_sum += other.queue_depth_sum;
    }

    /// Busy-core fraction of allocated container capacity over the
    /// bucket (0 when nothing was allocated).
    pub fn utilization(&self) -> f64 {
        if self.alloc_cores_sum <= 0.0 {
            0.0
        } else {
            (self.busy_cores_sum / self.alloc_cores_sum).clamp(0.0, 1.0)
        }
    }

    /// Render one timeline row. Keys are identical for the sim and live
    /// drivers — this is the row half of the shared observability
    /// contract — and the writer is BTreeMap-backed, so the rendering is
    /// byte-deterministic.
    pub fn to_json(&self) -> Json {
        let per = |sum: f64, n: u64| if n == 0 { 0.0 } else { sum / n as f64 };
        Json::obj(vec![
            (
                "t_s",
                Json::Num(self.start as f64 / MICROS_PER_S as f64),
            ),
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("dispatches", Json::Num(self.dispatches as f64)),
            ("completions", Json::Num(self.completions as f64)),
            ("slo_ok", Json::Num(self.slo_ok as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("cold_hit_jobs", Json::Num(self.cold_hit_jobs as f64)),
            ("spawns_cold", Json::Num(self.spawns_cold as f64)),
            ("spawns_warm", Json::Num(self.spawns_warm as f64)),
            ("retirements", Json::Num(self.retirements as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("batched_jobs", Json::Num(self.batched_jobs as f64)),
            (
                "e2e_mean_ms",
                Json::Num(per(self.lat_sum_ms, self.completions)),
            ),
            (
                "e2e_p50_ms",
                Json::Num(self.hist.percentile(50.0, self.lat_max_ms)),
            ),
            (
                "e2e_p95_ms",
                Json::Num(self.hist.percentile(95.0, self.lat_max_ms)),
            ),
            (
                "e2e_p99_ms",
                Json::Num(self.hist.percentile(99.0, self.lat_max_ms)),
            ),
            ("e2e_max_ms", Json::Num(self.lat_max_ms)),
            (
                "stage_exec_mean_ms",
                Json::Num(per(self.exec_sum_ms, self.completions)),
            ),
            (
                "stage_cold_mean_ms",
                Json::Num(per(self.cold_sum_ms, self.completions)),
            ),
            (
                "stage_batch_wait_mean_ms",
                Json::Num(per(self.batch_wait_sum_ms, self.completions)),
            ),
            ("utilization", Json::Num(self.utilization())),
            (
                "containers",
                Json::Num(per(self.containers_sum as f64, self.ticks)),
            ),
            (
                "warm_free_slots",
                Json::Num(per(self.warm_free_slots_sum as f64, self.ticks)),
            ),
            (
                "starting_slots",
                Json::Num(per(self.starting_slots_sum as f64, self.ticks)),
            ),
            (
                "queue_depth",
                Json::Num(per(self.queue_depth_sum as f64, self.ticks)),
            ),
            ("ticks", Json::Num(self.ticks as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_percentiles_bracket_samples() {
        let mut h = LatencyHist::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.total(), 100);
        let p50 = h.percentile(50.0, 100.0);
        let p99 = h.percentile(99.0, 100.0);
        // geometric buckets: estimates are upper bounds within one RATIO
        assert!(p50 >= 50.0 && p50 <= 50.0 * HIST_RATIO, "p50 = {p50}");
        assert!(p99 >= 99.0 && p99 <= 100.0, "p99 = {p99}");
        assert!(p50 <= p99, "percentiles must be monotone");
    }

    #[test]
    fn hist_empty_and_extremes() {
        let mut h = LatencyHist::default();
        assert_eq!(h.percentile(95.0, 0.0), 0.0);
        h.observe(0.0); // sub-ms
        h.observe(f64::NAN); // counted, not poisoning
        h.observe(1e12); // open-ended last bucket, capped at max
        assert_eq!(h.total(), 3);
        assert_eq!(h.percentile(100.0, 1e12), 1e12);
    }

    #[test]
    fn hist_merge_equals_combined_observe() {
        let (mut a, mut b, mut c) = (
            LatencyHist::default(),
            LatencyHist::default(),
            LatencyHist::default(),
        );
        for i in 0..50 {
            a.observe(i as f64 * 3.0);
            c.observe(i as f64 * 3.0);
        }
        for i in 0..50 {
            b.observe(1000.0 + i as f64);
            c.observe(1000.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.total(), c.total());
        assert_eq!(a.percentile(95.0, 1049.0), c.percentile(95.0, 1049.0));
    }

    #[test]
    fn row_json_guards_empty_denominators() {
        let r = BucketRow::new(0);
        let js = r.to_json().to_string();
        assert!(js.contains("\"e2e_mean_ms\":0"));
        assert!(js.contains("\"utilization\":0"));
        assert!(!js.contains("NaN"));
    }
}
