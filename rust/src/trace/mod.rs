//! Arrival traces: generators + composition operators (§5.3).
//!
//! Rust re-implements the same generator formulas as
//! `python/compile/traces.py`, and can also *load* the exact traces the
//! Python side exported to `artifacts/traces/*.json` (used by Fig. 6 so
//! predictors are scored on the series the LSTM was trained against).
//!
//! A [`Trace`] is a per-second arrival-rate series; [`Trace::arrivals`]
//! expands it into concrete request timestamps via a piecewise-constant
//! Poisson process.
//!
//! Beyond the paper's three workloads (constant-rate [`Trace::poisson`],
//! diurnal [`Trace::wiki`], bursty [`Trace::wits`]) there are two
//! post-paper generators — [`Trace::azure`] (an
//! Azure-FunctionsInvocationTrace-like heavy-tailed aggregate) and
//! [`Trace::flashcrowd`] (a step spike) — and four composition operators
//! ([`Trace::overlay`], [`Trace::splice`], [`Trace::ramp`],
//! [`Trace::noise`]) so arbitrary workload shapes can be expressed
//! without code edits. The [`crate::scenario`] module exposes all of
//! these through a declarative expression language in scenario files.
//! Every generator and operator is deterministic given its seed.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::{Micros, MICROS_PER_S};

/// A per-second arrival-rate series (requests/second).
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub rate_per_s: Vec<f64>,
}

impl Trace {
    pub fn duration_s(&self) -> usize {
        self.rate_per_s.len()
    }

    pub fn avg_rate(&self) -> f64 {
        crate::util::stats::mean(&self.rate_per_s)
    }

    pub fn peak_rate(&self) -> f64 {
        self.rate_per_s.iter().copied().fold(0.0, f64::max)
    }

    /// Scale the whole series by a factor (to match cluster capacity).
    pub fn scaled(&self, factor: f64) -> Trace {
        Trace {
            name: format!("{}x{factor:.3}", self.name),
            rate_per_s: self.rate_per_s.iter().map(|r| r * factor).collect(),
        }
    }

    /// Truncate/extend (by tiling) to `duration_s` seconds. An empty
    /// series resizes to all-zero rates (there is nothing to tile)
    /// instead of panicking on the modulo.
    pub fn resized(&self, duration_s: usize) -> Trace {
        if self.rate_per_s.is_empty() {
            return Trace {
                name: self.name.clone(),
                rate_per_s: vec![0.0; duration_s],
            };
        }
        let mut rate = Vec::with_capacity(duration_s);
        for i in 0..duration_s {
            rate.push(self.rate_per_s[i % self.rate_per_s.len()]);
        }
        Trace {
            name: self.name.clone(),
            rate_per_s: rate,
        }
    }

    /// Element-wise sum of two traces. The result is as long as the
    /// longer input; past the shorter one's end its rate counts as 0.
    pub fn overlay(&self, other: &Trace) -> Trace {
        let n = self.rate_per_s.len().max(other.rate_per_s.len());
        let rate = (0..n)
            .map(|i| {
                self.rate_per_s.get(i).copied().unwrap_or(0.0)
                    + other.rate_per_s.get(i).copied().unwrap_or(0.0)
            })
            .collect();
        Trace {
            name: format!("{}+{}", self.name, other.name),
            rate_per_s: rate,
        }
    }

    /// Switch workloads mid-run: `self` for the first `at_s` seconds
    /// (zero-padded if `self` is shorter), then all of `other` starting
    /// from its own t = 0. Length = `at_s` + `other.duration_s()`.
    pub fn splice(&self, other: &Trace, at_s: usize) -> Trace {
        let mut rate = Vec::with_capacity(at_s + other.rate_per_s.len());
        for i in 0..at_s {
            rate.push(self.rate_per_s.get(i).copied().unwrap_or(0.0));
        }
        rate.extend_from_slice(&other.rate_per_s);
        Trace {
            name: format!("{}>{}", self.name, other.name),
            rate_per_s: rate,
        }
    }

    /// Multiply the series by a factor ramping linearly from `from` at
    /// t = 0 to `to` at the final sample (e.g. `from = 0, to = 1` fades
    /// a workload in over the whole run).
    pub fn ramp(&self, from: f64, to: f64) -> Trace {
        let n = self.rate_per_s.len();
        let denom = n.saturating_sub(1).max(1) as f64;
        let rate = self
            .rate_per_s
            .iter()
            .enumerate()
            .map(|(i, r)| r * (from + (to - from) * i as f64 / denom))
            .collect();
        Trace {
            name: format!("{}~ramp", self.name),
            rate_per_s: rate,
        }
    }

    /// Multiplicative lognormal jitter: each sample is scaled by
    /// exp(N(0, sigma)). Deterministic given `seed`; sigma = 0 is the
    /// identity.
    pub fn noise(&self, sigma: f64, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let rate = self
            .rate_per_s
            .iter()
            .map(|r| r * rng.lognormal(0.0, sigma))
            .collect();
        Trace {
            name: format!("{}~noise", self.name),
            rate_per_s: rate,
        }
    }

    /// Expand into concrete arrival timestamps (µs): within each second,
    /// draw a Poisson count at that second's rate and scatter uniformly.
    pub fn arrivals(&self, rng: &mut Pcg) -> Vec<Micros> {
        let mut out = Vec::new();
        for (sec, &rate) in self.rate_per_s.iter().enumerate() {
            let n = rng.poisson(rate);
            let base = sec as u64 * MICROS_PER_S;
            for _ in 0..n {
                out.push(base + (rng.f64() * MICROS_PER_S as f64) as u64);
            }
        }
        out.sort_unstable();
        out
    }

    /// Load a trace exported by `python/compile/aot.py`.
    pub fn load_json(path: &Path) -> Result<Trace> {
        let j = Json::parse_file(path)?;
        Ok(Trace {
            name: j.get("name")?.as_str()?.to_string(),
            rate_per_s: j.get("rate_per_s")?.as_f64_vec()?,
        })
    }

    /// Constant-rate Poisson trace (paper: synthetic λ = 50).
    pub fn poisson(lambda: f64, duration_s: usize) -> Trace {
        Trace {
            name: format!("poisson{lambda}"),
            rate_per_s: vec![lambda; duration_s],
        }
    }

    /// WITS-like bursty trace — same formula as python traces.wits_trace.
    pub fn wits(duration_s: usize, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let mut rate: Vec<f64> = (0..duration_s)
            .map(|t| {
                let base = 230.0
                    * (1.0 + 0.20 * (2.0 * std::f64::consts::PI * t as f64 / 1800.0).sin());
                base * rng.lognormal(0.0, 0.12)
            })
            .collect();
        // rare sharp spikes
        let mut pos = 0.0f64;
        loop {
            pos += rng.exponential(500.0);
            if pos >= duration_s as f64 {
                break;
            }
            let width = rng.range(20.0, 60.0);
            let amp = rng.range(650.0, 950.0);
            let sigma = width / 2.355;
            let lo = ((pos - 4.0 * sigma).max(0.0)) as usize;
            let hi = ((pos + 4.0 * sigma) as usize).min(duration_s);
            for (t, r) in rate.iter_mut().enumerate().take(hi).skip(lo) {
                let d = (t as f64 - pos) / sigma;
                *r += amp * (-0.5 * d * d).exp();
            }
        }
        for r in rate.iter_mut() {
            *r = r.clamp(1.0, 1250.0);
        }
        Trace {
            name: "wits".to_string(),
            rate_per_s: rate,
        }
    }

    /// Wiki-like diurnal trace — same formula as python traces.wiki_trace.
    pub fn wiki(duration_s: usize, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let rate = (0..duration_s)
            .map(|t| {
                let tf = t as f64;
                let det = 1500.0
                    * (1.0
                        + 0.35 * (2.0 * std::f64::consts::PI * tf / 3600.0).sin()
                        + 0.12 * (2.0 * std::f64::consts::PI * tf / 600.0 + 1.0).sin());
                (det * rng.lognormal(0.0, 0.08)).max(1.0)
            })
            .collect();
        Trace {
            name: "wiki".to_string(),
            rate_per_s: rate,
        }
    }

    /// Azure-FunctionsInvocationTrace-like aggregate (Shahrad et al.,
    /// ATC'20 characterization): many "functions" whose per-function
    /// rates are Pareto-distributed (a few hot functions dominate the
    /// aggregate), mild phase-shifted diurnal modulation, per-second
    /// lognormal jitter, and rare bursts whose *amplitudes* are
    /// heavy-tailed too. The deterministic base is normalized to a mean
    /// of ~100 req/s so cluster sizing stays predictable; compose with
    /// [`Trace::scaled`] to change the level.
    pub fn azure(duration_s: usize, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let two_pi = 2.0 * std::f64::consts::PI;
        const FUNCS: usize = 200;
        // per-function weight ~ Pareto(x_m = 0.05, alpha = 1.1), capped
        let mut funcs = Vec::with_capacity(FUNCS);
        for _ in 0..FUNCS {
            let u = loop {
                let u = rng.f64();
                if u > 1e-12 {
                    break u;
                }
            };
            let weight = (0.05 / u.powf(1.0 / 1.1)).min(50.0);
            let phase = rng.range(0.0, two_pi);
            let diurnal = rng.range(0.0, 0.6);
            funcs.push((weight, phase, diurnal));
        }
        let mut rate: Vec<f64> = (0..duration_s)
            .map(|t| {
                let tf = t as f64;
                funcs
                    .iter()
                    .map(|&(w, phase, amp)| w * (1.0 + amp * (two_pi * tf / 3600.0 + phase).sin()))
                    .sum()
            })
            .collect();
        // normalize the deterministic base to mean ~100 req/s
        let mean = crate::util::stats::mean(&rate).max(1e-9);
        for r in rate.iter_mut() {
            *r *= 100.0 / mean;
        }
        // per-second jitter
        for r in rate.iter_mut() {
            *r *= rng.lognormal(0.0, 0.3);
        }
        // rare heavy-tailed bursts (Gaussian in time, Pareto amplitude)
        let mut pos = 0.0f64;
        loop {
            pos += rng.exponential(600.0);
            if pos >= duration_s as f64 {
                break;
            }
            let u = rng.f64().max(1e-12);
            let amp = (50.0 / u.powf(1.0 / 1.2)).min(1500.0);
            let width = 20.0 * rng.lognormal(0.0, 0.5);
            let sigma = (width / 2.355).max(1.0);
            let lo = ((pos - 4.0 * sigma).max(0.0)) as usize;
            let hi = ((pos + 4.0 * sigma) as usize).min(duration_s);
            for (t, r) in rate.iter_mut().enumerate().take(hi).skip(lo) {
                let d = (t as f64 - pos) / sigma;
                *r += amp * (-0.5 * d * d).exp();
            }
        }
        for r in rate.iter_mut() {
            *r = r.clamp(0.1, 2000.0);
        }
        Trace {
            name: "azure".to_string(),
            rate_per_s: rate,
        }
    }

    /// Flash-crowd step spike: `base` req/s everywhere except
    /// `[start_s, start_s + width_s)`, where the rate jumps to
    /// `base + amp`. Deterministic (no randomness); typically composed
    /// onto another trace via [`Trace::overlay`] with `base = 0`.
    pub fn flashcrowd(
        duration_s: usize,
        base: f64,
        amp: f64,
        start_s: usize,
        width_s: usize,
    ) -> Trace {
        let rate = (0..duration_s)
            .map(|t| {
                if t >= start_s && t < start_s + width_s {
                    base + amp
                } else {
                    base
                }
            })
            .collect();
        Trace {
            name: "flashcrowd".to_string(),
            rate_per_s: rate,
        }
    }

    /// Max arrival rate per adjacent window (paper §4.5: W_s = 5 s).
    /// A trailing partial window contributes its own maximum — the
    /// predictor input must not silently lose the end of the series
    /// when the duration is not a multiple of `window_s`.
    pub fn window_maxima(&self, window_s: usize) -> Vec<f64> {
        self.rate_per_s
            .chunks(window_s.max(1))
            .map(|w| w.iter().copied().fold(0.0, f64::max))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wits_statistics_match_paper() {
        let t = Trace::wits(4000, 1316);
        let avg = t.avg_rate();
        let peak = t.peak_rate();
        assert!((200.0..=360.0).contains(&avg), "avg {avg}");
        assert!((1000.0..=1300.0).contains(&peak), "peak {peak}");
        let mut v = t.rate_per_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = crate::util::stats::percentile_sorted(&v, 50.0);
        assert!(peak / median >= 3.5, "ratio {}", peak / median);
    }

    #[test]
    fn wiki_statistics_match_paper() {
        let t = Trace::wiki(4000, 2025);
        let avg = t.avg_rate();
        assert!((1200.0..=1800.0).contains(&avg), "avg {avg}");
        assert!(t.peak_rate() / avg < 2.5);
    }

    #[test]
    fn deterministic_generation() {
        let a = Trace::wits(500, 1);
        let b = Trace::wits(500, 1);
        assert_eq!(a.rate_per_s, b.rate_per_s);
        let c = Trace::wits(500, 2);
        assert_ne!(a.rate_per_s, c.rate_per_s);
    }

    #[test]
    fn arrivals_sorted_and_rate_correct() {
        let t = Trace::poisson(100.0, 50);
        let mut rng = Pcg::new(3);
        let arr = t.arrivals(&mut rng);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let rate = arr.len() as f64 / 50.0;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
        assert!(arr.iter().all(|&a| a < 50 * MICROS_PER_S));
    }

    #[test]
    fn scaling_and_resizing() {
        let t = Trace::poisson(10.0, 20);
        let s = t.scaled(2.0);
        assert_eq!(s.rate_per_s[0], 20.0);
        let r = t.resized(45);
        assert_eq!(r.duration_s(), 45);
        assert_eq!(r.rate_per_s[44], 10.0);
    }

    #[test]
    fn window_maxima_basics() {
        let t = Trace {
            name: "x".into(),
            rate_per_s: vec![1.0, 5.0, 2.0, 8.0, 3.0, 1.0],
        };
        assert_eq!(t.window_maxima(2), vec![5.0, 8.0, 3.0]);
        assert_eq!(t.window_maxima(3), vec![5.0, 8.0]);
    }

    #[test]
    fn window_maxima_includes_trailing_partial_window() {
        // regression: chunks_exact silently dropped the tail window,
        // so the predictor never saw the last duration % window_s secs.
        let t = Trace {
            name: "x".into(),
            rate_per_s: vec![1.0, 5.0, 2.0, 8.0, 3.0, 1.0, 9.0],
        };
        assert_eq!(t.window_maxima(2), vec![5.0, 8.0, 3.0, 9.0]);
        assert_eq!(t.window_maxima(3), vec![5.0, 8.0, 9.0]);
        assert_eq!(t.window_maxima(100), vec![9.0]);
        let empty = Trace {
            name: "e".into(),
            rate_per_s: vec![],
        };
        assert!(empty.window_maxima(5).is_empty());
    }

    #[test]
    fn resized_empty_series_does_not_panic() {
        // regression: `i % 0` panicked when a loaded trace was empty
        let empty = Trace {
            name: "e".into(),
            rate_per_s: vec![],
        };
        let r = empty.resized(10);
        assert_eq!(r.duration_s(), 10);
        assert!(r.rate_per_s.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn overlay_sums_and_pads() {
        let a = Trace::poisson(10.0, 3);
        let b = Trace::poisson(5.0, 5);
        let o = a.overlay(&b);
        assert_eq!(o.rate_per_s, vec![15.0, 15.0, 15.0, 5.0, 5.0]);
        // commutative on rates
        assert_eq!(b.overlay(&a).rate_per_s, o.rate_per_s);
    }

    #[test]
    fn splice_switches_workloads() {
        let a = Trace::poisson(10.0, 2);
        let b = Trace::poisson(3.0, 2);
        let s = a.splice(&b, 4); // a is shorter than the splice point
        assert_eq!(s.rate_per_s, vec![10.0, 10.0, 0.0, 0.0, 3.0, 3.0]);
        assert_eq!(a.splice(&b, 1).rate_per_s, vec![10.0, 3.0, 3.0]);
    }

    #[test]
    fn ramp_scales_linearly() {
        let t = Trace::poisson(100.0, 5);
        let r = t.ramp(0.0, 1.0);
        assert_eq!(r.rate_per_s[0], 0.0);
        assert_eq!(r.rate_per_s[4], 100.0);
        assert!((r.rate_per_s[2] - 50.0).abs() < 1e-9);
        // single-sample trace: factor is `from`, no divide-by-zero
        let one = Trace::poisson(10.0, 1).ramp(0.5, 2.0);
        assert_eq!(one.rate_per_s, vec![5.0]);
    }

    #[test]
    fn noise_is_seeded_and_sigma_zero_is_identity() {
        let t = Trace::poisson(50.0, 100);
        let a = t.noise(0.2, 9);
        let b = t.noise(0.2, 9);
        assert_eq!(a.rate_per_s, b.rate_per_s);
        assert_ne!(a.rate_per_s, t.noise(0.2, 10).rate_per_s);
        assert_eq!(t.noise(0.0, 9).rate_per_s, t.rate_per_s);
    }

    #[test]
    fn azure_is_heavy_tailed_and_deterministic() {
        let t = Trace::azure(4000, 1);
        assert_eq!(t.rate_per_s, Trace::azure(4000, 1).rate_per_s);
        assert_ne!(t.rate_per_s, Trace::azure(4000, 2).rate_per_s);
        // base normalized near 100 req/s (jitter/bursts push the mean up)
        let avg = t.avg_rate();
        assert!((60.0..=400.0).contains(&avg), "avg {avg}");
        // heavy tail: peak well above the median (lognormal jitter alone
        // would put the ratio near 2; bursts push it past this bound)
        let mut v = t.rate_per_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = crate::util::stats::percentile_sorted(&v, 50.0);
        assert!(t.peak_rate() / median >= 2.5, "ratio {}", t.peak_rate() / median);
        assert!(t.rate_per_s.iter().all(|&r| r >= 0.1));
    }

    #[test]
    fn flashcrowd_is_an_exact_step() {
        let t = Trace::flashcrowd(10, 5.0, 100.0, 3, 4);
        assert_eq!(t.rate_per_s[2], 5.0);
        assert_eq!(t.rate_per_s[3], 105.0);
        assert_eq!(t.rate_per_s[6], 105.0);
        assert_eq!(t.rate_per_s[7], 5.0);
        assert_eq!(t.duration_s(), 10);
        // spike window clipped by the duration is fine
        let clipped = Trace::flashcrowd(5, 0.0, 10.0, 4, 100);
        assert_eq!(clipped.rate_per_s, vec![0.0, 0.0, 0.0, 0.0, 10.0]);
    }

    #[test]
    fn loads_python_exported_trace_if_present() {
        let p = std::path::Path::new("artifacts/traces/wits.json");
        if p.exists() {
            let t = Trace::load_json(p).unwrap();
            assert_eq!(t.name, "wits");
            assert!(t.duration_s() >= 1000);
            assert!((200.0..=360.0).contains(&t.avg_rate()));
        }
    }
}
