//! Arrival traces: Poisson, Wiki-like diurnal, WITS-like bursty (§5.3).
//!
//! Rust re-implements the same generator formulas as
//! `python/compile/traces.py`, and can also *load* the exact traces the
//! Python side exported to `artifacts/traces/*.json` (used by Fig. 6 so
//! predictors are scored on the series the LSTM was trained against).
//!
//! A [`Trace`] is a per-second arrival-rate series; [`Trace::arrivals`]
//! expands it into concrete request timestamps via a piecewise-constant
//! Poisson process.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::util::{Micros, MICROS_PER_S};

/// A per-second arrival-rate series (requests/second).
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub rate_per_s: Vec<f64>,
}

impl Trace {
    pub fn duration_s(&self) -> usize {
        self.rate_per_s.len()
    }

    pub fn avg_rate(&self) -> f64 {
        crate::util::stats::mean(&self.rate_per_s)
    }

    pub fn peak_rate(&self) -> f64 {
        self.rate_per_s.iter().copied().fold(0.0, f64::max)
    }

    /// Scale the whole series by a factor (to match cluster capacity).
    pub fn scaled(&self, factor: f64) -> Trace {
        Trace {
            name: format!("{}x{factor:.3}", self.name),
            rate_per_s: self.rate_per_s.iter().map(|r| r * factor).collect(),
        }
    }

    /// Truncate/extend (by tiling) to `duration_s` seconds.
    pub fn resized(&self, duration_s: usize) -> Trace {
        let mut rate = Vec::with_capacity(duration_s);
        for i in 0..duration_s {
            rate.push(self.rate_per_s[i % self.rate_per_s.len()]);
        }
        Trace {
            name: self.name.clone(),
            rate_per_s: rate,
        }
    }

    /// Expand into concrete arrival timestamps (µs): within each second,
    /// draw a Poisson count at that second's rate and scatter uniformly.
    pub fn arrivals(&self, rng: &mut Pcg) -> Vec<Micros> {
        let mut out = Vec::new();
        for (sec, &rate) in self.rate_per_s.iter().enumerate() {
            let n = rng.poisson(rate);
            let base = sec as u64 * MICROS_PER_S;
            for _ in 0..n {
                out.push(base + (rng.f64() * MICROS_PER_S as f64) as u64);
            }
        }
        out.sort_unstable();
        out
    }

    /// Load a trace exported by `python/compile/aot.py`.
    pub fn load_json(path: &Path) -> Result<Trace> {
        let j = Json::parse_file(path)?;
        Ok(Trace {
            name: j.get("name")?.as_str()?.to_string(),
            rate_per_s: j.get("rate_per_s")?.as_f64_vec()?,
        })
    }

    /// Constant-rate Poisson trace (paper: synthetic λ = 50).
    pub fn poisson(lambda: f64, duration_s: usize) -> Trace {
        Trace {
            name: format!("poisson{lambda}"),
            rate_per_s: vec![lambda; duration_s],
        }
    }

    /// WITS-like bursty trace — same formula as python traces.wits_trace.
    pub fn wits(duration_s: usize, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let mut rate: Vec<f64> = (0..duration_s)
            .map(|t| {
                let base = 230.0
                    * (1.0 + 0.20 * (2.0 * std::f64::consts::PI * t as f64 / 1800.0).sin());
                base * rng.lognormal(0.0, 0.12)
            })
            .collect();
        // rare sharp spikes
        let mut pos = 0.0f64;
        loop {
            pos += rng.exponential(500.0);
            if pos >= duration_s as f64 {
                break;
            }
            let width = rng.range(20.0, 60.0);
            let amp = rng.range(650.0, 950.0);
            let sigma = width / 2.355;
            let lo = ((pos - 4.0 * sigma).max(0.0)) as usize;
            let hi = ((pos + 4.0 * sigma) as usize).min(duration_s);
            for (t, r) in rate.iter_mut().enumerate().take(hi).skip(lo) {
                let d = (t as f64 - pos) / sigma;
                *r += amp * (-0.5 * d * d).exp();
            }
        }
        for r in rate.iter_mut() {
            *r = r.clamp(1.0, 1250.0);
        }
        Trace {
            name: "wits".to_string(),
            rate_per_s: rate,
        }
    }

    /// Wiki-like diurnal trace — same formula as python traces.wiki_trace.
    pub fn wiki(duration_s: usize, seed: u64) -> Trace {
        let mut rng = Pcg::new(seed);
        let rate = (0..duration_s)
            .map(|t| {
                let tf = t as f64;
                let det = 1500.0
                    * (1.0
                        + 0.35 * (2.0 * std::f64::consts::PI * tf / 3600.0).sin()
                        + 0.12 * (2.0 * std::f64::consts::PI * tf / 600.0 + 1.0).sin());
                (det * rng.lognormal(0.0, 0.08)).max(1.0)
            })
            .collect();
        Trace {
            name: "wiki".to_string(),
            rate_per_s: rate,
        }
    }

    /// Max arrival rate per adjacent window (paper §4.5: W_s = 5 s).
    pub fn window_maxima(&self, window_s: usize) -> Vec<f64> {
        self.rate_per_s
            .chunks_exact(window_s)
            .map(|w| w.iter().copied().fold(0.0, f64::max))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wits_statistics_match_paper() {
        let t = Trace::wits(4000, 1316);
        let avg = t.avg_rate();
        let peak = t.peak_rate();
        assert!((200.0..=360.0).contains(&avg), "avg {avg}");
        assert!((1000.0..=1300.0).contains(&peak), "peak {peak}");
        let mut v = t.rate_per_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = crate::util::stats::percentile_sorted(&v, 50.0);
        assert!(peak / median >= 3.5, "ratio {}", peak / median);
    }

    #[test]
    fn wiki_statistics_match_paper() {
        let t = Trace::wiki(4000, 2025);
        let avg = t.avg_rate();
        assert!((1200.0..=1800.0).contains(&avg), "avg {avg}");
        assert!(t.peak_rate() / avg < 2.5);
    }

    #[test]
    fn deterministic_generation() {
        let a = Trace::wits(500, 1);
        let b = Trace::wits(500, 1);
        assert_eq!(a.rate_per_s, b.rate_per_s);
        let c = Trace::wits(500, 2);
        assert_ne!(a.rate_per_s, c.rate_per_s);
    }

    #[test]
    fn arrivals_sorted_and_rate_correct() {
        let t = Trace::poisson(100.0, 50);
        let mut rng = Pcg::new(3);
        let arr = t.arrivals(&mut rng);
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let rate = arr.len() as f64 / 50.0;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
        assert!(arr.iter().all(|&a| a < 50 * MICROS_PER_S));
    }

    #[test]
    fn scaling_and_resizing() {
        let t = Trace::poisson(10.0, 20);
        let s = t.scaled(2.0);
        assert_eq!(s.rate_per_s[0], 20.0);
        let r = t.resized(45);
        assert_eq!(r.duration_s(), 45);
        assert_eq!(r.rate_per_s[44], 10.0);
    }

    #[test]
    fn window_maxima_basics() {
        let t = Trace {
            name: "x".into(),
            rate_per_s: vec![1.0, 5.0, 2.0, 8.0, 3.0, 1.0],
        };
        assert_eq!(t.window_maxima(2), vec![5.0, 8.0, 3.0]);
        assert_eq!(t.window_maxima(3), vec![5.0, 8.0]);
    }

    #[test]
    fn loads_python_exported_trace_if_present() {
        let p = std::path::Path::new("artifacts/traces/wits.json");
        if p.exists() {
            let t = Trace::load_json(p).unwrap();
            assert_eq!(t.name, "wits");
            assert!(t.duration_s() >= 1000);
            assert!((200.0..=360.0).contains(&t.avg_rate()));
        }
    }
}
