//! Rust-native forwards for the JAX-trained NN predictors.
//!
//! Weights come from `artifacts/predictor_weights.json` (written by
//! `python/compile/lstm_train.py`). The math mirrors
//! `python/compile/kernels/ref.py` exactly — gate order i, f, g, o — and is
//! cross-checked against the AOT-compiled XLA artifact in
//! `rust/tests/test_runtime.rs`. The native forward is what the simulator
//! uses (millions of forecasts per run); the PJRT artifact is what the
//! live server uses (and what proves the L1/L2 path end-to-end).

use std::collections::VecDeque;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Predictor;
use crate::util::json::Json;

/// f32 row-major matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn from_json(v: &Json, rows: usize, cols: usize) -> Result<Mat> {
        let data = v.as_f32_flat()?;
        if data.len() != rows * cols {
            bail!("matrix size mismatch: {} != {rows}x{cols}", data.len());
        }
        Ok(Mat { rows, cols, data })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
}

/// y += x^T * M  (x: len rows, y: len cols)
fn vec_mat_acc(x: &[f32], m: &Mat, y: &mut [f32]) {
    debug_assert_eq!(x.len(), m.rows);
    debug_assert_eq!(y.len(), m.cols);
    for (r, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &m.data[r * m.cols..(r + 1) * m.cols];
        for (yv, &mv) in y.iter_mut().zip(row) {
            *yv += xv * mv;
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[derive(Debug, Clone)]
struct LstmLayer {
    wx: Mat, // (in, 4H)
    wh: Mat, // (H, 4H)
    b: Vec<f32>,
    hidden: usize,
}

impl LstmLayer {
    /// One cell step; h and c are updated in place. Matches
    /// `ref.lstm_cell_ref` (gate order i, f, g, o).
    fn step(&self, x: &[f32], h: &mut [f32], c: &mut [f32], scratch: &mut [f32]) {
        let hn = self.hidden;
        scratch[..4 * hn].copy_from_slice(&self.b);
        vec_mat_acc(x, &self.wx, &mut scratch[..4 * hn]);
        vec_mat_acc(h, &self.wh, &mut scratch[..4 * hn]);
        for j in 0..hn {
            let i = sigmoid(scratch[j]);
            let f = sigmoid(scratch[hn + j]);
            let g = scratch[2 * hn + j].tanh();
            let o = sigmoid(scratch[3 * hn + j]);
            c[j] = f * c[j] + i * g;
            h[j] = o * c[j].tanh();
        }
    }
}

/// The paper's LSTM load predictor: 2 layers × 32 units over the last
/// `window` arrival-rate samples, trained in JAX on the WITS trace.
#[derive(Debug, Clone)]
pub struct LstmPredictor {
    layers: Vec<LstmLayer>,
    w_out: Mat, // (H, 1)
    b_out: f32,
    pub scale: f64,
    /// "relative" = normalize each window by its own mean (scale-free);
    /// "global" = divide by the fixed training scale.
    pub relative_norm: bool,
    pub window: usize,
    hist: VecDeque<f64>,
}

impl LstmPredictor {
    pub fn load(path: &Path) -> Result<LstmPredictor> {
        let j = Json::parse_file(path).context("loading predictor weights")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<LstmPredictor> {
        let window = j.get("window")?.as_usize()?;
        let hidden = j.get("hidden")?.as_usize()?;
        let scale = j.get("scale")?.as_f64()?;
        let mut layers = Vec::new();
        let mut in_dim = 1usize;
        for l in j.get("layers")?.as_arr()? {
            layers.push(LstmLayer {
                wx: Mat::from_json(l.get("wx")?, in_dim, 4 * hidden)?,
                wh: Mat::from_json(l.get("wh")?, hidden, 4 * hidden)?,
                b: l.get("b")?
                    .as_f32_flat()?
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("bad bias"))?,
                hidden,
            });
            in_dim = hidden;
        }
        let w_out = Mat::from_json(j.get("w_out")?, hidden, 1)?;
        let b_out = j.get("b_out")?.as_f32_flat()?[0];
        let relative_norm = j
            .opt("norm")
            .and_then(|v| v.as_str().ok())
            .map(|n| n == "relative")
            .unwrap_or(false);
        Ok(LstmPredictor {
            layers,
            w_out,
            b_out,
            scale,
            relative_norm,
            window,
            hist: VecDeque::with_capacity(window),
        })
    }

    /// Forward over a full normalized window (also used by tests to
    /// cross-check against the PJRT artifact).
    ///
    /// Allocation-free inner loop: two flat (T × dim) sequence buffers are
    /// ping-ponged between layers (§Perf: removed the per-step
    /// `Vec<Vec<f32>>` clones — see docs/EXPERIMENTS.md).
    pub fn forward(&self, xs_norm: &[f32]) -> f32 {
        let hidden = self.layers[0].hidden;
        let t_len = xs_norm.len();
        let mut seq_a: Vec<f32> = xs_norm.to_vec(); // layer input, (T, in_dim)
        let mut in_dim = 1usize;
        let mut seq_b = vec![0.0f32; t_len * hidden]; // layer output
        let mut h = vec![0.0f32; hidden];
        let mut c = vec![0.0f32; hidden];
        let mut scratch = vec![0.0f32; 4 * hidden];
        for layer in &self.layers {
            h.iter_mut().for_each(|v| *v = 0.0);
            c.iter_mut().for_each(|v| *v = 0.0);
            for t in 0..t_len {
                let x = &seq_a[t * in_dim..(t + 1) * in_dim];
                layer.step(x, &mut h, &mut c, &mut scratch);
                seq_b[t * hidden..(t + 1) * hidden].copy_from_slice(&h);
            }
            std::mem::swap(&mut seq_a, &mut seq_b);
            if seq_b.len() < t_len * hidden {
                seq_b.resize(t_len * hidden, 0.0);
            }
            in_dim = hidden;
        }
        let last = &seq_a[(t_len - 1) * hidden..t_len * hidden];
        let mut y = self.b_out;
        for (j, &hv) in last.iter().enumerate() {
            y += hv * self.w_out.at(j, 0);
        }
        y
    }
}

impl Predictor for LstmPredictor {
    fn name(&self) -> &'static str {
        "LSTM"
    }

    fn observe(&mut self, w: f64) {
        if self.hist.len() == self.window {
            self.hist.pop_front();
        }
        self.hist.push_back(w);
    }

    fn forecast(&mut self) -> f64 {
        if self.hist.is_empty() {
            return 0.0;
        }
        let denom = if self.relative_norm {
            (self.hist.iter().sum::<f64>() / self.hist.len() as f64).max(1.0)
        } else {
            self.scale
        };
        // left-pad with the oldest observation until the window fills
        let mut xs = vec![(self.hist[0] / denom) as f32; self.window];
        let off = self.window - self.hist.len();
        for (i, &v) in self.hist.iter().enumerate() {
            xs[off + i] = (v / denom) as f32;
        }
        (self.forward(&xs) as f64 * denom).max(0.0)
    }

    fn warmup(&self) -> usize {
        self.window / 2
    }
}

/// The simple feed-forward baseline from Fig. 6 (window -> 32 -> 1).
#[derive(Debug, Clone)]
pub struct FfPredictor {
    layers: Vec<(Mat, Vec<f32>)>,
    pub scale: f64,
    pub relative_norm: bool,
    pub window: usize,
    hist: VecDeque<f64>,
}

impl FfPredictor {
    pub fn load(path: &Path) -> Result<FfPredictor> {
        let j = Json::parse_file(path).context("loading predictor weights")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<FfPredictor> {
        let window = j.get("window")?.as_usize()?;
        let scale = j.get("scale")?.as_f64()?;
        let mut layers = Vec::new();
        let mut in_dim = window;
        for l in j.get("ff")?.as_arr()? {
            let b = l.get("b")?.as_f32_flat()?;
            let out_dim = b.len();
            layers.push((Mat::from_json(l.get("w")?, in_dim, out_dim)?, b));
            in_dim = out_dim;
        }
        let relative_norm = j
            .opt("norm")
            .and_then(|v| v.as_str().ok())
            .map(|n| n == "relative")
            .unwrap_or(false);
        Ok(FfPredictor {
            layers,
            scale,
            relative_norm,
            window,
            hist: VecDeque::with_capacity(window),
        })
    }

    pub fn forward(&self, xs_norm: &[f32]) -> f32 {
        let mut x = xs_norm.to_vec();
        let n = self.layers.len();
        for (i, (w, b)) in self.layers.iter().enumerate() {
            let mut y = b.clone();
            vec_mat_acc(&x, w, &mut y);
            if i + 1 < n {
                for v in y.iter_mut() {
                    *v = v.max(0.0); // relu (final layer linear)
                }
            }
            x = y;
        }
        x[0]
    }
}

impl Predictor for FfPredictor {
    fn name(&self) -> &'static str {
        "FeedForward"
    }

    fn observe(&mut self, w: f64) {
        if self.hist.len() == self.window {
            self.hist.pop_front();
        }
        self.hist.push_back(w);
    }

    fn forecast(&mut self) -> f64 {
        if self.hist.is_empty() {
            return 0.0;
        }
        let denom = if self.relative_norm {
            (self.hist.iter().sum::<f64>() / self.hist.len() as f64).max(1.0)
        } else {
            self.scale
        };
        let mut xs = vec![(self.hist[0] / denom) as f32; self.window];
        let off = self.window - self.hist.len();
        for (i, &v) in self.hist.iter().enumerate() {
            xs[off + i] = (v / denom) as f32;
        }
        (self.forward(&xs) as f64 * denom).max(0.0)
    }

    fn warmup(&self) -> usize {
        self.window / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_lstm_json() -> Json {
        // 1 layer, hidden 2, window 4, zero weights except forget bias
        Json::parse(
            r#"{
                "window": 4, "hidden": 2, "scale": 100.0,
                "layers": [
                    {"wx": [[0.5, 0.5, 0.0, 0.0, 0.3, 0.3, 0.2, 0.2]],
                     "wh": [[0,0,0,0,0,0,0,0],[0,0,0,0,0,0,0,0]],
                     "b": [0, 0, 1, 1, 0, 0, 0, 0]}
                ],
                "w_out": [[1.0],[1.0]], "b_out": [0.1],
                "ff": [
                    {"w": [[1],[1],[1],[1]], "b": [0.0]}
                ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn lstm_loads_and_forwards() {
        let p = LstmPredictor::from_json(&tiny_lstm_json()).unwrap();
        let y = p.forward(&[0.1, 0.2, 0.3, 0.4]);
        assert!(y.is_finite());
        // hand-computed single step sanity: output bounded by 2*tanh(1)+b
        assert!(y.abs() < 2.1);
    }

    #[test]
    fn lstm_forward_deterministic() {
        let p = LstmPredictor::from_json(&tiny_lstm_json()).unwrap();
        assert_eq!(p.forward(&[0.5; 4]), p.forward(&[0.5; 4]));
    }

    #[test]
    fn lstm_predictor_interface() {
        let mut p = LstmPredictor::from_json(&tiny_lstm_json()).unwrap();
        for i in 0..6 {
            p.observe(50.0 + i as f64);
        }
        let f = p.forecast();
        assert!(f >= 0.0 && f.is_finite());
    }

    #[test]
    fn ff_identity_network() {
        let p = FfPredictor::from_json(&tiny_lstm_json()).unwrap();
        // single linear layer summing inputs
        let y = p.forward(&[0.1, 0.2, 0.3, 0.4]);
        assert!((y - 1.0).abs() < 1e-6, "{y}");
    }

    #[test]
    fn ff_forecast_denormalizes() {
        let mut p = FfPredictor::from_json(&tiny_lstm_json()).unwrap();
        for _ in 0..4 {
            p.observe(25.0); // norm 0.25 each, sum = 1.0 -> 100.0 denorm
        }
        assert!((p.forecast() - 100.0).abs() < 1e-3);
    }

    #[test]
    fn loads_real_weights_if_present() {
        let path = Path::new("artifacts/predictor_weights.json");
        if path.exists() {
            let p = LstmPredictor::load(path).unwrap();
            assert_eq!(p.window, 20);
            assert_eq!(p.layers.len(), 2);
            let y = p.forward(&[0.5; 20]);
            assert!(y.is_finite() && y.abs() < 10.0);
            let f = FfPredictor::load(path).unwrap();
            assert!(f.forward(&[0.5; 20]).is_finite());
        }
    }

    #[test]
    fn bad_weights_rejected() {
        let j = Json::parse(r#"{"window": 4}"#).unwrap();
        assert!(LstmPredictor::from_json(&j).is_err());
        assert!(FfPredictor::from_json(&j).is_err());
    }
}
