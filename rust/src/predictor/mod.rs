//! Load-prediction models (paper §4.5.1 / Fig. 6).
//!
//! Fifer samples the arrival rate in adjacent W_s = 5 s windows over the
//! past 100 s and forecasts the max arrival rate for the next monitoring
//! interval. The paper compares four non-ML models (fitted online over the
//! trailing history) and four ML models (pre-trained on 60% of the WITS
//! trace). We implement:
//!
//! | paper model       | here                                            |
//! |-------------------|-------------------------------------------------|
//! | MWA               | [`classic::Mwa`]                                |
//! | EWMA              | [`classic::Ewma`]                               |
//! | Linear Regression | [`classic::LinReg`]                             |
//! | Logistic Reg.     | [`classic::LogisticReg`]                        |
//! | Simple FF network | [`nn::FfPredictor`] (JAX-trained, rust forward) |
//! | LSTM              | [`nn::LstmPredictor`] (JAX-trained, AOT-export) |
//! | DeepAREstimator   | [`classic::Ar`] — online AR(3) substitute       |
//! | WeaveNet          | [`classic::Holt`] — Holt double-smoothing subst.|
//!
//! (The last two are closed-model substitutes, documented in docs/DESIGN.md §2;
//! both are autoregressive forecasters of the same input series.)
//!
//! The NN forwards also exist as AOT-compiled XLA artifacts executed via
//! PJRT from `runtime::PredictorExec` — the rust-native forwards here are
//! cross-checked against those artifacts in integration tests.

pub mod classic;
pub mod nn;

use crate::util::stats;

/// A max-arrival-rate forecaster over fixed sampling windows.
pub trait Predictor {
    fn name(&self) -> &'static str;

    /// Feed the max arrival rate observed in the window that just closed.
    fn observe(&mut self, window_max_rate: f64);

    /// Forecast the max arrival rate over the next monitoring interval.
    fn forecast(&mut self) -> f64;

    /// Number of observations required before forecasts are meaningful.
    fn warmup(&self) -> usize {
        2
    }
}

/// All predictors of Fig. 6, constructed with paper defaults.
/// `weights_path` points at artifacts/predictor_weights.json (NN models are
/// skipped when it is missing — e.g. before `make artifacts`).
pub fn all_predictors(weights_path: Option<&std::path::Path>) -> Vec<Box<dyn Predictor>> {
    let mut v: Vec<Box<dyn Predictor>> = vec![
        Box::new(classic::Mwa::new(20)),
        Box::new(classic::Ewma::new(0.5)),
        Box::new(classic::LinReg::new(20)),
        Box::new(classic::LogisticReg::new(20)),
        Box::new(classic::Ar::new(3, 20)),
        Box::new(classic::Holt::new(0.5, 0.3)),
    ];
    if let Some(p) = weights_path {
        if let Ok(l) = nn::LstmPredictor::load(p) {
            v.push(Box::new(l));
        }
        if let Ok(f) = nn::FfPredictor::load(p) {
            v.push(Box::new(f));
        }
    }
    v
}

/// Result of scoring one predictor over a trace (one Fig. 6a row).
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub name: &'static str,
    pub rmse: f64,
    /// Mean wall-clock latency per forecast call, in microseconds.
    pub latency_us: f64,
    /// Fraction of forecasts within `accuracy_band` of the actual max.
    pub accuracy_pct: f64,
    pub forecasts: Vec<f64>,
    pub actuals: Vec<f64>,
}

/// Score a predictor over a window-maxima series: at step i the model has
/// observed w[..=i] and forecasts max(w[i+1..=i+horizon]) (the next 10 s
/// monitoring interval, matching the LSTM's training target).
pub fn evaluate(
    p: &mut dyn Predictor,
    window_maxima: &[f64],
    horizon: usize,
    accuracy_band: f64,
) -> EvalResult {
    let mut forecasts = Vec::new();
    let mut actuals = Vec::new();
    let mut lat_ns = 0u128;
    let mut calls = 0u64;
    let warmup = p.warmup();
    for i in 0..window_maxima.len().saturating_sub(horizon) {
        p.observe(window_maxima[i]);
        if i + 1 < warmup {
            continue;
        }
        let t0 = std::time::Instant::now();
        let f = p.forecast();
        lat_ns += t0.elapsed().as_nanos();
        calls += 1;
        let actual = window_maxima[i + 1..=i + horizon]
            .iter()
            .copied()
            .fold(0.0f64, f64::max);
        forecasts.push(f);
        actuals.push(actual);
    }
    let rmse = stats::rmse(&forecasts, &actuals);
    let within = forecasts
        .iter()
        .zip(&actuals)
        .filter(|(f, a)| (*f - **a).abs() <= accuracy_band * **a)
        .count();
    EvalResult {
        name: p.name(),
        rmse,
        latency_us: if calls == 0 {
            0.0
        } else {
            lat_ns as f64 / calls as f64 / 1e3
        },
        accuracy_pct: if forecasts.is_empty() {
            0.0
        } else {
            100.0 * within as f64 / forecasts.len() as f64
        },
        forecasts,
        actuals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Persist(f64);
    impl Predictor for Persist {
        fn name(&self) -> &'static str {
            "persist"
        }
        fn observe(&mut self, w: f64) {
            self.0 = w;
        }
        fn forecast(&mut self) -> f64 {
            self.0
        }
    }

    #[test]
    fn evaluate_perfect_on_constant_series() {
        let w = vec![100.0; 50];
        let mut p = Persist(0.0);
        let r = evaluate(&mut p, &w, 2, 0.1);
        assert_eq!(r.rmse, 0.0);
        assert_eq!(r.accuracy_pct, 100.0);
        assert!(!r.forecasts.is_empty());
    }

    #[test]
    fn evaluate_measures_error() {
        // alternating series: persistence is always wrong by 100
        let w: Vec<f64> = (0..40)
            .map(|i| if i % 2 == 0 { 100.0 } else { 200.0 })
            .collect();
        let mut p = Persist(0.0);
        let r = evaluate(&mut p, &w, 1, 0.05);
        assert!((r.rmse - 100.0).abs() < 1e-9, "{}", r.rmse);
        assert_eq!(r.accuracy_pct, 0.0);
    }

    #[test]
    fn all_predictors_construct_without_weights() {
        let v = all_predictors(None);
        assert_eq!(v.len(), 6);
    }
}
