//! Non-ML predictors, fitted online over the trailing history (Fig. 6).

use std::collections::VecDeque;

use super::Predictor;
use crate::util::stats;

/// Bounded trailing history of window maxima.
#[derive(Debug, Clone)]
pub(crate) struct History {
    buf: VecDeque<f64>,
    cap: usize,
}

impl History {
    pub fn new(cap: usize) -> History {
        History {
            buf: VecDeque::with_capacity(cap),
            cap: cap.max(1),
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn last(&self) -> f64 {
        self.buf.back().copied().unwrap_or(0.0)
    }

    pub fn as_vec(&self) -> Vec<f64> {
        self.buf.iter().copied().collect()
    }
}

/// Moving Window Average: mean of the last `k` windows.
#[derive(Debug, Clone)]
pub struct Mwa {
    hist: History,
}

impl Mwa {
    pub fn new(k: usize) -> Mwa {
        Mwa {
            hist: History::new(k),
        }
    }
}

impl Predictor for Mwa {
    fn name(&self) -> &'static str {
        "MWA"
    }

    fn observe(&mut self, w: f64) {
        self.hist.push(w);
    }

    fn forecast(&mut self) -> f64 {
        stats::mean(&self.hist.as_vec())
    }
}

/// Exponentially Weighted Moving Average with smoothing factor alpha.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Ewma {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }
}

impl Predictor for Ewma {
    fn name(&self) -> &'static str {
        "EWMA"
    }

    fn observe(&mut self, w: f64) {
        self.value = Some(match self.value {
            None => w,
            Some(v) => self.alpha * w + (1.0 - self.alpha) * v,
        });
    }

    fn forecast(&mut self) -> f64 {
        self.value.unwrap_or(0.0)
    }

    fn warmup(&self) -> usize {
        1
    }
}

/// Linear regression over the last `k` windows, extrapolated one step.
#[derive(Debug, Clone)]
pub struct LinReg {
    hist: History,
}

impl LinReg {
    pub fn new(k: usize) -> LinReg {
        LinReg {
            hist: History::new(k),
        }
    }
}

impl Predictor for LinReg {
    fn name(&self) -> &'static str {
        "LinearR"
    }

    fn observe(&mut self, w: f64) {
        self.hist.push(w);
    }

    fn forecast(&mut self) -> f64 {
        let ys = self.hist.as_vec();
        if ys.len() < 2 {
            return self.hist.last();
        }
        let xs: Vec<f64> = (0..ys.len()).map(|i| i as f64).collect();
        let (a, b) = stats::linear_fit(&xs, &ys);
        (a + b * ys.len() as f64).max(0.0)
    }
}

/// Logistic regression: linear fit in logit space of the rate normalized
/// by the running max (the paper's "Logistic R." rate forecaster).
#[derive(Debug, Clone)]
pub struct LogisticReg {
    hist: History,
    max_seen: f64,
}

impl LogisticReg {
    pub fn new(k: usize) -> LogisticReg {
        LogisticReg {
            hist: History::new(k),
            max_seen: 1.0,
        }
    }
}

impl Predictor for LogisticReg {
    fn name(&self) -> &'static str {
        "LogisticR"
    }

    fn observe(&mut self, w: f64) {
        self.max_seen = self.max_seen.max(w);
        self.hist.push(w);
    }

    fn forecast(&mut self) -> f64 {
        let ys = self.hist.as_vec();
        if ys.len() < 2 {
            return self.hist.last();
        }
        let cap = self.max_seen * 1.1;
        let logits: Vec<f64> = ys
            .iter()
            .map(|&y| {
                let p = (y / cap).clamp(1e-4, 1.0 - 1e-4);
                (p / (1.0 - p)).ln()
            })
            .collect();
        let xs: Vec<f64> = (0..logits.len()).map(|i| i as f64).collect();
        let (a, b) = stats::linear_fit(&xs, &logits);
        let z: f64 = a + b * logits.len() as f64;
        let p = 1.0 / (1.0 + (-z).exp());
        (p * cap).max(0.0)
    }
}

/// Online autoregressive model AR(p), least-squares-fitted over the last
/// `k` windows (DeepAREstimator substitute — see module docs).
#[derive(Debug, Clone)]
pub struct Ar {
    p: usize,
    hist: History,
}

impl Ar {
    pub fn new(p: usize, k: usize) -> Ar {
        assert!(p >= 1);
        Ar {
            p,
            hist: History::new(k.max(p + 2)),
        }
    }

    /// Solve the p x p normal equations by Gaussian elimination.
    fn fit(&self, ys: &[f64]) -> Option<Vec<f64>> {
        let p = self.p;
        let n = ys.len();
        if n < p + 2 {
            return None;
        }
        // X[i] = ys[i..i+p], target ys[i+p]
        let rows = n - p;
        let mut xtx = vec![vec![0.0f64; p + 1]; p + 1]; // + intercept
        let mut xty = vec![0.0f64; p + 1];
        for i in 0..rows {
            let mut x = vec![1.0f64];
            x.extend_from_slice(&ys[i..i + p]);
            let y = ys[i + p];
            for a in 0..=p {
                for b in 0..=p {
                    xtx[a][b] += x[a] * x[b];
                }
                xty[a] += x[a] * y;
            }
        }
        // ridge for stability
        for (a, row) in xtx.iter_mut().enumerate() {
            row[a] += 1e-6;
        }
        gaussian_solve(&mut xtx, &mut xty)
    }
}

fn gaussian_solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for r in col + 1..n {
            let f = a[r][col] / a[col][col];
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in r + 1..n {
            s -= a[r][c] * x[c];
        }
        x[r] = s / a[r][r];
    }
    Some(x)
}

impl Predictor for Ar {
    fn name(&self) -> &'static str {
        "AR3(DeepAR-sub)"
    }

    fn observe(&mut self, w: f64) {
        self.hist.push(w);
    }

    fn forecast(&mut self) -> f64 {
        let ys = self.hist.as_vec();
        match self.fit(&ys) {
            None => self.hist.last(),
            Some(coef) => {
                let tail = &ys[ys.len() - self.p..];
                let mut pred = coef[0];
                for (i, &y) in tail.iter().enumerate() {
                    pred += coef[i + 1] * y;
                }
                pred.max(0.0)
            }
        }
    }

    fn warmup(&self) -> usize {
        self.p + 2
    }
}

/// Holt's double exponential smoothing: level + trend (WeaveNet
/// substitute — see module docs).
#[derive(Debug, Clone)]
pub struct Holt {
    alpha: f64,
    beta: f64,
    level: Option<f64>,
    trend: f64,
}

impl Holt {
    pub fn new(alpha: f64, beta: f64) -> Holt {
        Holt {
            alpha,
            beta,
            level: None,
            trend: 0.0,
        }
    }
}

impl Predictor for Holt {
    fn name(&self) -> &'static str {
        "Holt(WeaveNet-sub)"
    }

    fn observe(&mut self, w: f64) {
        match self.level {
            None => self.level = Some(w),
            Some(l0) => {
                let l1 = self.alpha * w + (1.0 - self.alpha) * (l0 + self.trend);
                self.trend = self.beta * (l1 - l0) + (1.0 - self.beta) * self.trend;
                self.level = Some(l1);
            }
        }
    }

    fn forecast(&mut self) -> f64 {
        (self.level.unwrap_or(0.0) + self.trend).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(p: &mut dyn Predictor, xs: &[f64]) {
        for &x in xs {
            p.observe(x);
        }
    }

    #[test]
    fn mwa_is_windowed_mean() {
        let mut p = Mwa::new(3);
        feed(&mut p, &[1.0, 2.0, 3.0, 4.0]);
        assert!((p.forecast() - 3.0).abs() < 1e-12); // last 3: 2,3,4
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut p = Ewma::new(0.5);
        feed(&mut p, &[10.0; 20]);
        assert!((p.forecast() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_step() {
        let mut p = Ewma::new(0.5);
        feed(&mut p, &[0.0; 5]);
        feed(&mut p, &[100.0; 5]);
        let f = p.forecast();
        assert!(f > 90.0 && f <= 100.0, "{f}");
    }

    #[test]
    fn linreg_extrapolates_trend() {
        let mut p = LinReg::new(10);
        feed(&mut p, &[10.0, 20.0, 30.0, 40.0]);
        assert!((p.forecast() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_never_negative() {
        let mut p = LinReg::new(10);
        feed(&mut p, &[40.0, 30.0, 20.0, 10.0, 1.0]);
        assert!(p.forecast() >= 0.0);
    }

    #[test]
    fn logistic_bounded_by_cap() {
        let mut p = LogisticReg::new(10);
        feed(&mut p, &[100.0, 200.0, 400.0, 800.0]);
        let f = p.forecast();
        assert!(f <= 800.0 * 1.1 + 1e-9, "{f}");
        assert!(f > 400.0, "{f}");
    }

    #[test]
    fn ar_learns_linear_recurrence() {
        // y[t] = y[t-1] + 5
        let mut p = Ar::new(3, 30);
        let xs: Vec<f64> = (0..20).map(|i| 5.0 * i as f64).collect();
        feed(&mut p, &xs);
        let f = p.forecast();
        assert!((f - 100.0).abs() < 1.0, "{f}");
    }

    #[test]
    fn ar_falls_back_with_short_history() {
        let mut p = Ar::new(3, 30);
        feed(&mut p, &[7.0, 8.0]);
        assert_eq!(p.forecast(), 8.0);
    }

    #[test]
    fn holt_tracks_trend() {
        let mut p = Holt::new(0.6, 0.4);
        let xs: Vec<f64> = (0..30).map(|i| 10.0 * i as f64).collect();
        feed(&mut p, &xs);
        let f = p.forecast();
        assert!((f - 300.0).abs() < 20.0, "{f}");
    }

    #[test]
    fn gaussian_solver_known_system() {
        // 2x + y = 5; x + 3y = 10 -> x = 1, y = 3
        let mut a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let mut b = vec![5.0, 10.0];
        let x = gaussian_solve(&mut a, &mut b).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9 && (x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn gaussian_solver_singular() {
        let mut a = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        let mut b = vec![2.0, 2.0];
        assert!(gaussian_solve(&mut a, &mut b).is_none());
    }
}
