//! # Fifer
//!
//! A reproduction of *"Fifer: Tackling Underutilization in the Serverless
//! Era"* (Gunasekaran et al., Middleware 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Fifer is a stage-aware resource-management (RM) framework for serverless
//! *function chains*: sequences of short-lived ML microservices with a
//! shared end-to-end SLO. The core ideas reproduced here:
//!
//! * **Slack-aware request batching** — the gap between a chain's execution
//!   time and its SLO ("slack") is distributed across stages proportionally
//!   to stage execution time; the per-stage batch size is
//!   `B_size = stage_slack / stage_exec_time` (paper Eq. 1).
//! * **Stage-aware reactive scaling (RScale)** — per-stage queuing-delay
//!   monitoring spawns containers only when the queuing delay exceeds the
//!   cold-start delay.
//! * **Proactive scaling** — an LSTM load predictor (trained offline in JAX,
//!   executed via an AOT-compiled XLA artifact) forecasts arrivals so
//!   containers are spawned *before* the load hits, hiding cold starts.
//! * **Greedy bin-packing** — least-free-slots container selection and
//!   least-available-resources node selection consolidate load onto few
//!   servers for energy savings.
//!
//! The crate contains both a **live serving runtime** (real batched ML
//! inference through PJRT-compiled XLA artifacts; Python never on the
//! request path) and a **high-fidelity event-driven cluster simulator**
//! used for the paper's large-scale trace-driven experiments.
//!
//! Both run on **one engine with two drivers**: the coordinator state
//! machine ([`coordinator::engine::EngineCore`]) owns every scheduling
//! decision, parameterized over a small [`coordinator::engine::Driver`]
//! that supplies time and effects — virtual time with modeled latencies
//! in the simulator, wall-clock time with real executor threads in the
//! live server. Resource-management policies are **plug-ins**: the
//! paper's five RMs, the Knative-style `Kn` autoscaler, and the
//! `FiferEq` ablation all implement
//! [`coordinator::policy::SchedulerPolicy`], and both drivers exercise
//! the full hook surface against the same trait objects (live runs get
//! policy-driven container autoscaling, not just batching). See
//! `examples/custom_policy.rs` for a user-defined policy run through
//! [`sim::run_sim_with`].
//!
//! Workloads are **data**: the [`scenario`] module turns the paper's
//! fixed trace × mix × RM evaluation grid into declarative TOML scenario
//! files with composable traces ([`trace::Trace::overlay`] and friends)
//! and runs arbitrary matrices through a sharded, deterministic parallel
//! sweep runner (`fifer scenario run <file> --threads N`).
//!
//! # Layer map
//!
//! | layer | modules |
//! |-------|---------|
//! | workloads | [`trace`], [`model`], [`scenario`] |
//! | policies | [`coordinator::policy`], [`config`] (registry facade) |
//! | engine core | [`coordinator::engine`] (one state machine, `Driver`-parameterized) |
//! | drivers | [`sim`] (virtual time), [`server`] + [`runtime`] (real time, PJRT/synthetic) |
//! | mechanics | [`coordinator`] (store/queues/slack/scaling), [`coldstart`], [`energy`] |
//! | prediction | [`predictor`] (EWMA/ARIMA/LSTM zoo) |
//! | evaluation | [`experiments`], [`metrics`], [`bench`], [`estimator`] (offline lower bounds / optimality gap) |
//! | observability | [`obs`] (SLO contract, timeline ring, `/metrics` endpoint — one schema for both drivers) |
//! | support | [`cli`], [`util`] (vendored rng/json/stats) |
//!
//! See the top-level `README.md` for the quickstart, `docs/DESIGN.md`
//! for the experiment index and design notes, and `docs/EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod coldstart;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod estimator;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod predictor;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod sim;
pub mod trace;
pub mod util;
