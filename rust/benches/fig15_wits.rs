//! Fig. 15 — macro benchmark: WITS trace, 2500-core simulated cluster.
//!
//! All three workload mixes. Paper shape: sudden 5× peak-to-median spikes
//! make reactive RMs over-spawn — Fifer uses 7.7× / 2.7× fewer containers
//! than BPred / RScale while keeping SLO compliance near Bline's.

use fifer::bench::{norm, section, Table};
use fifer::experiments::{run_macro, TraceKind};

fn main() {
    let duration = 900; // covers multiple WITS spikes
    for mix in ["Heavy", "Medium", "Light"] {
        section(
            "Fig. 15",
            &format!("WITS trace — {mix} mix, {duration} s, 2500 cores"),
        );
        let runs = run_macro(TraceKind::Wits, mix, duration, 42);
        let base = runs[0].summary.clone();
        let mut t = Table::new(&[
            "policy",
            "SLO viol %",
            "avg containers",
            "norm to Bline",
            "cold starts",
        ]);
        for r in &runs {
            t.row(&[
                r.policy.name().to_string(),
                format!("{:.2}", r.summary.slo_violation_pct),
                format!("{:.0}", r.summary.avg_containers),
                norm(r.summary.avg_containers, base.avg_containers),
                format!("{}", r.summary.cold_starts),
            ]);
        }
        t.print();
    }
}
