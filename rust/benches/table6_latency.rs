//! Table 6 — median and tail (P99) latencies, Wiki & WITS, heavy mix.
//!
//! Paper values (ms):
//! ```text
//!          Wiki med/tail    WITS med/tail
//! Bline      233 /  3967      237 /  5807
//! SBatch     458 / 13349      437 / 17736
//! RScale     251 / 10245      252 / 12164
//! BPred      281 /  4240      290 /  5914
//! Fifer      413 /  4952      354 /  6151
//! ```
//! Shape to hold: batching raises medians; SBatch/RScale tails blow up;
//! Fifer's tail stays near Bline/BPred.

use fifer::bench::{section, Table};
use fifer::experiments::{run_macro, TraceKind};

fn main() {
    section("Table 6", "median and tail latency (ms), heavy workload mix");
    let wiki = run_macro(TraceKind::Wiki, "Heavy", 600, 42);
    let wits = run_macro(TraceKind::Wits, "Heavy", 900, 42);
    let mut t = Table::new(&[
        "policy", "Wiki med", "Wiki tail", "WITS med", "WITS tail",
    ]);
    for (a, b) in wiki.iter().zip(&wits) {
        t.row(&[
            a.policy.name().to_string(),
            format!("{:.0}", a.summary.median_ms),
            format!("{:.0}", a.summary.p99_ms),
            format!("{:.0}", b.summary.median_ms),
            format!("{:.0}", b.summary.p99_ms),
        ]);
    }
    t.print();
}
