//! Fig. 14 — macro benchmark: Wiki trace, 2500-core simulated cluster.
//!
//! All three workload mixes; SLO violations and average containers
//! normalized to Bline. Paper shape: RScale/BPred spawn up to 3.5× more
//! containers than Fifer while still violating ~5% more SLOs; Fifer rides
//! the diurnal pattern via the LSTM.

use fifer::bench::{norm, section, Table};
use fifer::experiments::{run_macro, TraceKind};

fn main() {
    // duration bounded for single-core CI; docs/EXPERIMENTS.md records the
    // long-run numbers (the trace tiles to any duration).
    let duration = 600;
    for mix in ["Heavy", "Medium", "Light"] {
        section(
            "Fig. 14",
            &format!("Wiki trace — {mix} mix, {duration} s, 2500 cores"),
        );
        let runs = run_macro(TraceKind::Wiki, mix, duration, 42);
        let base = runs[0].summary.clone();
        let mut t = Table::new(&[
            "policy",
            "SLO viol %",
            "avg containers",
            "norm to Bline",
            "cold starts",
        ]);
        for r in &runs {
            t.row(&[
                r.policy.name().to_string(),
                format!("{:.2}", r.summary.slo_violation_pct),
                format!("{:.0}", r.summary.avg_containers),
                norm(r.summary.avg_containers, base.avg_containers),
                format!("{}", r.summary.cold_starts),
            ]);
        }
        t.print();
    }
}
