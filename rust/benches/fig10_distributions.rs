//! Fig. 10 — latency and queuing-time distributions, heavy mix.
//!
//! (a) response-latency CDF up to P95 (paper: batching RMs shift the
//! median right but stay within SLO); (b) queuing-time distribution
//! (paper: Fifer/RScale queue heavily by design; Bline/BPred irregular).

use fifer::bench::{section, Table};
use fifer::config::Policy;
use fifer::experiments::run_prototype;

fn main() {
    let runs = run_prototype("Heavy", 1500, 42);

    section("Fig. 10a", "response-latency CDF to P95 (ms)");
    let quantiles = [10.0, 25.0, 50.0, 75.0, 90.0, 95.0];
    let mut t = Table::new(&["policy", "p10", "p25", "p50", "p75", "p90", "p95"]);
    for r in &runs {
        let mut responses: Vec<f64> = r
            .recorder
            .jobs
            .iter()
            .map(|j| fifer::util::to_ms(j.response()))
            .collect();
        responses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut row = vec![r.policy.name().to_string()];
        for q in quantiles {
            row.push(format!(
                "{:.0}",
                fifer::util::stats::percentile_sorted(&responses, q)
            ));
        }
        t.row(&row);
    }
    t.print();

    section("Fig. 10b", "stage queuing-time distribution (ms)");
    let mut t = Table::new(&["policy", "q50", "q90", "q99"]);
    for r in &runs {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.1}", r.summary.queue_wait_median_ms),
            {
                let cdf = r.recorder.queue_cdf(10);
                format!("{:.1}", cdf.get(8).map(|p| p.0).unwrap_or(0.0))
            },
            format!("{:.1}", r.summary.queue_wait_p99_ms),
        ]);
    }
    t.print();

    let fifer = runs.iter().find(|r| r.policy == Policy::Fifer).unwrap();
    let bline = runs.iter().find(|r| r.policy == Policy::Bline).unwrap();
    println!(
        "\nmedian queuing: Fifer {:.1} ms vs Bline {:.1} ms — slack absorbed \
         into queues by design (paper §6.1.3)",
        fifer.summary.queue_wait_median_ms, bline.summary.queue_wait_median_ms
    );
}
