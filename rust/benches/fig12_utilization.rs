//! Fig. 12 — sources of improvement: container utilization.
//!
//! (a) average requests executed per container (RPC) per IPA stage —
//! paper: Fifer highest everywhere, Bline/BPred worst on the short NLP
//! stage; (b) containers alive over time in 10 s bins — paper: RScale and
//! Fifer track the request rate with far fewer containers than Bline.

use fifer::bench::{section, Table};
use fifer::experiments::run_prototype;
use fifer::model::Catalog;

fn main() {
    let cat = Catalog::paper();
    let runs = run_prototype("Heavy", 1500, 42);

    section("Fig. 12a", "requests per container (RPC) per IPA stage");
    let ipa = &cat.chains[cat.chain_id("IPA").unwrap()];
    let mut t = Table::new(&["policy", "ASR", "NLP", "QA", "all-stage avg"]);
    for r in &runs {
        let mut row = vec![r.policy.name().to_string()];
        let mut total_jobs = 0u64;
        let mut total_cont = 0u64;
        for &s in &ipa.stages {
            let st = r.summary.per_stage.get(&s).copied().unwrap_or_default();
            row.push(format!("{:.1}", st.rpc()));
        }
        for st in r.summary.per_stage.values() {
            total_jobs += st.jobs;
            total_cont += st.containers;
        }
        row.push(format!("{:.1}", total_jobs as f64 / total_cont.max(1) as f64));
        t.row(&row);
    }
    t.print();

    section("Fig. 12b", "containers alive over time (10 s bins, sampled)");
    // headers derive from the registry, so new policies appear for free
    let mut headers = vec!["t (s)".to_string()];
    headers.extend(runs.iter().map(|r| r.policy.name().to_string()));
    let header_refs: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new(&header_refs);
    let series: Vec<Vec<(f64, usize)>> = runs
        .iter()
        .map(|r| r.recorder.containers_over_time(10))
        .collect();
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    for i in (0..n).step_by(15) {
        let mut row = vec![format!("{:.0}", series[0][i].0)];
        row.extend(series.iter().map(|s| format!("{}", s[i].1)));
        t.row(&row);
    }
    t.print();
}
