//! Fig. 8 — prototype: SLO violations + containers, 5 RMs × 3 mixes.
//!
//! Poisson λ=50 on the 80-core cluster, everything normalized to Bline
//! (as the paper plots it). Expected shape: SBatch fewest containers but
//! the most violations; Fifer/RScale far fewer containers than
//! Bline/BPred; Fifer's violations ≈ Bline's.

use fifer::bench::{norm, section, Table};
use fifer::experiments::run_prototype;

fn main() {
    let duration = 1500;
    for mix in ["Heavy", "Medium", "Light"] {
        section(
            "Fig. 8",
            &format!("{mix} mix — Poisson λ=50, {duration} s, prototype cluster"),
        );
        let runs = run_prototype(mix, duration, 42);
        let base = runs[0].summary.clone();
        let mut t = Table::new(&[
            "policy",
            "SLO viol %",
            "viol norm",
            "avg containers",
            "cont norm",
            "spawned",
        ]);
        for r in &runs {
            t.row(&[
                r.policy.name().to_string(),
                format!("{:.2}", r.summary.slo_violation_pct),
                norm(
                    r.summary.slo_violation_pct.max(0.01),
                    base.slo_violation_pct.max(0.01),
                ),
                format!("{:.1}", r.summary.avg_containers),
                norm(r.summary.avg_containers, base.avg_containers),
                format!("{}", r.summary.total_spawned),
            ]);
        }
        t.print();
    }
}
