//! Fig. 13 — cluster-wide energy, normalized to Bline.
//!
//! All three mixes, prototype cluster. Paper shape: Fifer ~31% more
//! energy-efficient than Bline (heavy mix), ~17% better than RScale, and
//! within ~4% of SBatch — driven by bin-packing active containers onto
//! fewer powered nodes.

use fifer::bench::{section, Table};
use fifer::config::Policy;
use fifer::experiments::{run_policies, TraceKind};

fn main() {
    section("Fig. 13", "cluster energy normalized to Bline (lower is better)");
    let mut t = Table::new(&["mix", "Bline", "SBatch", "RScale", "BPred", "Fifer", "Fifer saving"]);
    for mix in ["Heavy", "Medium", "Light"] {
        // paper figure: only the paper's five RMs (the hardcoded columns
        // above index into this head) — don't burn sim time on the rest
        let runs = run_policies(&Policy::PAPER, mix, TraceKind::Poisson, 1500, true, 42);
        let base = runs[0].summary.energy_wh;
        let fifer = runs[4].summary.energy_wh;
        t.row(&[
            mix.to_string(),
            "1.00".to_string(),
            format!("{:.2}", runs[1].summary.energy_wh / base),
            format!("{:.2}", runs[2].summary.energy_wh / base),
            format!("{:.2}", runs[3].summary.energy_wh / base),
            format!("{:.2}", fifer / base),
            format!("{:.1}%", 100.0 * (1.0 - fifer / base)),
        ]);
    }
    t.print();
    println!("(paper: Fifer ≈ 31% savings vs Bline on the heavy mix)");
}
