//! §6.1.5 system overheads + the §Perf hot-path microbenchmarks.
//!
//! Paper reference points: LSF scheduling decision ~0.35 ms; DB reads/
//! writes ≤1.25 ms; LSTM prediction ~2.5 ms (off the critical path).
//! Targets here (in-process state store, no mongod): decisions well under
//! 50 µs, LSTM forecast well under 2.5 ms, simulator ≥ 1M events/s-scale
//! throughput on trivial events.

use fifer::bench::{bench, section, Table};
use fifer::config::Policy;
use fifer::coordinator::queue::{Ordering as QOrder, QueueEntry, StageQueue};
use fifer::coordinator::state::StateStore;
use fifer::experiments::{run_policy, TraceKind};
use fifer::predictor::{nn::LstmPredictor, Predictor};
use fifer::util::stats;

fn main() {
    let mut t = Table::new(&["operation", "mean", "p50", "p99", "paper ref"]);

    // LSF queue push+pop
    let mut q = StageQueue::new(QOrder::LeastSlackFirst);
    for i in 0..10_000u64 {
        q.push(QueueEntry {
            job_id: i,
            lsf_key: (i * 2_654_435_761) % 1_000_000,
            enqueued: i,
            seq: i,
        });
    }
    let mut i = 10_000u64;
    let r = bench("lsf push+pop @10k", 300, || {
        q.push(QueueEntry {
            job_id: i,
            lsf_key: (i * 2_654_435_761) % 1_000_000,
            enqueued: i,
            seq: i,
        });
        std::hint::black_box(q.pop());
        i += 1;
    });
    t.row(&[
        "LSF enqueue+dequeue (10k deep)".into(),
        format!("{:.2} µs", r.mean_us()),
        format!("{:.2} µs", r.p50_ns / 1e3),
        format!("{:.2} µs", r.p99_ns / 1e3),
        "0.35 ms/decision".into(),
    ]);

    // greedy container selection over a realistic pool
    let mut store = StateStore::new(78, 32, 0.5);
    for k in 0..2000 {
        let cid = store.spawn(k % 7, 8, 0, 0, false).unwrap();
        let c = store.containers.get_mut(&cid).unwrap();
        for _ in 0..(k % 8) {
            c.local.push_back(0);
        }
    }
    let r = bench("pick_container @2000", 300, || {
        std::hint::black_box(store.pick_container(3));
    });
    t.row(&[
        "greedy container pick (2000 pool)".into(),
        format!("{:.2} µs", r.mean_us()),
        format!("{:.2} µs", r.p50_ns / 1e3),
        format!("{:.2} µs", r.p99_ns / 1e3),
        "<=1.25 ms (db query)".into(),
    ]);

    // greedy node selection
    let r = bench("pick_node @78", 200, || {
        std::hint::black_box(store.pick_node());
    });
    t.row(&[
        "greedy node pick (78 nodes)".into(),
        format!("{:.2} µs", r.mean_us()),
        format!("{:.2} µs", r.p50_ns / 1e3),
        format!("{:.2} µs", r.p99_ns / 1e3),
        "k8s scheduler pass".into(),
    ]);

    // LSTM forecast (rust-native, the simulator's path)
    let wp = std::path::Path::new("artifacts/predictor_weights.json");
    if wp.exists() {
        let mut lstm = LstmPredictor::load(wp).unwrap();
        for k in 0..20 {
            lstm.observe(100.0 + k as f64);
        }
        let r = bench("lstm forecast", 300, || {
            std::hint::black_box(lstm.forecast());
        });
        t.row(&[
            "LSTM forecast (native)".into(),
            format!("{:.2} µs", r.mean_us()),
            format!("{:.2} µs", r.p50_ns / 1e3),
            format!("{:.2} µs", r.p99_ns / 1e3),
            "2.5 ms (paper, keras)".into(),
        ]);
    }
    t.print();

    // whole-sim throughput + sampled dispatch decision latency (§6.1.5)
    section("Perf", "end-to-end simulator throughput (heavy mix, λ=50)");
    let t0 = std::time::Instant::now();
    let run = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 600, true, 42);
    let wall = t0.elapsed().as_secs_f64();
    let stage_events: u64 = run.summary.jobs * 4; // ≈2 events per stage visit
    println!(
        "sim 600 s ({} jobs) in {:.2} s wall -> {:.0} jobs/s, ~{:.2} M events/s",
        run.summary.jobs,
        wall,
        run.summary.jobs as f64 / wall,
        stage_events as f64 / wall / 1e6
    );
    let dn: Vec<f64> = run.recorder.decision_ns.iter().map(|&n| n as f64).collect();
    if !dn.is_empty() {
        println!(
            "sampled dispatch decision: mean {:.2} µs, p99 {:.2} µs \
             (paper LSF decision: 350 µs)",
            stats::mean(&dn) / 1e3,
            stats::percentile(&dn, 99.0) / 1e3
        );
    }

    // PJRT batched-inference batch sweep: calibrates batch_cost_gamma
    let art = std::path::Path::new("artifacts");
    if art.join("manifest.json").exists() {
        section("Perf", "PJRT batched inference scaling (gamma calibration)");
        let mut rt = fifer::runtime::Runtime::new(art).unwrap();
        let mut t = Table::new(&["microservice", "batch", "ms/batch", "ms/req", "speedup"]);
        for name in ["QA", "HS"] {
            let dim = rt.manifest.microservices[name].input_dim;
            let mut per1 = 0.0f64;
            for &b in &[1usize, 4, 16, 32] {
                let x = vec![0.1f32; b * dim];
                rt.infer(name, b, &x).unwrap(); // warm compile
                let mut samples = Vec::new();
                for _ in 0..10 {
                    let t0 = std::time::Instant::now();
                    rt.infer(name, b, &x).unwrap();
                    samples.push(t0.elapsed().as_secs_f64() * 1e3);
                }
                let ms = stats::median(&samples);
                if b == 1 {
                    per1 = ms;
                }
                t.row(&[
                    name.into(),
                    format!("{b}"),
                    format!("{ms:.2}"),
                    format!("{:.2}", ms / b as f64),
                    format!("{:.2}x", per1 / (ms / b as f64)),
                ]);
            }
        }
        t.print();
        println!("(simulator batch_cost_gamma defaults to 0.25; see EXPERIMENTS.md §Perf)");
    }
}
