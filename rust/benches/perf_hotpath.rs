//! §6.1.5 system overheads + the §Perf hot-path microbenchmarks.
//!
//! Paper reference points: LSF scheduling decision ~0.35 ms; DB reads/
//! writes ≤1.25 ms; LSTM prediction ~2.5 ms (off the critical path).
//! Targets here (in-process indexed state store, no mongod): decisions
//! well under 50 µs, LSTM forecast well under 2.5 ms, simulator ≥ 1M
//! events/s-scale throughput on trivial events.
//!
//! Emits machine-readable results to `BENCH_perf.json` at the repository
//! root so the perf trajectory is tracked across PRs. The pool-size sweep
//! times each indexed decision against a naive linear-scan reference over
//! the same store (the pre-index implementation), asserting both agree
//! on every answer before trusting the speedup. Set `FIFER_BENCH_QUICK=1`
//! (CI smoke) to trim the sweep and the end-to-end simulation.
//!
//! With `--features bench-alloc` a counting global allocator is
//! installed and the **zero-allocation dispatch pin** is asserted: a
//! steady-state dispatch cycle (enqueue → pick_container → pop →
//! dispatch → begin_batch → finish_batch with a warm container
//! available and no spawn) must perform 0 heap allocations, and
//! end-to-end `jobs_per_s` must stay within the pinned fraction of the
//! committed baseline (`benches/perf_baseline.json`). A violation
//! panics, failing the CI perf job.

use fifer::bench::{bench, section, Table, Timing};
use fifer::config::{Policy, SystemConfig};
use fifer::coordinator::queue::{Ordering as QOrder, QueueEntry, StageQueue};
use fifer::coordinator::sharded::ShardRouter;
use fifer::coordinator::state::StateStore;
use fifer::experiments::{run_policy, TraceKind};
use fifer::model::Catalog;
use fifer::obs::ObsConfig;
use fifer::predictor::{nn::LstmPredictor, Predictor};
use fifer::sim::sharded::run_sharded_summarized;
use fifer::sim::SimParams;
use fifer::trace::Trace;
use fifer::util::json::Json;
use fifer::util::{secs, stats};

/// The allocation counter behind the zero-alloc pin (see module docs).
#[cfg(feature = "bench-alloc")]
#[global_allocator]
static ALLOC: fifer::bench::alloc_count::CountingAlloc =
    fifer::bench::alloc_count::CountingAlloc;

/// Zero the allocation counter (no-op without `bench-alloc`).
fn alloc_reset() {
    #[cfg(feature = "bench-alloc")]
    fifer::bench::alloc_count::reset();
}

/// Heap allocations since the last [`alloc_reset`]; None without the
/// `bench-alloc` feature (nothing is counted).
fn alloc_count() -> Option<u64> {
    #[cfg(feature = "bench-alloc")]
    {
        Some(fifer::bench::alloc_count::allocs())
    }
    #[cfg(not(feature = "bench-alloc"))]
    {
        None
    }
}

/// The scan-based container pick the indexed store replaced — kept here
/// as the yardstick (and correctness oracle) for the sweep.
fn naive_pick_container(store: &StateStore, ms_id: usize) -> Option<u64> {
    store
        .iter()
        .filter(|c| c.ms_id == ms_id && c.is_warm() && c.free_slots() > 0)
        .map(|c| {
            (
                c.free_slots(),
                std::cmp::Reverse(store.nodes[c.node].containers),
                c.id,
            )
        })
        .min()
        .map(|(_, _, id)| id)
}

/// The scan-based node pick the packing index replaced.
fn naive_pick_node(store: &StateStore) -> Option<usize> {
    let need = store.cpu_per_container;
    store
        .nodes
        .iter()
        .filter(|n| n.free_cores() >= need - 1e-9)
        .min_by(|a, b| {
            a.free_cores()
                .partial_cmp(&b.free_cores())
                .unwrap()
                .then(a.id.cmp(&b.id))
        })
        .map(|n| n.id)
}

/// The scan-based warm-slot aggregate the running counters replaced.
fn naive_warm_free_slots(store: &StateStore, ms_id: usize) -> usize {
    store
        .iter()
        .filter(|c| c.ms_id == ms_id && c.is_warm())
        .map(|c| c.free_slots())
        .sum()
}

/// Build a store with `pool` containers spread over 7 stages, each with
/// batch size 8 and a varied fill level (the perf_hotpath fixture shape).
fn build_pool(nodes: usize, cores: usize, pool: usize) -> StateStore {
    let mut store = StateStore::new(nodes, cores, 0.5);
    let mut jid = 0u64;
    for k in 0..pool {
        let cid = store.spawn(k % 7, 8, 0, 0, false).expect("pool fits cluster");
        for _ in 0..(k % 8) {
            jid += 1;
            store.dispatch(cid, jid, 0);
        }
    }
    store
}

/// One steady-state dispatch cycle over the pinned fixture: enqueue →
/// pick_container → pop → dispatch → begin_batch → finish_batch, with a
/// warm container always available (no spawn, no eviction). The fixture
/// is shaped so every B-tree on the path stays at 2..=11 elements — a
/// single root node, where remove+insert never splits, merges, or frees
/// — and every Vec/VecDeque runs at settled capacity, so the cycle is
/// heap-silent. That is the property the `bench-alloc` pin asserts.
fn dispatch_cycle(
    store: &mut StateStore,
    q: &mut StageQueue,
    seq: &mut u64,
    now: &mut u64,
    batch_buf: &mut Vec<u64>,
    done_buf: &mut Vec<u64>,
) {
    *seq += 1;
    *now += 1;
    q.push(QueueEntry {
        job_id: *seq,
        lsf_key: *seq,
        enqueued: *now,
        seq: *seq,
    });
    let cid = store.pick_container(0).expect("warm container available");
    let entry = q.pop().expect("standing backlog");
    let was_idle = store.dispatch(cid, entry.job_id, *now);
    assert!(was_idle, "fixture containers are idle between cycles");
    let b = store.begin_batch(cid, batch_buf);
    std::hint::black_box(b.len);
    *now += 1;
    let ms = store.finish_batch(cid, *now, done_buf);
    std::hint::black_box(ms);
}

fn case_json(name: &str, pool: usize, t: &Timing) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("pool", Json::Num(pool as f64)),
        ("mean_us", Json::Num(t.mean_ns / 1e3)),
        ("p50_us", Json::Num(t.p50_ns / 1e3)),
        ("p99_us", Json::Num(t.p99_ns / 1e3)),
    ])
}

fn main() {
    let quick = std::env::var("FIFER_BENCH_QUICK").is_ok();
    // the §6.1.5 dispatch-decision latency probe is opt-in (it reads
    // host time inside the engine); this bench is the opt-in site
    std::env::set_var("FIFER_DECISION_PROBE", "1");
    let mut cases: Vec<Json> = Vec::new();
    let mut t = Table::new(&["operation", "mean", "p50", "p99", "paper ref"]);

    // LSF queue push+pop
    let mut q = StageQueue::new(QOrder::LeastSlackFirst);
    for i in 0..10_000u64 {
        q.push(QueueEntry {
            job_id: i,
            lsf_key: (i * 2_654_435_761) % 1_000_000,
            enqueued: i,
            seq: i,
        });
    }
    let mut i = 10_000u64;
    let r = bench("lsf push+pop @10k", 300, || {
        q.push(QueueEntry {
            job_id: i,
            lsf_key: (i * 2_654_435_761) % 1_000_000,
            enqueued: i,
            seq: i,
        });
        std::hint::black_box(q.pop());
        i += 1;
    });
    t.row(&[
        "LSF enqueue+dequeue (10k deep)".into(),
        format!("{:.2} µs", r.mean_us()),
        format!("{:.2} µs", r.p50_ns / 1e3),
        format!("{:.2} µs", r.p99_ns / 1e3),
        "0.35 ms/decision".into(),
    ]);
    cases.push(case_json("lsf_push_pop", 10_000, &r));

    // oldest-enqueued monitoring probe (O(log n) mirror vs old heap scan)
    let r = bench("oldest_enqueued @10k", 100, || {
        std::hint::black_box(q.oldest_enqueued());
    });
    t.row(&[
        "LSF oldest-enqueued probe (10k deep)".into(),
        format!("{:.2} µs", r.mean_us()),
        format!("{:.2} µs", r.p50_ns / 1e3),
        format!("{:.2} µs", r.p99_ns / 1e3),
        "monitor tick".into(),
    ]);
    cases.push(case_json("lsf_oldest_enqueued", 10_000, &r));

    // LSTM forecast (rust-native, the simulator's path)
    let wp = std::path::Path::new("artifacts/predictor_weights.json");
    if wp.exists() {
        let mut lstm = LstmPredictor::load(wp).unwrap();
        for k in 0..20 {
            lstm.observe(100.0 + k as f64);
        }
        let r = bench("lstm forecast", 300, || {
            std::hint::black_box(lstm.forecast());
        });
        t.row(&[
            "LSTM forecast (native)".into(),
            format!("{:.2} µs", r.mean_us()),
            format!("{:.2} µs", r.p50_ns / 1e3),
            format!("{:.2} µs", r.p99_ns / 1e3),
            "2.5 ms (paper, keras)".into(),
        ]);
        cases.push(case_json("lstm_forecast", 0, &r));
    }
    t.print();

    // ------------------------------------------------------------------
    // Pool-size sweep: indexed store vs the naive scan it replaced.
    // ------------------------------------------------------------------
    section(
        "Perf",
        "indexed store vs linear scan (pool sweep, greedy bin-packing decisions)",
    );
    let mut sweep = Table::new(&[
        "pool", "nodes", "operation", "indexed p50", "scan p50", "speedup",
    ]);
    let mut sweep_json: Vec<Json> = Vec::new();
    // acceptance tracker: pick_container @2k pool must beat the scan ≥10x
    let mut pick2k_speedup: Option<f64> = None;
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(78, 32, 2_000)]
    } else {
        &[(78, 32, 2_000), (500, 64, 20_000)]
    };
    for &(nodes, cores, pool) in shapes {
        let store = build_pool(nodes, cores, pool);
        // correctness oracle before timing: the index must agree with the
        // scan on every decision it replaced
        for ms in 0..7usize {
            assert_eq!(
                store.pick_container(ms),
                naive_pick_container(&store, ms),
                "pick_container diverged at pool {pool} stage {ms}"
            );
            assert_eq!(
                store.warm_free_slots(ms),
                naive_warm_free_slots(&store, ms),
                "warm_free_slots diverged at pool {pool} stage {ms}"
            );
        }
        assert_eq!(store.pick_node(), naive_pick_node(&store));

        let ops: Vec<(&str, Timing, Timing)> = vec![
            (
                "pick_container",
                bench("indexed pick_container", 200, || {
                    std::hint::black_box(store.pick_container(3));
                }),
                bench("scan pick_container", 200, || {
                    std::hint::black_box(naive_pick_container(&store, 3));
                }),
            ),
            (
                "pick_node",
                bench("indexed pick_node", 200, || {
                    std::hint::black_box(store.pick_node());
                }),
                bench("scan pick_node", 200, || {
                    std::hint::black_box(naive_pick_node(&store));
                }),
            ),
            (
                "warm_free_slots",
                bench("indexed warm_free_slots", 200, || {
                    std::hint::black_box(store.warm_free_slots(3));
                }),
                bench("scan warm_free_slots", 200, || {
                    std::hint::black_box(naive_warm_free_slots(&store, 3));
                }),
            ),
            (
                "lru_idle_since",
                bench("indexed lru_idle_since", 100, || {
                    std::hint::black_box(store.lru_idle_since(u64::MAX));
                }),
                bench("scan lru_idle (via iter)", 100, || {
                    std::hint::black_box(
                        store
                            .iter()
                            .filter(|c| {
                                c.state == fifer::coordinator::state::CState::Idle
                                    && c.local.is_empty()
                            })
                            .map(|c| (c.last_used, c.id))
                            .min(),
                    );
                }),
            ),
        ];
        for (op, indexed, scan) in &ops {
            let speedup = if indexed.p50_ns > 0.0 {
                scan.p50_ns / indexed.p50_ns
            } else {
                f64::INFINITY
            };
            if *op == "pick_container" && pool == 2_000 {
                pick2k_speedup = Some(speedup);
            }
            sweep.row(&[
                format!("{pool}"),
                format!("{nodes}"),
                (*op).into(),
                format!("{:.3} µs", indexed.p50_ns / 1e3),
                format!("{:.3} µs", scan.p50_ns / 1e3),
                format!("{speedup:.1}x"),
            ]);
            sweep_json.push(Json::obj(vec![
                ("op", Json::Str(op.to_string())),
                ("pool", Json::Num(pool as f64)),
                ("nodes", Json::Num(nodes as f64)),
                ("indexed_p50_us", Json::Num(indexed.p50_ns / 1e3)),
                ("indexed_mean_us", Json::Num(indexed.mean_ns / 1e3)),
                ("indexed_p99_us", Json::Num(indexed.p99_ns / 1e3)),
                ("scan_p50_us", Json::Num(scan.p50_ns / 1e3)),
                ("speedup_p50", Json::Num(speedup)),
            ]));
        }
    }
    sweep.print();
    if let Some(s) = pick2k_speedup {
        println!(
            "acceptance: pick_container @2k pool p50 speedup {s:.1}x vs scan \
             (target >=10x) -> {}",
            if s >= 10.0 { "PASS" } else { "FAIL" }
        );
    }

    // ------------------------------------------------------------------
    // Zero-alloc steady-state dispatch pin (+ cycle latency).
    // ------------------------------------------------------------------
    section(
        "Perf",
        "steady-state dispatch cycle (warm container available, no spawn)",
    );
    // committed regression pin: alloc budget + jobs/s baselines
    let baseline_path =
        std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/benches/perf_baseline.json"));
    let baseline = Json::parse_file(baseline_path)
        .unwrap_or_else(|e| panic!("perf_baseline.json unreadable: {e}"));
    let alloc_budget = baseline
        .opt("allocs_per_dispatch_budget")
        .and_then(|v| v.as_f64().ok())
        .unwrap_or(0.0);

    // fixture: one stage, 4 warm containers (batch 4) on two roomy
    // nodes, an LSF queue with a standing backlog of 4 — see
    // `dispatch_cycle` for why this shape guarantees zero heap traffic
    let mut dstore = StateStore::new(2, 16, 1.0);
    for _ in 0..4 {
        dstore.spawn(0, 4, 0, 0, false).expect("fixture fits");
    }
    let mut dq = StageQueue::new(QOrder::LeastSlackFirst);
    let mut seq = 0u64;
    let mut now = 0u64;
    for _ in 0..4 {
        seq += 1;
        now += 1;
        dq.push(QueueEntry {
            job_id: seq,
            lsf_key: seq,
            enqueued: now,
            seq,
        });
    }
    let mut batch_buf: Vec<u64> = Vec::with_capacity(8);
    let mut done_buf: Vec<u64> = Vec::with_capacity(8);
    // settle every capacity (heap, queue mirror, scratch) before counting
    for _ in 0..1_000 {
        dispatch_cycle(&mut dstore, &mut dq, &mut seq, &mut now, &mut batch_buf, &mut done_buf);
    }
    const CYCLES: u64 = 100_000;
    alloc_reset();
    let t0 = std::time::Instant::now();
    for _ in 0..CYCLES {
        dispatch_cycle(&mut dstore, &mut dq, &mut seq, &mut now, &mut batch_buf, &mut done_buf);
    }
    let dispatch_cycle_ns = t0.elapsed().as_nanos() as f64 / CYCLES as f64;
    let allocs_per_dispatch = alloc_count().map(|n| n as f64 / CYCLES as f64);
    dstore.check_consistency().expect("fixture store consistent");
    println!(
        "dispatch cycle: {dispatch_cycle_ns:.0} ns, allocs/dispatch: {} (budget {alloc_budget})",
        match allocs_per_dispatch {
            Some(a) => format!("{a:.4}"),
            None => "not counted (run with --features bench-alloc)".to_string(),
        }
    );
    if let Some(a) = allocs_per_dispatch {
        assert!(
            a <= alloc_budget,
            "zero-alloc dispatch pin violated: {a:.4} allocs/dispatch > budget {alloc_budget}"
        );
        println!("acceptance: allocs/dispatch {a:.4} <= {alloc_budget} -> PASS");
    }

    // ------------------------------------------------------------------
    // Shard router: p50 ns/route over 1M chain-hash routes, alloc-free.
    // ------------------------------------------------------------------
    section("Perf", "shard router (splitmix64 chain-hash, 1M routes)");
    let router = ShardRouter::new(42, 4);
    // time in batches of 1000 so the ~ns-scale op outlives timer noise
    const ROUTE_BATCH: usize = 1_000;
    let mut chain = 0usize;
    let rt = bench("route x1000", 1_000, || {
        let mut acc = 0usize;
        for _ in 0..ROUTE_BATCH {
            chain = (chain + 1) % 1024;
            acc = acc.wrapping_add(router.route(chain));
        }
        std::hint::black_box(acc);
    });
    let route_p50_ns = rt.p50_ns / ROUTE_BATCH as f64;
    // separate counted pass: routing must never touch the heap
    alloc_reset();
    let mut acc = 0usize;
    for i in 0..1_000_000usize {
        acc = acc.wrapping_add(router.route(i % 1024));
    }
    std::hint::black_box(acc);
    let route_allocs = alloc_count();
    println!(
        "route: {route_p50_ns:.2} ns/route p50, allocs over 1M routes: {}",
        match route_allocs {
            Some(n) => format!("{n}"),
            None => "not counted (run with --features bench-alloc)".to_string(),
        }
    );
    if let Some(n) = route_allocs {
        assert_eq!(n, 0, "shard routing must not allocate");
        println!("acceptance: 0 allocs/route -> PASS");
    }

    // ------------------------------------------------------------------
    // Per-shard dispatch cycle: route into 4 shard-local stores, pin the
    // routed steady-state cycle to the same zero-alloc budget.
    // ------------------------------------------------------------------
    section(
        "Perf",
        "per-shard dispatch cycle (router + 4 shard-local stores, zero-alloc pin)",
    );
    let nsh = 4usize;
    let mut sh_stores: Vec<StateStore> = Vec::with_capacity(nsh);
    let mut sh_queues: Vec<StageQueue> = Vec::with_capacity(nsh);
    let mut sh_seq = vec![0u64; nsh];
    let mut sh_now = vec![0u64; nsh];
    for k in 0..nsh {
        let mut st = StateStore::new(2, 16, 1.0);
        for _ in 0..4 {
            st.spawn(0, 4, 0, 0, false).expect("fixture fits");
        }
        let mut q = StageQueue::new(QOrder::LeastSlackFirst);
        for _ in 0..4 {
            sh_seq[k] += 1;
            sh_now[k] += 1;
            q.push(QueueEntry {
                job_id: sh_seq[k],
                lsf_key: sh_seq[k],
                enqueued: sh_now[k],
                seq: sh_seq[k],
            });
        }
        sh_stores.push(st);
        sh_queues.push(q);
    }
    // settle capacities across all shards before counting
    for i in 0..1_000usize {
        let k = router.route(i % 64);
        dispatch_cycle(
            &mut sh_stores[k],
            &mut sh_queues[k],
            &mut sh_seq[k],
            &mut sh_now[k],
            &mut batch_buf,
            &mut done_buf,
        );
    }
    alloc_reset();
    let t0 = std::time::Instant::now();
    for i in 0..CYCLES {
        let k = router.route(i as usize % 64);
        dispatch_cycle(
            &mut sh_stores[k],
            &mut sh_queues[k],
            &mut sh_seq[k],
            &mut sh_now[k],
            &mut batch_buf,
            &mut done_buf,
        );
    }
    let sharded_cycle_ns = t0.elapsed().as_nanos() as f64 / CYCLES as f64;
    let allocs_per_sharded_dispatch = alloc_count().map(|n| n as f64 / CYCLES as f64);
    for st in &sh_stores {
        st.check_consistency().expect("shard fixture store consistent");
    }
    println!(
        "routed dispatch cycle: {sharded_cycle_ns:.0} ns, allocs/dispatch: {} (budget {alloc_budget})",
        match allocs_per_sharded_dispatch {
            Some(a) => format!("{a:.4}"),
            None => "not counted (run with --features bench-alloc)".to_string(),
        }
    );
    if let Some(a) = allocs_per_sharded_dispatch {
        assert!(
            a <= alloc_budget,
            "per-shard zero-alloc dispatch pin violated: {a:.4} allocs/dispatch > \
             budget {alloc_budget}"
        );
        println!("acceptance: sharded allocs/dispatch {a:.4} <= {alloc_budget} -> PASS");
    }

    // ------------------------------------------------------------------
    // Quality vs shard count: the same flash crowd under 1/2/4 shards
    // (SLO attainment, utilization, cold starts, PR-9 optimality bound,
    // per-shard decision latency). Lands in BENCH_perf.json `shards`.
    // ------------------------------------------------------------------
    section(
        "Perf",
        "sharded coordinator: quality vs shard count (flashcrowd, heavy mix, Fifer)",
    );
    let shard_counts = [1usize, 2, 4];
    let lambdas: &[f64] = if quick { &[30.0] } else { &[20.0, 50.0, 80.0] };
    let sh_dur = if quick { 60usize } else { 300 };
    let mut shard_sweep_json: Vec<Json> = Vec::new();
    let mut sht = Table::new(&[
        "λ", "shards", "jobs", "attain%", "util%", "cold", "gap%", "migr", "decision p95 µs",
    ]);
    for &lam in lambdas {
        for &ns in &shard_counts {
            let cat = Catalog::paper();
            let p = SimParams {
                cfg: SystemConfig::prototype(Policy::Fifer), // seed 42
                chains: cat.mix("Heavy").expect("Heavy mix registered").chains.clone(),
                trace: Trace::flashcrowd(sh_dur, lam, 2.0 * lam, sh_dur / 3, (sh_dur / 10).max(1)),
                drain_s: 30.0,
            };
            let warmup = secs((sh_dur as f64 * 0.5).min(700.0));
            let (run, sum) =
                run_sharded_summarized(p, ns, warmup, Some(ObsConfig::default()), true)
                    .expect("shard counts fit the prototype cluster");
            // cluster utilization over the whole timeline: busy core-ticks
            // over allocated core-ticks
            let util_pct = run
                .report
                .as_ref()
                .map(|r| {
                    let busy: f64 = r.rows.iter().map(|row| row.busy_cores_sum).sum();
                    let alloc: f64 = r.rows.iter().map(|row| row.alloc_cores_sum).sum();
                    100.0 * busy / alloc.max(1e-9)
                })
                .unwrap_or(0.0);
            let gap_pct = sum.optimality.as_ref().map(|o| o.gap_container_pct);
            let shard_decision: Vec<Json> = run
                .shard_decision_ns
                .iter()
                .enumerate()
                .map(|(k, v)| {
                    let f: Vec<f64> = v.iter().map(|&n| n as f64).collect();
                    let (p50, p95) = if f.is_empty() {
                        (0.0, 0.0)
                    } else {
                        (
                            stats::percentile(&f, 50.0) / 1e3,
                            stats::percentile(&f, 95.0) / 1e3,
                        )
                    };
                    Json::obj(vec![
                        ("shard", Json::Num(k as f64)),
                        ("decisions", Json::Num(f.len() as f64)),
                        ("p50_us", Json::Num(p50)),
                        ("p95_us", Json::Num(p95)),
                    ])
                })
                .collect();
            let dec_all: Vec<f64> = run
                .shard_decision_ns
                .iter()
                .flatten()
                .map(|&n| n as f64)
                .collect();
            let dec_p95_us = if dec_all.is_empty() {
                0.0
            } else {
                stats::percentile(&dec_all, 95.0) / 1e3
            };
            sht.row(&[
                format!("{lam:.0}"),
                format!("{ns}"),
                format!("{}", sum.jobs),
                format!("{:.2}", 100.0 * sum.slo_attainment),
                format!("{util_pct:.1}"),
                format!("{}", sum.cold_starts),
                match gap_pct {
                    Some(g) => format!("{g:.1}"),
                    None => "-".to_string(),
                },
                format!("{}", run.migrations),
                format!("{dec_p95_us:.2}"),
            ]);
            shard_sweep_json.push(Json::obj(vec![
                ("lambda", Json::Num(lam)),
                ("shards", Json::Num(ns as f64)),
                ("jobs", Json::Num(sum.jobs as f64)),
                ("slo_attainment", Json::Num(sum.slo_attainment)),
                ("utilization_pct", Json::Num(util_pct)),
                ("cold_starts", Json::Num(sum.cold_starts as f64)),
                ("gap_container_pct", gap_pct.map_or(Json::Null, Json::Num)),
                ("migrations", Json::Num(run.migrations as f64)),
                ("decision_p95_us", Json::Num(dec_p95_us)),
                ("per_shard_decision_latency_us", Json::Arr(shard_decision)),
            ]));
        }
    }
    sht.print();
    let shards_json = Json::obj(vec![
        ("router_p50_ns_per_route", Json::Num(route_p50_ns)),
        (
            "router_allocs_per_1m_routes",
            route_allocs.map_or(Json::Null, |n| Json::Num(n as f64)),
        ),
        ("sharded_dispatch_cycle_ns", Json::Num(sharded_cycle_ns)),
        (
            "allocs_per_sharded_dispatch",
            allocs_per_sharded_dispatch.map_or(Json::Null, Json::Num),
        ),
        ("sweep", Json::Arr(shard_sweep_json)),
    ]);

    // whole-sim throughput + sampled dispatch decision latency (§6.1.5)
    let dur = if quick { 60 } else { 600 };
    section(
        "Perf",
        &format!("end-to-end simulator throughput (heavy mix, λ=50, {dur} s)"),
    );
    alloc_reset();
    let t0 = std::time::Instant::now();
    let run = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, dur, true, 42);
    let wall = t0.elapsed().as_secs_f64();
    // informational only (the metrics log inherently retains per-job
    // records, so end-to-end cannot be literally zero-alloc)
    let allocs_per_job_e2e = alloc_count().map(|n| n as f64 / (run.summary.jobs as f64).max(1.0));
    let jobs_per_s = run.summary.jobs as f64 / wall.max(1e-9);
    let stage_events: u64 = run.summary.jobs * 4; // ≈2 events per stage visit
    println!(
        "sim {dur} s ({} jobs) in {:.2} s wall -> {:.0} jobs/s, ~{:.2} M events/s",
        run.summary.jobs,
        wall,
        run.summary.jobs as f64 / wall,
        stage_events as f64 / wall / 1e6
    );
    if let Some(a) = allocs_per_job_e2e {
        println!("end-to-end allocations: {a:.1} allocs/job (informational)");
    }
    // throughput regression pin vs the committed baseline (quick and
    // full modes are pinned separately — they are different workloads)
    let base_key = if quick { "jobs_per_s_quick" } else { "jobs_per_s_full" };
    let jobs_per_s_baseline = baseline.opt(base_key).and_then(|v| v.as_f64().ok());
    let min_fraction = baseline
        .opt("jobs_per_s_min_fraction")
        .and_then(|v| v.as_f64().ok())
        .unwrap_or(0.8);
    match jobs_per_s_baseline {
        Some(base) => {
            assert!(
                jobs_per_s >= min_fraction * base,
                "throughput regression: {jobs_per_s:.0} jobs/s < {:.0} \
                 ({min_fraction} x baseline {base:.0})",
                min_fraction * base
            );
            println!(
                "acceptance: jobs/s {jobs_per_s:.0} >= {:.0} ({min_fraction} x baseline) -> PASS",
                min_fraction * base
            );
        }
        None => println!(
            "no {base_key} baseline recorded yet -> throughput pin skipped \
             (record the first measured run in benches/perf_baseline.json)"
        ),
    }
    let dn: Vec<f64> = run.recorder.decision_ns.iter().map(|&n| n as f64).collect();
    if !dn.is_empty() {
        println!(
            "sampled dispatch decision: mean {:.2} µs, p99 {:.2} µs \
             (paper LSF decision: 350 µs)",
            stats::mean(&dn) / 1e3,
            stats::percentile(&dn, 99.0) / 1e3
        );
    }
    let sim_json = Json::obj(vec![
        ("duration_s", Json::Num(dur as f64)),
        ("jobs", Json::Num(run.summary.jobs as f64)),
        ("wall_s", Json::Num(wall)),
        ("jobs_per_s", Json::Num(jobs_per_s)),
        (
            "jobs_per_s_baseline",
            jobs_per_s_baseline.map_or(Json::Null, Json::Num),
        ),
        (
            "allocs_per_job_e2e",
            allocs_per_job_e2e.map_or(Json::Null, Json::Num),
        ),
        (
            "decision_p99_us",
            Json::Num(if dn.is_empty() {
                0.0
            } else {
                stats::percentile(&dn, 99.0) / 1e3
            }),
        ),
    ]);

    // machine-readable drop for cross-PR tracking
    let out = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".to_string())),
        ("quick", Json::Bool(quick)),
        ("status", Json::Str("measured".to_string())),
        ("cases", Json::Arr(cases)),
        ("sweep", Json::Arr(sweep_json)),
        (
            "meets_10x_pick_container_2k",
            match pick2k_speedup {
                Some(s) => Json::Bool(s >= 10.0),
                None => Json::Null,
            },
        ),
        ("alloc_counting", Json::Bool(cfg!(feature = "bench-alloc"))),
        (
            "allocs_per_dispatch",
            allocs_per_dispatch.map_or(Json::Null, Json::Num),
        ),
        ("dispatch_cycle_ns", Json::Num(dispatch_cycle_ns)),
        ("shards", shards_json),
        ("sim", sim_json),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_perf.json");
    match std::fs::write(path, out.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    // PJRT batched-inference batch sweep: calibrates batch_cost_gamma
    let art = std::path::Path::new("artifacts");
    if art.join("manifest.json").exists() {
        section("Perf", "PJRT batched inference scaling (gamma calibration)");
        let mut rt = fifer::runtime::Runtime::new(art).unwrap();
        let mut t = Table::new(&["microservice", "batch", "ms/batch", "ms/req", "speedup"]);
        for name in ["QA", "HS"] {
            let dim = rt.manifest.microservices[name].input_dim;
            let mut per1 = 0.0f64;
            for &b in &[1usize, 4, 16, 32] {
                let x = vec![0.1f32; b * dim];
                rt.infer(name, b, &x).unwrap(); // warm compile
                let mut samples = Vec::new();
                for _ in 0..10 {
                    let t0 = std::time::Instant::now();
                    rt.infer(name, b, &x).unwrap();
                    samples.push(t0.elapsed().as_secs_f64() * 1e3);
                }
                let ms = stats::median(&samples);
                if b == 1 {
                    per1 = ms;
                }
                t.row(&[
                    name.into(),
                    format!("{b}"),
                    format!("{ms:.2}"),
                    format!("{:.2}", ms / b as f64),
                    format!("{:.2}x", per1 / (ms / b as f64)),
                ]);
            }
        }
        t.print();
        println!("(simulator batch_cost_gamma defaults to 0.25; see docs/EXPERIMENTS.md §Perf)");
    }
}
