//! Fig. 16 — number of cold starts over a 2-hour-scale snapshot.
//!
//! Paper shape: Fifer incurs up to 7× / 3.5× fewer cold starts than BPred
//! on Wiki / WITS, and ~3× fewer than RScale, because proactive spawning
//! replaces reactive cold spawns.

use fifer::bench::{norm, section, Table};
use fifer::config::Policy;
use fifer::experiments::{run_macro, TraceKind};

fn main() {
    let duration = 900;
    for kind in [TraceKind::Wiki, TraceKind::Wits] {
        section(
            "Fig. 16",
            &format!("cold starts — {} trace, heavy mix, {duration} s", kind.name()),
        );
        let runs = run_macro(kind, "Heavy", duration, 42);
        let fifer = runs
            .iter()
            .find(|r| r.policy == Policy::Fifer)
            .unwrap()
            .summary
            .cold_starts;
        let mut t = Table::new(&["policy", "cold starts", "vs Fifer"]);
        for r in &runs {
            if matches!(r.policy, Policy::SBatch) {
                continue; // fixed pool: cold starts only at t=0 (as in paper)
            }
            t.row(&[
                r.policy.name().to_string(),
                format!("{}", r.summary.cold_starts),
                norm(r.summary.cold_starts as f64, fifer.max(1) as f64),
            ]);
        }
        t.print();

        // time series, coarse
        let f = runs.iter().find(|r| r.policy == Policy::Fifer).unwrap();
        let series = f.recorder.coldstarts_over_time(60);
        let line: Vec<String> = series
            .iter()
            .step_by(2)
            .map(|(t, n)| format!("{}s:{}", t, n))
            .collect();
        println!("Fifer cold starts per 60 s: {}", line.join(" "));
    }
}
