//! Fig. 6 — load-prediction model comparison on the WITS trace.
//!
//! (a) RMSE and per-forecast latency for the non-ML (MWA, EWMA, LinearR,
//! LogisticR + AR3/Holt substitutes for DeepAR/WeaveNet) and ML (FF,
//! LSTM) models; (b) LSTM accuracy on the test region (paper: ~85%
//! within-band over an 800 s window). Paper shape: LSTM lowest RMSE among
//! burst-robust models, non-ML models cheapest per call.

use fifer::bench::{section, Table};
use fifer::experiments::fig6_predictors;

fn main() {
    section("Fig. 6a", "predictor RMSE + forecast latency (WITS, horizon 10 s)");
    let results = fig6_predictors("artifacts", 0.15);
    let mut t = Table::new(&["model", "RMSE req/s", "latency µs", "accuracy %"]);
    let mut best = ("", f64::INFINITY);
    for r in &results {
        if r.rmse < best.1 {
            best = (r.name, r.rmse);
        }
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.rmse),
            format!("{:.2}", r.latency_us),
            format!("{:.1}", r.accuracy_pct),
        ]);
    }
    t.print();
    println!("\nlowest RMSE: {} ({:.1} req/s)", best.0, best.1);

    section("Fig. 6b", "LSTM forecast vs actual over the last 800 s of WITS");
    if let Some(lstm) = results.iter().find(|r| r.name == "LSTM") {
        let n = lstm.forecasts.len();
        let take = 160.min(n); // 800 s / 5 s windows
        let mut t = Table::new(&["t (s)", "actual req/s", "LSTM forecast"]);
        for i in (n - take..n).step_by(8) {
            t.row(&[
                format!("{}", (i + 1) * 5),
                format!("{:.0}", lstm.actuals[i]),
                format!("{:.0}", lstm.forecasts[i]),
            ]);
        }
        t.print();
        println!(
            "LSTM within-15%-band accuracy: {:.1}% (paper: ~85%)",
            lstm.accuracy_pct
        );
    } else {
        println!("(LSTM weights missing — run `make artifacts`)");
    }
}
