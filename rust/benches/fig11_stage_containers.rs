//! Fig. 11 — distribution of containers across IPA's three stages.
//!
//! Heavy mix. Paper shape: Bline/BPred concentrate containers on stage-1
//! (ASR, the bottleneck); Fifer evens out stages 1/3 and keeps stage-2
//! (NLP, <2% of exec) minimal thanks to early scale-in.

use fifer::bench::{section, Table};
use fifer::experiments::{run_prototype, stage_distribution};

fn main() {
    section("Fig. 11", "containers per IPA stage (% of chain total)");
    let runs = run_prototype("Heavy", 1500, 42);
    let mut t = Table::new(&["policy", "S1:ASR %", "S2:NLP %", "S3:QA %"]);
    for r in &runs {
        let dist = stage_distribution(&r, "IPA");
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.1}", dist[0].1),
            format!("{:.1}", dist[1].1),
            format!("{:.1}", dist[2].1),
        ]);
    }
    t.print();
    println!("(paper: Fifer ≈ 38/21/36, with NLP lowest because it scales in early)");
}
