//! Fig. 9 — P99 tail-latency breakdown (exec / cold-start / batching).
//!
//! Heavy mix, prototype cluster. Paper shape: RScale/SBatch P99 up to 3×
//! Bline/BPred (cold-start + queueing congestion); Fifer ~2× Bline with a
//! much smaller cold-start component than RScale.

use fifer::bench::{section, Table};
use fifer::experiments::run_prototype;

fn main() {
    section("Fig. 9", "P99 tail latency breakdown — heavy mix (ms)");
    let runs = run_prototype("Heavy", 1500, 42);
    let base_p99 = runs[0].summary.p99_ms;
    let mut t = Table::new(&[
        "policy", "p99", "p99/Bline", "tail exec", "tail cold-start", "tail batching",
    ]);
    for r in &runs {
        let b = &r.summary.tail_breakdown;
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.0}", r.summary.p99_ms),
            format!("{:.2}x", r.summary.p99_ms / base_p99),
            format!("{:.0}", b.exec_ms),
            format!("{:.0}", b.cold_ms),
            format!("{:.0}", b.batch_ms),
        ]);
    }
    t.print();
}
