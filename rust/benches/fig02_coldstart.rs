//! Fig. 2 — implications of cold starts for ML inference functions.
//!
//! Regenerates both panels: (a) cold-start breakdown (spawn / image pull /
//! runtime init / exec) and (b) warm-start totals, per model ordered by
//! image size — the paper's squeezenet→resnet-200 axis. Expected shape:
//! cold start adds ~2000–7500 ms on top of execution (paper §2.2.1), warm
//! totals stay ~exec time.

use fifer::bench::{section, Table};
use fifer::experiments::fig2_coldstart;

fn main() {
    section("Fig. 2a", "cold-start breakdown per model (ms, 500 samples)");
    let rows = fig2_coldstart(500, 1);
    let mut t = Table::new(&[
        "model", "exec", "spawn", "image pull", "init", "cold total", "cold-exec",
    ]);
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.exec_ms),
            format!("{:.0}", r.spawn_ms),
            format!("{:.0}", r.pull_ms),
            format!("{:.0}", r.init_ms),
            format!("{:.0}", r.cold_total_ms),
            format!("{:.0}", r.cold_total_ms - r.exec_ms),
        ]);
    }
    t.print();

    section("Fig. 2b", "warm-start totals per model (ms)");
    let mut t = Table::new(&["model", "warm total", "cold/warm ratio"]);
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.1}", r.warm_total_ms),
            format!("{:.0}x", r.cold_total_ms / r.warm_total_ms),
        ]);
    }
    t.print();

    // paper check: cold start adds 2000-7500ms over exec
    let overhead_min = rows
        .iter()
        .map(|r| r.cold_total_ms - r.exec_ms)
        .fold(f64::INFINITY, f64::min);
    let overhead_max = rows
        .iter()
        .map(|r| r.cold_total_ms - r.exec_ms)
        .fold(0.0, f64::max);
    println!(
        "\ncold-start overhead range: {overhead_min:.0}–{overhead_max:.0} ms \
         (paper: ~2000–7500 ms)"
    );
}
