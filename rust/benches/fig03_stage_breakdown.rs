//! Fig. 3 — microservice characterization.
//!
//! (a) per-stage breakdown of each chain's execution time (paper: stage-1
//! of Detect-Fatigue ≈ 81% of the total); (b) execution-time variation
//! across 100 runs (paper: std-dev within 20 ms). When artifacts are
//! present, panel (b) is additionally re-measured on the *real* PJRT
//! models rather than the analytic sampler.

use fifer::bench::{section, Table};
use fifer::experiments::{fig3a_breakdown, fig3b_variation};

fn main() {
    section("Fig. 3a", "per-stage breakdown of chain execution time");
    let mut t = Table::new(&["chain", "stage", "exec ms", "% of total"]);
    for b in fig3a_breakdown() {
        for (i, (name, exec, pct)) in b.stages.iter().enumerate() {
            t.row(&[
                if i == 0 { b.chain.to_string() } else { String::new() },
                name.to_string(),
                format!("{exec:.2}"),
                format!("{pct:.1}"),
            ]);
        }
    }
    t.print();

    section("Fig. 3b", "execution-time variation over 100 runs (model)");
    let mut t = Table::new(&["microservice", "mean ms", "std ms"]);
    for (name, mean, std) in fig3b_variation(100, 7) {
        t.row(&[name.to_string(), format!("{mean:.2}"), format!("{std:.2}")]);
    }
    t.print();
    println!("(paper claim: std-dev within 20 ms for every microservice)");

    // live re-measurement against the real artifacts, if present
    let art = std::path::Path::new("artifacts");
    if art.join("manifest.json").exists() {
        section("Fig. 3b-live", "PJRT batch-1 latency over 30 runs (ms)");
        let mut rt = fifer::runtime::Runtime::new(art).expect("runtime");
        let mut t = Table::new(&["microservice", "mean ms", "std ms"]);
        for name in ["FACER", "NLP", "IMC", "QA"] {
            let dim = rt.manifest.microservices[name].input_dim;
            let x = vec![0.1f32; dim];
            rt.infer(name, 1, &x).unwrap(); // compile outside timing
            let mut samples = Vec::new();
            for _ in 0..30 {
                let t0 = std::time::Instant::now();
                rt.infer(name, 1, &x).unwrap();
                samples.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            t.row(&[
                name.to_string(),
                format!("{:.2}", fifer::util::stats::mean(&samples)),
                format!("{:.2}", fifer::util::stats::std_dev(&samples)),
            ]);
        }
        t.print();
    }
}
