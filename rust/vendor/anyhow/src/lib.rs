//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds without crates.io access (the same spirit as the
//! in-tree `serde_json` and `rand` substitutes in `fifer::util`).
//!
//! Implements exactly the surface this workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension for results. Swap in the real crate by replacing the path
//! dependency with a registry version — the API is call-compatible.

use std::fmt;

/// A string-backed error that preserves the source chain for display.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap with higher-level context, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// std::error::Error — that is what makes this blanket conversion (and the
// `?` operator on any std error) coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to the error variant of a result.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading missing file".to_string())?;
        Ok(s)
    }

    #[test]
    fn conversion_and_context() {
        let e = io_fail().unwrap_err();
        let text = format!("{e}");
        assert!(text.starts_with("reading missing file: "), "{text}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Err(anyhow!("always fails with {}", x))
        }
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(3).unwrap_err()), "always fails with 3");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(format!("{}", none.context("missing").unwrap_err()), "missing");
    }
}
