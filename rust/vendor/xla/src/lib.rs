//! Compile-only stub of the `xla` PJRT binding.
//!
//! `fifer::runtime` is the single module that touches PJRT; everything
//! else (the simulator, coordinator, predictors, benches) is pure Rust.
//! This stub keeps the whole workspace building and testing on machines
//! without an `xla_extension` install: every entry point that would need
//! a real PJRT client returns a descriptive error, which the runtime
//! surfaces as the usual "run `make artifacts`?" failure path. The live
//! tests in `rust/tests/test_runtime.rs` / `test_server_live.rs` no-op
//! without artifacts, so nothing downstream breaks.
//!
//! To run real inference, point the `xla` dependency of `rust/Cargo.toml`
//! at an actual binding with the same surface (e.g. a local xla-rs
//! checkout): the API below is the exact subset `fifer::runtime` calls.

/// Error type surfaced by every stubbed operation (printed with `{:?}`).
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (the `xla` dependency is \
         the compile-only stub; see rust/vendor/xla)"
    )))
}

/// Host literal (stub: shape-less placeholder).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction itself reports unavailability, so
/// `Runtime::new` fails fast with an actionable message).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}
