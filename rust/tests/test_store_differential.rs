//! Differential property tests for the indexed state store.
//!
//! The indexed `StateStore` must make byte-for-byte the same greedy
//! decisions as the naive scan-based implementation it replaced (the
//! seed's O(pool) version): same container picks, same node placements,
//! same aggregates, same reclaim victims. A reference model re-implements
//! every query as a linear scan; a randomized operation sequence drives
//! both and compares all answers after every single operation. A second
//! test pins end-to-end reproducibility: a fixed-seed `run_policy` must
//! produce identical job/SLO summaries run-to-run. (It cannot literally
//! re-run the pre-refactor engine; the decision-equivalence half of the
//! cross-refactor claim is carried by the differential test above, which
//! *is* the seed's scan implementation, op for op.)

use fifer::config::Policy;
use fifer::coordinator::state::{CState, StateStore};
use fifer::experiments::{run_policy, TraceKind};
use fifer::metrics::Summary;
use fifer::util::prop::{assert_prop, check};

const STAGES: usize = 5;

/// Reference container mirror (shares ids with the real store).
#[derive(Clone)]
struct RefC {
    id: u64,
    ms_id: usize,
    node: usize,
    batch: usize,
    queued: usize,
    cur_batch: usize,
    state: CState,
    last_used: u64,
}

/// Scan-based reference model: every query recomputed from first
/// principles over a flat container list, mirroring the pre-index store.
struct RefStore {
    cs: Vec<RefC>,
    node_total: Vec<f64>,
    cpu: f64,
}

impl RefStore {
    fn free_slots(c: &RefC) -> usize {
        c.batch.saturating_sub(c.queued)
    }

    fn is_warm(c: &RefC) -> bool {
        matches!(c.state, CState::Idle | CState::Busy)
    }

    fn is_idle_empty(c: &RefC) -> bool {
        c.state == CState::Idle && c.queued == 0
    }

    /// Containers per node, recomputed by one scan (shared by queries so
    /// the reference stays O(pool) per query even in debug builds).
    fn node_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.node_total.len()];
        for c in &self.cs {
            counts[c.node] += 1;
        }
        counts
    }

    fn node_free_of(&self, node: usize, counts: &[usize]) -> f64 {
        self.node_total[node] - counts[node] as f64 * self.cpu
    }

    fn pick_node(&self) -> Option<usize> {
        let counts = self.node_counts();
        (0..self.node_total.len())
            .filter(|&n| self.node_free_of(n, &counts) >= self.cpu - 1e-9)
            .min_by(|&a, &b| {
                self.node_free_of(a, &counts)
                    .partial_cmp(&self.node_free_of(b, &counts))
                    .unwrap()
                    .then(a.cmp(&b))
            })
    }

    fn pick_container(&self, ms: usize) -> Option<u64> {
        let counts = self.node_counts();
        self.cs
            .iter()
            .filter(|c| c.ms_id == ms && Self::is_warm(c) && Self::free_slots(c) > 0)
            .map(|c| {
                (
                    Self::free_slots(c),
                    std::cmp::Reverse(counts[c.node]),
                    c.id,
                )
            })
            .min()
            .map(|(_, _, id)| id)
    }

    fn warm_free(&self, ms: usize) -> usize {
        self.cs
            .iter()
            .filter(|c| c.ms_id == ms && Self::is_warm(c))
            .map(Self::free_slots)
            .sum()
    }

    fn starting(&self, ms: usize) -> usize {
        self.cs
            .iter()
            .filter(|c| c.ms_id == ms && c.state == CState::Starting)
            .map(|c| c.batch)
            .sum()
    }

    fn live(&self, ms: usize) -> usize {
        self.cs.iter().filter(|c| c.ms_id == ms).count()
    }

    fn idle_since(&self, ms: usize, cutoff: u64) -> Vec<u64> {
        let mut v: Vec<(u64, u64)> = self
            .cs
            .iter()
            .filter(|c| c.ms_id == ms && Self::is_idle_empty(c) && c.last_used < cutoff)
            .map(|c| (c.last_used, c.id))
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, id)| id).collect()
    }

    fn lru_idle_since(&self, cutoff: u64) -> Option<u64> {
        self.cs
            .iter()
            .filter(|c| Self::is_idle_empty(c) && c.last_used < cutoff)
            .map(|c| (c.last_used, c.id))
            .min()
            .map(|(_, id)| id)
    }

    fn node_loads(&self) -> Vec<(f64, f64)> {
        let mut loads = vec![(0.0f64, 0.0f64); self.node_total.len()];
        for c in &self.cs {
            loads[c.node].1 += self.cpu;
            if c.state == CState::Busy {
                loads[c.node].0 += self.cpu;
            }
        }
        loads
    }

    fn find(&mut self, id: u64) -> &mut RefC {
        self.cs.iter_mut().find(|c| c.id == id).expect("mirror has id")
    }
}

#[test]
fn prop_indexed_store_matches_scan_reference() {
    check("store_differential", 30, |rng| {
        let nodes = 2 + rng.below(6);
        let cores = 2 + rng.below(12);
        let mut store = StateStore::new(nodes, cores, 0.5);
        let mut mirror = RefStore {
            cs: Vec::new(),
            node_total: vec![cores as f64; nodes],
            cpu: 0.5,
        };
        let mut now: u64 = 0;
        let mut next_job: u64 = 0;
        for _ in 0..250 {
            now += rng.below(1000) as u64;
            let roll = rng.f64();
            if roll < 0.40 {
                // spawn (placement decision compared via pick_node)
                let picked = mirror.pick_node();
                assert_prop(store.pick_node() == picked, "pick_node diverged pre-spawn")?;
                let ms = rng.below(STAGES);
                let batch = 1 + rng.below(6);
                let latency: u64 = if rng.f64() < 0.4 { 1_000_000 } else { 0 };
                match store.spawn(ms, batch, now, latency, latency > 0) {
                    Some(cid) => {
                        let node = store.get(cid).unwrap().node;
                        assert_prop(Some(node) == picked, "spawn placement diverged")?;
                        mirror.cs.push(RefC {
                            id: cid,
                            ms_id: ms,
                            node,
                            batch,
                            queued: 0,
                            cur_batch: 0,
                            state: if latency == 0 {
                                CState::Idle
                            } else {
                                CState::Starting
                            },
                            last_used: now,
                        });
                    }
                    None => assert_prop(picked.is_none(), "spawn refused with capacity")?,
                }
            } else if roll < 0.55 {
                // remove an arbitrary live container (any state)
                if !mirror.cs.is_empty() {
                    let k = rng.below(mirror.cs.len());
                    let id = mirror.cs.remove(k).id;
                    assert_prop(store.remove(id).is_some(), "remove lost container")?;
                    assert_prop(store.remove(id).is_none(), "double remove succeeded")?;
                    assert_prop(store.get(id).is_none(), "removed id still resolves")?;
                }
            } else if roll < 0.75 {
                // dispatch through the greedy pick, maybe kick off a batch
                let ms = rng.below(STAGES);
                let pick = store.pick_container(ms);
                assert_prop(pick == mirror.pick_container(ms), "pick_container diverged")?;
                if let Some(cid) = pick {
                    next_job += 1;
                    let was_idle = store.dispatch(cid, next_job, now);
                    let kick = rng.f64() < 0.7;
                    let m = mirror.find(cid);
                    assert_prop(
                        was_idle == (m.state == CState::Idle),
                        "dispatch idle flag diverged",
                    )?;
                    m.queued += 1;
                    m.last_used = now;
                    if was_idle && kick {
                        let captured = m.queued;
                        m.state = CState::Busy;
                        m.cur_batch = captured;
                        let mut jobs = Vec::new();
                        let b = store.begin_batch(cid, &mut jobs);
                        assert_prop(
                            b.len == captured && jobs.len() == captured && b.ms_id == ms,
                            "batch capture diverged",
                        )?;
                    }
                }
            } else if roll < 0.87 {
                // complete a random executing batch
                let busy: Vec<u64> = mirror
                    .cs
                    .iter()
                    .filter(|c| c.state == CState::Busy)
                    .map(|c| c.id)
                    .collect();
                if !busy.is_empty() {
                    let id = busy[rng.below(busy.len())];
                    let mut jobs = Vec::new();
                    let ms = store.finish_batch(id, now, &mut jobs);
                    let m = mirror.find(id);
                    assert_prop(
                        ms == m.ms_id && jobs.len() == m.cur_batch,
                        "finish_batch diverged",
                    )?;
                    m.queued -= m.cur_batch;
                    m.cur_batch = 0;
                    m.state = CState::Idle;
                    m.last_used = now;
                }
            } else {
                // finish a cold start
                let starting: Vec<u64> = mirror
                    .cs
                    .iter()
                    .filter(|c| c.state == CState::Starting)
                    .map(|c| c.id)
                    .collect();
                if !starting.is_empty() {
                    let id = starting[rng.below(starting.len())];
                    assert_prop(store.warm_up(id, now) == Some(mirror.find(id).ms_id),
                        "warm_up diverged")?;
                    let m = mirror.find(id);
                    m.state = CState::Idle;
                    m.last_used = now;
                }
            }

            // after EVERY operation: internal invariants + full query parity
            store
                .check_consistency()
                .map_err(|e| format!("consistency: {e}"))?;
            for ms in 0..STAGES {
                assert_prop(
                    store.pick_container(ms) == mirror.pick_container(ms),
                    "pick_container query diverged",
                )?;
                assert_prop(
                    store.warm_free_slots(ms) == mirror.warm_free(ms),
                    "warm_free_slots diverged",
                )?;
                assert_prop(
                    store.starting_slots(ms) == mirror.starting(ms),
                    "starting_slots diverged",
                )?;
                assert_prop(
                    store.stage_containers(ms) == mirror.live(ms),
                    "stage_containers diverged",
                )?;
                let cutoff = now.saturating_sub(300_000);
                assert_prop(
                    store.idle_since(ms, cutoff).collect::<Vec<u64>>()
                        == mirror.idle_since(ms, cutoff),
                    "idle_since diverged",
                )?;
            }
            assert_prop(store.pick_node() == mirror.pick_node(), "pick_node diverged")?;
            for cutoff in [0u64, now / 2, now, u64::MAX] {
                assert_prop(
                    store.lru_idle_since(cutoff) == mirror.lru_idle_since(cutoff),
                    "lru_idle_since diverged",
                )?;
            }
            assert_prop(store.node_loads() == mirror.node_loads(), "node_loads diverged")?;
            assert_prop(
                store.total_containers() == mirror.cs.len(),
                "total_containers diverged",
            )?;
        }
        Ok(())
    });
}

/// Stable fingerprint over everything a Summary reports (per-stage map
/// sorted for determinism).
fn fingerprint(s: &Summary) -> String {
    let mut per_stage: Vec<(usize, u64, u64, u64)> = s
        .per_stage
        .iter()
        .map(|(&k, v)| (k, v.containers, v.jobs, v.cold_starts))
        .collect();
    per_stage.sort_unstable();
    format!(
        "jobs={} slo={:.12} med={:.12} p95={:.12} p99={:.12} mean={:.12} \
         avgc={:.12} spawned={} cold={} energy={:.12} qmed={:.12} qp99={:.12} \
         tail=({:.12},{:.12},{:.12}) avg=({:.12},{:.12},{:.12}) stages={:?}",
        s.jobs,
        s.slo_violation_pct,
        s.median_ms,
        s.p95_ms,
        s.p99_ms,
        s.mean_ms,
        s.avg_containers,
        s.total_spawned,
        s.cold_starts,
        s.energy_wh,
        s.queue_wait_median_ms,
        s.queue_wait_p99_ms,
        s.tail_breakdown.exec_ms,
        s.tail_breakdown.cold_ms,
        s.tail_breakdown.batch_ms,
        s.avg_breakdown.exec_ms,
        s.avg_breakdown.cold_ms,
        s.avg_breakdown.batch_ms,
        per_stage,
    )
}

#[test]
fn fixed_seed_run_policy_reproduces_identical_summaries() {
    let a = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 120, true, 42);
    let b = run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 120, true, 42);
    assert_eq!(
        fingerprint(&a.summary),
        fingerprint(&b.summary),
        "fixed-seed summary not byte-identical"
    );
    // recorder-level: identical job timelines and container history, so
    // any future scheduling change that shifts a single dispatch shows up
    assert_eq!(a.recorder.jobs.len(), b.recorder.jobs.len());
    for (x, y) in a.recorder.jobs.iter().zip(&b.recorder.jobs) {
        assert_eq!(
            (x.chain, x.arrival, x.completion),
            (y.chain, y.arrival, y.completion)
        );
    }
    assert_eq!(a.recorder.containers.len(), b.recorder.containers.len());
    assert_eq!(a.recorder.cold_starts, b.recorder.cold_starts);
}
