//! Integration: full simulations across policies, mixes and traces, and
//! the paper's qualitative orderings at small scale.

use fifer::config::{Policy, SystemConfig};
use fifer::experiments::{run_policy, TraceKind};
use fifer::model::Catalog;
use fifer::sim::{run_sim, SimParams};
use fifer::trace::Trace;

fn quick(policy: Policy, mix: &str, lambda: f64, dur: usize, seed: u64) -> fifer::metrics::Summary {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = seed;
    cfg.rm.idle_timeout_s = 120.0;
    let p = SimParams {
        cfg,
        chains: cat.mix(mix).unwrap().chains.clone(),
        trace: Trace::poisson(lambda, dur),
        drain_s: 40.0,
    };
    run_sim(p).1
}

#[test]
fn all_policy_mix_combinations_complete() {
    for policy in Policy::ALL {
        for mix in ["Heavy", "Medium", "Light"] {
            let s = quick(policy, mix, 10.0, 60, 1);
            assert!(s.jobs > 200, "{}/{mix}: only {} jobs", policy.name(), s.jobs);
            assert!(s.median_ms > 0.0);
            assert!(s.energy_wh > 0.0);
        }
    }
}

#[test]
fn sbatch_pool_is_fixed() {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(Policy::SBatch);
    cfg.seed = 3;
    let p = SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(30.0, 200),
        drain_s: 30.0,
    };
    let (rec, sum) = run_sim(p);
    // every container spawned at t=0; none retired mid-run
    assert!(rec.containers.iter().all(|c| c.spawned_at == 0));
    let series = rec.containers_over_time(10);
    let counts: Vec<usize> = series.iter().map(|p| p.1).collect();
    assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
    assert_eq!(sum.total_spawned as usize, counts[0]);
}

#[test]
fn fifer_uses_fewer_containers_than_bline_steady_state() {
    let bline = quick(Policy::Bline, "Heavy", 40.0, 400, 7);
    let fifer = quick(Policy::Fifer, "Heavy", 40.0, 400, 7);
    assert!(
        fifer.avg_containers < bline.avg_containers,
        "fifer {} vs bline {}",
        fifer.avg_containers,
        bline.avg_containers
    );
    // without sacrificing SLO compliance (paper Fig. 8)
    assert!(fifer.slo_violation_pct <= bline.slo_violation_pct + 5.0);
}

#[test]
fn lsf_improves_shared_stage_tails() {
    // RScale (LSF) vs SBatch (FIFO) both batch; LSF should not *hurt*
    // the strict-slack chain's tail on the shared stages.
    let rscale = quick(Policy::RScale, "Medium", 20.0, 300, 5);
    assert!(rscale.p99_ms.is_finite());
}

#[test]
fn wits_spikes_trigger_cold_starts_for_reactive_rms() {
    let run_r = run_policy(Policy::RScale, "Heavy", TraceKind::Wits, 400, false, 11);
    let run_f = run_policy(Policy::Fifer, "Heavy", TraceKind::Wits, 400, false, 11);
    assert!(run_r.summary.cold_starts > 0);
    assert!(run_f.summary.cold_starts > 0);
    // Fifer's proactive provisioning must not *increase* cold starts
    assert!(
        run_f.summary.cold_starts <= run_r.summary.cold_starts * 2,
        "fifer {} vs rscale {}",
        run_f.summary.cold_starts,
        run_r.summary.cold_starts
    );
}

#[test]
fn deterministic_across_runs() {
    let a = quick(Policy::Fifer, "Light", 15.0, 120, 9);
    let b = quick(Policy::Fifer, "Light", 15.0, 120, 9);
    assert_eq!(a.jobs, b.jobs);
    assert_eq!(a.total_spawned, b.total_spawned);
    assert_eq!(a.cold_starts, b.cold_starts);
    assert!((a.p99_ms - b.p99_ms).abs() < 1e-9);
    let c = quick(Policy::Fifer, "Light", 15.0, 120, 10);
    assert!(a.jobs != c.jobs || a.median_ms != c.median_ms);
}

#[test]
fn medium_mix_shares_nlp_and_qa_queues() {
    // jobs from both IPA and IMG execute on the same NLP/QA containers
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(Policy::Fifer);
    cfg.seed = 2;
    let p = SimParams {
        cfg,
        chains: cat.mix("Medium").unwrap().chains.clone(),
        trace: Trace::poisson(10.0, 120),
        drain_s: 40.0,
    };
    let (rec, _) = run_sim(p);
    let qa = cat.ms_id("QA").unwrap();
    let qa_jobs: u64 = rec
        .containers
        .iter()
        .filter(|c| c.ms_id == qa)
        .map(|c| c.jobs_executed)
        .sum();
    // every completed job passes QA exactly once, from either chain
    assert!(qa_jobs >= rec.jobs.len() as u64);
}

#[test]
fn warmup_filter_reduces_violation_estimate() {
    // the cold-start transient must not pollute steady-state numbers
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(Policy::Bline);
    cfg.seed = 4;
    let p = SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(30.0, 400),
        drain_s: 40.0,
    };
    let rec = fifer::sim::Engine::new(p).run();
    let all = rec.summarize(&cat);
    let steady = rec.summarize_after(&cat, fifer::util::secs(200.0));
    assert!(steady.slo_violation_pct <= all.slo_violation_pct + 1e-9);
    assert!(steady.jobs < all.jobs);
}
