//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! These tests require `make artifacts` (they no-op gracefully otherwise,
//! mirroring how CI machines without the Python toolchain behave).

use std::path::{Path, PathBuf};

use fifer::predictor::nn::{FfPredictor, LstmPredictor};
use fifer::runtime::Runtime;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn infer_every_microservice_batch1() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let names: Vec<String> = rt.manifest.microservices.keys().cloned().collect();
    for name in names {
        let e = rt.manifest.microservices[&name].clone();
        let x = vec![0.25f32; e.input_dim];
        let out = rt.infer(&name, 1, &x).unwrap();
        assert_eq!(out.len(), e.output_dim, "{name}");
        assert!(out.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    }
}

#[test]
fn inference_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let dim = rt.manifest.microservices["FACER"].input_dim;
    let x: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.01).sin()).collect();
    let a = rt.infer("FACER", 1, &x).unwrap();
    let b = rt.infer("FACER", 1, &x).unwrap();
    assert_eq!(a, b);
}

#[test]
fn batch_rows_independent() {
    // row 0 of a batch-4 call must equal the batch-1 result (padding and
    // batching must not leak across rows)
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let e = rt.manifest.microservices["NLP"].clone();
    let x1: Vec<f32> = (0..e.input_dim).map(|i| (i as f32 * 0.03).cos()).collect();
    let single = rt.infer("NLP", 1, &x1).unwrap();
    let mut x4 = x1.clone();
    x4.extend(vec![9.0f32; 3 * e.input_dim]); // garbage in other rows
    let quad = rt.infer("NLP", 4, &x4).unwrap();
    for (a, b) in single.iter().zip(&quad[..e.output_dim]) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn odd_batch_pads_up() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let e = rt.manifest.microservices["FACED"].clone();
    let rows = 3; // no batch-3 artifact: must use batch-4 transparently
    let x = vec![0.5f32; rows * e.input_dim];
    let out = rt.infer("FACED", rows, &x).unwrap();
    assert_eq!(out.len(), rows * e.output_dim);
}

#[test]
fn lstm_native_matches_pjrt_artifact() {
    // The simulator's rust-native LSTM forward and the AOT-compiled XLA
    // artifact must agree — this pins L1 (Pallas) == L3-native math.
    let Some(dir) = artifacts() else { return };
    let native = LstmPredictor::load(&dir.join("predictor_weights.json")).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    for k in 0..5 {
        let xs: Vec<f32> = (0..native.window)
            .map(|i| 0.2 + 0.05 * ((i + k) as f32).sin())
            .collect();
        let a = native.forward(&xs);
        let b = rt.predict("lstm", &xs).unwrap();
        assert!(
            (a - b).abs() < 1e-4,
            "case {k}: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn ff_native_matches_pjrt_artifact() {
    let Some(dir) = artifacts() else { return };
    let native = FfPredictor::load(&dir.join("predictor_weights.json")).unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let xs: Vec<f32> = (0..native.window).map(|i| 0.3 + 0.02 * i as f32).collect();
    let a = native.forward(&xs);
    let b = rt.predict("ff", &xs).unwrap();
    assert!((a - b).abs() < 1e-4, "native {a} vs pjrt {b}");
}

#[test]
fn predictor_rejects_bad_window() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.predict("lstm", &[0.5; 3]).is_err());
    assert!(rt.predict("nope", &[0.5; 20]).is_err());
}

#[test]
fn infer_rejects_bad_input_len() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.infer("IMC", 2, &[0.0; 10]).is_err());
    assert!(rt.infer("UNKNOWN", 1, &[0.0; 10]).is_err());
}

#[test]
fn executables_cached_once() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let dim = rt.manifest.microservices["POS"].input_dim;
    let x = vec![0.0f32; dim];
    rt.infer("POS", 1, &x).unwrap();
    let n = rt.compiled_count();
    rt.infer("POS", 1, &x).unwrap();
    assert_eq!(rt.compiled_count(), n, "re-compiled a cached executable");
}

#[test]
fn trace_artifacts_match_generators_statistically() {
    let Some(dir) = artifacts() else { return };
    let wits = fifer::trace::Trace::load_json(&dir.join("traces/wits.json")).unwrap();
    let gen = fifer::trace::Trace::wits(wits.duration_s(), 1316);
    // not bit-identical (different PRNGs) but statistically matched
    assert!((wits.avg_rate() - gen.avg_rate()).abs() / gen.avg_rate() < 0.15);
    assert!((wits.peak_rate() - gen.peak_rate()).abs() / gen.peak_rate() < 0.15);
}

#[test]
fn manifest_slo_consistent_with_catalog() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cat = fifer::model::Catalog::paper();
    assert_eq!(rt.manifest.slo_ms, cat.chains[0].slo_ms);
    for ms in &cat.microservices {
        assert!(
            rt.manifest.microservices.contains_key(ms.name),
            "{} missing from manifest",
            ms.name
        );
    }
    let _ = Path::new("x");
}
