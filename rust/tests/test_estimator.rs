//! Optimality-gap subsystem integration: the soundness property (a
//! lower bound never exceeds any policy's achieved cost, for every
//! registered policy at every tested seed, on both objectives), a
//! hand-computed fixture whose exact optimum all three estimators must
//! hit, byte-determinism of `--optimality` output across runs and
//! thread counts, and the JSON-only placement of the `optimality`
//! block.

use std::collections::BTreeMap;

use fifer::config::{Policy, SystemConfig};
use fifer::estimator::{
    self, greedy_bound, path_cover_bound, segment_bound, Invocation, InvocationLog,
};
use fifer::metrics::Recorder;
use fifer::model::Catalog;
use fifer::scenario::{self, ScenarioSpec};
use fifer::sim::{run_summarized_full, SimParams};
use fifer::trace::Trace;
use fifer::util::secs;

/// A small pure-generator sweep (traces are a function of the spec
/// alone): 2 policies x 2 seeds, mirroring the `test_obs.rs` pins.
const SPEC: &str = r#"
[scenario]
name = "optimality-pin"
duration_s = 60
drain_s = 10
seeds = [7, 42]
traces = ["t"]
mixes = ["Heavy"]
policies = ["Bline", "Fifer"]

[trace.t]
expr = "poisson(rate=20)"
"#;

fn run_with_optimality(policy: Policy, seed: u64) -> fifer::metrics::Summary {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = seed;
    let (_, sum, _) = run_summarized_full(
        SimParams {
            cfg,
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(20.0, 60),
            drain_s: 30.0,
        },
        0,
        None,
        true,
    );
    sum
}

/// The headline invariant: for every registered policy, at every tested
/// seed, no estimator's bound exceeds the run's achieved cost on either
/// objective. Soundness holds by construction (deadlines are relaxed to
/// realized completions; per-invocation occupancy never exceeds the
/// realized batch share), so any failure here is an estimator bug, not
/// an unlucky seed.
#[test]
fn bounds_are_sound_for_every_policy_and_seed() {
    for policy in Policy::ALL {
        for seed in [7u64, 42, 1009] {
            let sum = run_with_optimality(policy, seed);
            let o = sum.optimality.as_ref().expect("optimality requested");
            assert!(
                o.invocations > 0,
                "{}/{}: no invocations captured",
                policy.name(),
                seed
            );
            let per = [
                ("greedy", &o.greedy),
                ("path_cover", &o.path_cover),
                ("segment", &o.segment),
            ];
            for (name, b) in per {
                assert!(
                    b.container_s <= o.achieved_container_s + 1e-6,
                    "{}/{} {}: container_s bound {} > achieved {}",
                    policy.name(),
                    seed,
                    name,
                    b.container_s,
                    o.achieved_container_s
                );
                assert!(
                    b.cold_starts <= o.achieved_cold_starts,
                    "{}/{} {}: cold bound {} > achieved {}",
                    policy.name(),
                    seed,
                    name,
                    b.cold_starts,
                    o.achieved_cold_starts
                );
            }
            // combined bound inherits soundness and is non-vacuous
            assert!(o.bound_container_s <= o.achieved_container_s + 1e-6);
            assert!(o.bound_cold_starts <= o.achieved_cold_starts);
            assert!(
                o.bound_container_s > 0.0 && o.bound_cold_starts >= 1,
                "{}/{}: vacuous bound",
                policy.name(),
                seed
            );
            assert!(o.gap_container_pct >= -1e-9 && o.gap_container_pct <= 100.0);
            assert!(o.gap_cold_start_pct >= -1e-9 && o.gap_cold_start_pct <= 100.0);
        }
    }
}

/// Hand-computable fixture: three back-to-back 10s jobs on one stage,
/// zero overhead, no batching (cap 1), budgets exactly as tight as the
/// work. The unique optimal schedule runs them consecutively in one
/// container: 30 container-seconds, 1 cold start — and each estimator
/// must report exactly that, not merely a lower bound of it.
#[test]
fn three_job_fixture_all_estimators_hit_exact_optimum() {
    let inv = |enq_s: f64, end_s: f64| Invocation {
        ms_id: 0,
        enqueued: secs(enq_s),
        exec_start: secs(enq_s),
        exec_end: secs(end_s),
        batch: 1,
        budget: secs(10.0),
    };
    let mut batch_cap = BTreeMap::new();
    batch_cap.insert(0usize, 1usize);
    let log = InvocationLog {
        entries: vec![inv(0.0, 10.0), inv(10.0, 20.0), inv(20.0, 30.0)],
        gamma: 0.0,
        overhead: 0,
        batch_cap,
    };
    let per = [
        ("greedy", greedy_bound(&log)),
        ("path_cover", path_cover_bound(&log)),
        ("segment", segment_bound(&log)),
    ];
    for (name, b) in per {
        assert_eq!(b.container_s, 30.0, "{name}: container_s {}", b.container_s);
        assert_eq!(b.cold_starts, 1, "{name}: cold_starts {}", b.cold_starts);
    }
    // against a recorder that realizes the optimum, the gap is exactly 0
    let mut rec = Recorder::new();
    rec.horizon = secs(30.0);
    rec.container_spawned(0, 0, 0, true);
    rec.container_retired(0, secs(30.0));
    let rep = estimator::analyze(&log, &rec);
    assert_eq!(rep.bound_container_s, 30.0);
    assert_eq!(rep.bound_cold_starts, 1);
    assert_eq!(rep.achieved_container_s, 30.0);
    assert_eq!(rep.achieved_cold_starts, 1);
    assert_eq!(rep.gap_container_pct, 0.0);
    assert_eq!(rep.gap_cold_start_pct, 0.0);
}

/// `--optimality` output is a pure function of the spec: byte-identical
/// across repeated runs and across `--threads 1` vs `4`, mirroring the
/// `--slo-timeline` / `--trace-out` pins in `test_obs.rs`.
#[test]
fn optimality_json_is_byte_identical_across_runs_and_thread_counts() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let render = |threads| {
        let results = scenario::run_scenario_full(&spec, threads, None, true).unwrap();
        assert!(results.iter().all(|r| r.summary.optimality.is_some()));
        scenario::results_json(&spec, &results).to_string()
    };
    let serial = render(1);
    assert_eq!(serial, render(1), "run-to-run divergence");
    assert_eq!(serial, render(4), "thread-count divergence");
    assert!(serial.contains("\"optimality\""));
    assert!(serial.contains("\"bound_container_s\""));
    assert!(serial.contains("\"path_cover\""));
}

/// The estimators are pure observers: a sweep that doesn't ask for
/// them carries no `optimality` block, and its JSON is unchanged.
#[test]
fn plain_sweep_carries_no_optimality_block() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let results = scenario::run_scenario(&spec, 2).unwrap();
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.summary.optimality.is_none()));
    let js = scenario::results_json(&spec, &results).to_string();
    assert!(!js.contains("\"optimality\""));
}

/// Enabling the invocation log cannot perturb scheduling: the summary
/// of a run with `--optimality` matches the plain run byte-for-byte
/// once the block itself is stripped (here: compare shared scalars).
#[test]
fn invocation_log_is_a_pure_observer() {
    let cat = Catalog::paper();
    let params = || SimParams {
        cfg: SystemConfig::prototype(Policy::Fifer),
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(20.0, 40),
        drain_s: 20.0,
    };
    let (_, plain, _) = run_summarized_full(params(), 0, None, false);
    let (_, logged, _) = run_summarized_full(params(), 0, None, true);
    assert!(plain.optimality.is_none() && logged.optimality.is_some());
    assert_eq!(plain.jobs, logged.jobs);
    assert_eq!(plain.total_spawned, logged.total_spawned);
    assert_eq!(plain.cold_starts, logged.cold_starts);
    assert!(plain.median_ms.to_bits() == logged.median_ms.to_bits());
    assert!(plain.energy_wh.to_bits() == logged.energy_wh.to_bits());
}
