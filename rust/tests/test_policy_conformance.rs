//! Policy-conformance suite: every policy in the registry — including
//! post-paper additions like `Kn` and `FiferEq` — must drive the cluster
//! correctly under the λ=5 Poisson smoke workload: jobs complete,
//! request conservation and store-index invariants hold throughout the
//! run, and each policy's *declared* capabilities match what the
//! mechanics observe.

use fifer::config::{Policy, RmConfig, SystemConfig};
use fifer::coordinator::queue::Ordering as QueueOrdering;
use fifer::coordinator::slack::SlackPlan;
use fifer::model::Catalog;
use fifer::sim::{Engine, SimParams};
use fifer::trace::Trace;
use fifer::util::secs;

fn smoke_params(policy: Policy, seed: u64) -> SimParams {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = seed;
    cfg.rm.idle_timeout_s = 60.0;
    SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(5.0, 60),
        drain_s: 30.0,
    }
}

#[test]
fn every_registered_policy_completes_the_smoke_sim_with_invariants() {
    let cat = Catalog::paper();
    for policy in Policy::ALL {
        // conservation + store consistency verified every 200 events
        let rec = Engine::new(smoke_params(policy, 1))
            .run_checked(200)
            .unwrap_or_else(|e| panic!("{}: invariant violated: {e}", policy.name()));
        let sum = rec.summarize(&cat);
        assert!(sum.jobs > 50, "{}: only {} jobs completed", policy.name(), sum.jobs);
        assert!(sum.total_spawned > 0, "{}: never spawned", policy.name());
        assert!(sum.energy_wh > 0.0, "{}: no energy accounted", policy.name());
    }
}

#[test]
fn declared_capabilities_match_registry_and_slack_plan() {
    let cat = Catalog::paper();
    let chains = cat.mix("Heavy").unwrap().chains.clone();
    for policy in Policy::ALL {
        let built = policy.build();
        // the enum facade and the trait object must agree
        assert_eq!(policy.name(), built.name());
        assert_eq!(policy.batching(), built.batching());
        assert_eq!(policy.proactive(), built.proactive());
        assert_eq!(
            policy.lsf(),
            built.queue_order() == QueueOrdering::LeastSlackFirst
        );
        // a non-batching policy's slack plan must pin every batch to 1
        let rm = RmConfig::paper(policy);
        let plan = SlackPlan::build(&cat, &chains, &rm, built.batching());
        if !built.batching() {
            for (&ms, &b) in &plan.batch {
                assert_eq!(b, 1, "{}: stage {ms} batch {b}", policy.name());
            }
        } else {
            // batching policies get at least one stage with a real batch
            assert!(
                plan.batch.values().any(|&b| b > 1),
                "{}: batching declared but every batch is 1",
                policy.name()
            );
        }
    }
}

#[test]
fn monitor_only_policies_still_drain() {
    // Kn never spawns from on_arrival: every container it ever creates
    // comes from monitor-tick scaling, so nothing can exist before the
    // first monitor interval — yet the queue must still drain.
    let p = smoke_params(Policy::Kn, 3);
    let monitor_s = p.cfg.rm.monitor_interval_s;
    let rec = Engine::new(p).run_checked(200).unwrap();
    let cat = Catalog::paper();
    let sum = rec.summarize(&cat);
    assert!(sum.jobs > 50, "Kn drained only {} jobs", sum.jobs);
    assert!(!rec.containers.is_empty());
    assert!(
        rec.containers
            .iter()
            .all(|c| c.spawned_at >= secs(monitor_s)),
        "Kn spawned a container before the first monitor tick"
    );
}

#[test]
fn sbatch_pool_stays_fixed_under_conformance_run() {
    // the one policy that must never scale or reclaim after t = 0
    let rec = Engine::new(smoke_params(Policy::SBatch, 5))
        .run_checked(200)
        .unwrap();
    assert!(rec.containers.iter().all(|c| c.spawned_at == 0));
}

#[test]
fn fifereq_ablation_differs_from_fifer() {
    // same workload, same seed: the ablated slack division must yield a
    // different (equal-division) batch plan than proportional Fifer on
    // at least one stage of the heavy mix
    let cat = Catalog::paper();
    let chains = cat.mix("Heavy").unwrap().chains.clone();
    let plan_f = SlackPlan::build(&cat, &chains, &RmConfig::paper(Policy::Fifer), true);
    let plan_eq = SlackPlan::build(&cat, &chains, &RmConfig::paper(Policy::FiferEq), true);
    assert!(
        plan_f.batch != plan_eq.batch || plan_f.s_r_ms != plan_eq.s_r_ms,
        "equal-division ablation produced an identical plan"
    );
}
