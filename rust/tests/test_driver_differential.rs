//! Sim-vs-live agreement: every registered policy runs through BOTH
//! drivers of the shared `coordinator::engine` core — the virtual-time
//! simulator and the real-time server (synthetic executor backend, so no
//! artifacts/PJRT are needed) — on the same seed, cluster shape, and RM
//! knobs, and the decision counts (container spawns, batched executions,
//! completed jobs, reclamations) must agree within the live path's
//! timing tolerance.
//!
//! This is the paper's §5.2 "simulator validated against the prototype"
//! claim, restructured: after PR 4 the two paths share one decision
//! core, so the only divergence left is physical timing (thread
//! scheduling, real sleeps vs sampled latencies, generator jitter). The
//! bounds below are deliberately loose — they catch structural drift
//! (a driver that stops spawning, retiring, or batching), not noise.

use fifer::config::{ClusterConfig, Policy, SystemConfig};
use fifer::model::Catalog;
use fifer::obs::ObsConfig;
use fifer::server::{serve, ServeParams};
use fifer::sim::{run_sim, Engine, SimParams};
use fifer::trace::Trace;
use fifer::util::json::Json;
use fifer::util::secs;

/// Live container slots == sim cluster capacity (1 node x SLOTS).
const SLOTS: usize = 16;
const RATE: f64 = 10.0;
const DURATION_S: usize = 12;
const DRAIN_S: f64 = 15.0;

fn config(policy: Policy) -> SystemConfig {
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = 42;
    cfg.cluster = ClusterConfig {
        nodes: 1,
        cores_per_node: SLOTS,
        cpu_per_container: 1.0,
        ..ClusterConfig::prototype()
    };
    // tight control loop so monitor-driven policies act inside the short
    // horizon, and idle reclamation fires before the drain ends
    cfg.rm.monitor_interval_s = 1.0;
    cfg.rm.sample_window_s = 1.0;
    cfg.rm.idle_timeout_s = 6.0;
    cfg
}

/// |a - b| within a factor-4 band plus an absolute slack — loose enough
/// for wall-clock jitter, tight enough to catch a driver that stopped
/// making a class of decisions.
fn close(a: u64, b: u64, slack: u64) -> bool {
    a <= b * 4 + slack && b <= a * 4 + slack
}

fn differential(policy: Policy) {
    let cat = Catalog::paper();
    let chains = cat.mix("Heavy").unwrap().chains.clone();

    let (sim_rec, sim_sum) = run_sim(SimParams {
        cfg: config(policy),
        chains: chains.clone(),
        trace: Trace::poisson(RATE, DURATION_S),
        drain_s: DRAIN_S,
    });

    let mut p = ServeParams::quick(RATE, DURATION_S as f64);
    p.cfg = config(policy);
    p.chains = chains;
    p.executors = SLOTS;
    p.drain_s = DRAIN_S;
    p.synthetic = true;
    let live = serve(p).expect("synthetic live run");

    let tag = policy.name();
    let (sj, lj) = (sim_sum.jobs, live.summary.jobs);
    let (ss, ls) = (sim_sum.total_spawned, live.summary.total_spawned);
    let (sb, lb) = (sim_rec.batches, live.recorder.batches);
    let (sr, lr) = (sim_rec.reclaimed, live.recorder.reclaimed);

    // every policy moves traffic in both worlds at this rate
    assert!(lj > 0, "{tag}: live completed no jobs (sim {sj})");
    assert!(ls > 0, "{tag}: live spawned no containers (sim {ss})");
    assert!(lb > 0, "{tag}: live executed no batches (sim {sb})");

    // decision counts agree within timing tolerance
    assert!(
        lj >= sj / 2 && lj <= sj * 2 + 5,
        "{tag}: completed jobs diverge (sim {sj}, live {lj})"
    );
    assert!(close(ss, ls, 8), "{tag}: spawns diverge (sim {ss}, live {ls})");
    assert!(close(sb, lb, 8), "{tag}: batches diverge (sim {sb}, live {lb})");

    // reclamation: SBatch never scales in; for everyone else a clearly
    // reclaiming sim implies a reclaiming live path
    if policy == Policy::SBatch {
        assert_eq!(sr, 0, "{tag}: sim reclaimed a fixed-pool container");
        assert_eq!(lr, 0, "{tag}: live reclaimed a fixed-pool container");
    } else {
        assert!(lr <= ls, "{tag}: more reclaims than spawns");
        if sr >= 3 {
            assert!(lr > 0, "{tag}: sim reclaimed {sr}, live reclaimed none");
        }
    }
}

// One #[test] per policy so the wall-clock runs overlap under the
// default parallel test runner.

#[test]
fn differential_bline() {
    differential(Policy::Bline);
}

#[test]
fn differential_sbatch() {
    differential(Policy::SBatch);
}

#[test]
fn differential_rscale() {
    differential(Policy::RScale);
}

#[test]
fn differential_bpred() {
    differential(Policy::BPred);
}

#[test]
fn differential_fifer() {
    differential(Policy::Fifer);
}

#[test]
fn differential_kn() {
    differential(Policy::Kn);
}

#[test]
fn differential_fifereq() {
    differential(Policy::FiferEq);
}

#[test]
fn advance_to_fires_due_events_once_and_never_moves_time_backwards() {
    // Drive the engine the way a real-time driver does — explicit
    // advance_to / arrival_at calls — and pin the clock contract: an
    // event due *exactly* at t fires, re-advancing to the same instant
    // does not double-fire it, and a stale (past) injection timestamp
    // clamps to the current engine time instead of rewinding it.
    let cat = Catalog::paper();
    let chains = cat.mix("Heavy").unwrap().chains.clone();
    let mut eng = Engine::new(SimParams {
        cfg: config(Policy::Bline), // per-request spawning: arrivals complete
        chains: chains.clone(),
        trace: Trace::poisson(RATE, DURATION_S), // unused: driven manually
        drain_s: DRAIN_S,
    });
    eng.bootstrap(secs(60.0), secs(90.0));
    assert_eq!(eng.recorder.energy_series.len(), 0, "no scan before t=1s");

    // the first Scan is due exactly at t = monitor_interval = 1 s
    eng.advance_to(secs(1.0));
    assert_eq!(
        eng.recorder.energy_series.len(),
        1,
        "scan due exactly at t must fire"
    );
    // re-advancing to the same instant must not re-fire it
    eng.advance_to(secs(1.0));
    assert_eq!(eng.recorder.energy_series.len(), 1, "scan double-fired");

    // a stale arrival timestamp clamps to now (time never rewinds): the
    // job's recorded arrival must be 1 s, not 0.5 s
    eng.arrival_at(chains[0], secs(0.5));
    assert_eq!(eng.jobs_arrived(), 1);

    // each later scan fires exactly once while advancing across ticks
    eng.advance_to(secs(4.5));
    assert_eq!(
        eng.recorder.energy_series.len(),
        4,
        "scans at 1,2,3,4 s fire once each"
    );
    for w in eng.recorder.energy_series.windows(2) {
        assert!(w[0].0 < w[1].0, "scan timestamps must increase");
    }

    // let the clamped arrival complete (Bline spawns per request), then
    // verify its recorded arrival saw the clamped clock
    eng.advance_to(secs(30.0));
    assert_eq!(eng.jobs_completed(), 1, "arrival did not complete");
    assert_eq!(
        eng.recorder.jobs[0].arrival,
        secs(1.0),
        "stale timestamp must clamp to engine time"
    );
}

#[test]
fn obs_timeline_schema_is_driver_agnostic() {
    // The observability plane's core claim: both drivers emit the SAME
    // timeline/contract schema — keys, SLO names, window structure —
    // with only the counted quantities subject to timing tolerance.
    fn keys(j: &Json) -> Vec<String> {
        match j {
            Json::Obj(m) => m.keys().cloned().collect(),
            other => panic!("expected a JSON object, got {other:?}"),
        }
    }
    fn field<'a>(j: &'a Json, name: &str) -> &'a Json {
        match j {
            Json::Obj(m) => m.get(name).unwrap_or_else(|| panic!("missing {name:?}")),
            other => panic!("expected a JSON object, got {other:?}"),
        }
    }

    let cat = Catalog::paper();
    let chains = cat.mix("Heavy").unwrap().chains.clone();

    let (_, report) = Engine::new(SimParams {
        cfg: config(Policy::Fifer),
        chains: chains.clone(),
        trace: Trace::poisson(RATE, DURATION_S),
        drain_s: DRAIN_S,
    })
    .run_collecting(0, Some(ObsConfig::default()))
    .expect("sim run");
    let sim = report.expect("collector was enabled");

    let mut p = ServeParams::quick(RATE, DURATION_S as f64);
    p.cfg = config(Policy::Fifer);
    p.chains = chains;
    p.executors = SLOTS;
    p.drain_s = DRAIN_S;
    p.synthetic = true;
    let live = serve(p)
        .expect("synthetic live run")
        .obs
        .expect("serve always collects");

    assert!(!sim.rows.is_empty(), "sim produced no timeline rows");
    assert!(!live.rows.is_empty(), "live produced no timeline rows");

    // identical row schema and summary schema (BTreeMap-sorted key sets)
    assert_eq!(keys(&sim.rows[0].to_json()), keys(&live.rows[0].to_json()));
    let (ss, ls) = (sim.summary_json(), live.summary_json());
    assert_eq!(keys(&ss), keys(&ls));
    assert_eq!(keys(field(&ss, "slo")), keys(field(&ls, "slo")));
    assert_eq!(
        keys(field(&ss, "slo")),
        vec![
            "cold_start_ratio",
            "container_utilization",
            "e2e_p95_ms",
            "request_success_rate",
        ]
    );

    // same contract objectives in the same order
    let names: Vec<&str> = sim.contract().iter().map(|e| e.name).collect();
    let live_names: Vec<&str> = live.contract().iter().map(|e| e.name).collect();
    assert_eq!(names, live_names);

    // the counted quantities track the same workload within the live
    // path's timing band
    assert!(
        close(sim.totals.completions, live.totals.completions, 8),
        "completions diverge (sim {}, live {})",
        sim.totals.completions,
        live.totals.completions
    );
    assert!(
        close(
            sim.totals.spawns_cold + sim.totals.spawns_warm,
            live.totals.spawns_cold + live.totals.spawns_warm,
            8
        ),
        "spawns diverge (sim {}/{}, live {}/{})",
        sim.totals.spawns_cold,
        sim.totals.spawns_warm,
        live.totals.spawns_cold,
        live.totals.spawns_warm
    );
}

#[test]
fn registry_is_fully_covered() {
    // fail loudly when a policy is registered without a differential
    // smoke above
    assert_eq!(Policy::ALL.len(), 7, "add a differential_<name> test");
}
