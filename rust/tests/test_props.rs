//! Property-based tests on coordinator invariants (own mini-proptest,
//! see `fifer::util::prop`): routing, batching, bin-packing, queues,
//! slack distribution, and whole-sim conservation.

use fifer::config::{Policy, RmConfig, SlackPolicy, SystemConfig};
use fifer::coordinator::queue::{Ordering as QOrder, QueueEntry, StageQueue};
use fifer::coordinator::slack::{batch_size, distribute_slack, SlackPlan};
use fifer::coordinator::state::StateStore;
use fifer::model::Catalog;
use fifer::sim::{Engine, SimParams};
use fifer::trace::Trace;
use fifer::util::prop::{assert_prop, check};
use fifer::util::rng::Pcg;

#[test]
fn prop_lsf_pops_in_key_order() {
    check("lsf_order", 200, |rng| {
        let mut q = StageQueue::new(QOrder::LeastSlackFirst);
        let n = 1 + rng.below(200);
        for i in 0..n {
            q.push(QueueEntry {
                job_id: i as u64,
                lsf_key: rng.next_u64() % 10_000,
                enqueued: i as u64,
                seq: i as u64,
            });
        }
        let mut last = 0u64;
        while let Some(e) = q.pop() {
            assert_prop(e.lsf_key >= last, "keys must be non-decreasing")?;
            last = e.lsf_key;
        }
        Ok(())
    });
}

#[test]
fn prop_queue_conserves_entries() {
    check("queue_conservation", 200, |rng| {
        let order = if rng.f64() < 0.5 {
            QOrder::Fifo
        } else {
            QOrder::LeastSlackFirst
        };
        let mut q = StageQueue::new(order);
        let mut pushed = 0u64;
        let mut popped = 0u64;
        for i in 0..500 {
            if rng.f64() < 0.6 {
                q.push(QueueEntry {
                    job_id: i,
                    lsf_key: rng.next_u64() % 1000,
                    enqueued: i,
                    seq: i,
                });
                pushed += 1;
            } else if q.pop().is_some() {
                popped += 1;
            }
        }
        let (p, o) = q.counters();
        assert_prop(p == pushed && o == popped, "counters drifted")?;
        assert_prop(q.len() == (pushed - popped) as usize, "len != pushed-popped")
    });
}

#[test]
fn prop_binpack_never_exceeds_node_capacity() {
    check("binpack_capacity", 100, |rng| {
        let nodes = 2 + rng.below(8);
        let cores = 2 + rng.below(16);
        let mut store = StateStore::new(nodes, cores, 0.5);
        let mut live: Vec<u64> = Vec::new();
        for step in 0..400 {
            if rng.f64() < 0.7 {
                if let Some(cid) = store.spawn(rng.below(5), 1 + rng.below(8), step, 0, true) {
                    live.push(cid);
                }
            } else if !live.is_empty() {
                let cid = live.swap_remove(rng.below(live.len()));
                store.remove(cid);
            }
            for n in &store.nodes {
                assert_prop(
                    n.alloc_cores <= n.total_cores + 1e-9,
                    "node over capacity",
                )?;
                assert_prop(n.alloc_cores >= -1e-9, "negative allocation")?;
            }
        }
        // index + aggregate consistency (slab, ready/idle sets, counters)
        store.check_consistency().map_err(|e| format!("store drift: {e}"))?;
        assert_prop(store.total_containers() == live.len(), "live count drift")
    });
}

#[test]
fn prop_greedy_placement_is_most_loaded_first() {
    check("greedy_placement", 100, |rng| {
        let mut store = StateStore::new(4, 8, 0.5);
        for step in 0..40 {
            let before: Vec<f64> = store.nodes.iter().map(|n| n.free_cores()).collect();
            if let Some(cid) = store.spawn(rng.below(3), 1, step, 0, false) {
                let node = store.get(cid).unwrap().node;
                // chosen node must have had the minimal feasible free cores
                let min_feasible = before
                    .iter()
                    .copied()
                    .filter(|&f| f >= 0.5 - 1e-9)
                    .fold(f64::INFINITY, f64::min);
                assert_prop(
                    (before[node] - min_feasible).abs() < 1e-9,
                    "not most-loaded feasible node",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batch_size_bounds() {
    check("batch_size_bounds", 300, |rng| {
        let slack = rng.range(0.0, 2000.0);
        let exec = rng.range(0.01, 300.0);
        let maxb = 1 + rng.below(64);
        let b = batch_size(slack, exec, maxb);
        assert_prop((1..=maxb).contains(&b), "batch out of bounds")?;
        // never exceed what slack admits (by Eq. 1)
        if slack / exec >= 1.0 {
            assert_prop(b as f64 <= slack / exec + 1e-9, "batch exceeds slack/exec")?;
        }
        Ok(())
    });
}

#[test]
fn prop_slack_distribution_sums_to_total() {
    let cat = Catalog::paper();
    check("slack_sum", 100, |rng| {
        let chain = rng.below(cat.chains.len());
        let policy = if rng.f64() < 0.5 {
            SlackPolicy::Proportional
        } else {
            SlackPolicy::EqualDivision
        };
        let slacks = distribute_slack(&cat, chain, policy, true);
        let total: f64 = slacks.iter().sum();
        assert_prop(
            (total - cat.chains[chain].slack_ms).abs() < 1e-6,
            "distributed slack != total slack",
        )?;
        assert_prop(slacks.iter().all(|&s| s >= 0.0), "negative stage slack")
    });
}

#[test]
fn prop_plan_batches_within_limits() {
    let cat = Catalog::paper();
    check("plan_batches", 50, |rng| {
        let mix = &cat.mixes[rng.below(cat.mixes.len())];
        let mut rm = RmConfig::paper(Policy::Fifer);
        rm.max_batch = 1 + rng.below(64);
        let plan = SlackPlan::build(&cat, &mix.chains, &rm, true);
        for &ms in &cat.mix_stages(mix) {
            let b = plan.batch_for(ms);
            assert_prop((1..=rm.max_batch).contains(&b), "plan batch out of range")?;
        }
        Ok(())
    });
}

#[test]
fn prop_sim_conserves_jobs_across_policies() {
    // randomized short sims: every arrival is either queued, in flight,
    // or completed at drain end; store invariants hold.
    let cat = Catalog::paper();
    check("sim_conservation", 12, |rng: &mut Pcg| {
        let policy = Policy::ALL[rng.below(5)];
        let mix = &cat.mixes[rng.below(3)];
        let lambda = 2.0 + rng.f64() * 30.0;
        let dur = 30 + rng.below(60);
        let mut cfg = SystemConfig::prototype(policy);
        cfg.seed = rng.next_u64();
        cfg.rm.idle_timeout_s = 30.0 + rng.f64() * 120.0;
        let p = SimParams {
            cfg,
            chains: mix.chains.clone(),
            trace: Trace::poisson(lambda, dur),
            drain_s: 40.0,
        };
        let eng = Engine::new(p);
        let rec = eng.run();
        assert_prop(!rec.jobs.is_empty(), "no jobs completed")?;
        // stage timeline sanity on every record
        for j in rec.jobs.iter().take(500) {
            let mut prev_end = j.arrival;
            for s in &j.stages {
                assert_prop(s.enqueued >= prev_end, "stage enqueued before previous end")?;
                assert_prop(s.exec_start >= s.enqueued, "exec before enqueue")?;
                assert_prop(s.exec_end >= s.exec_start, "negative exec")?;
                assert_prop(s.cold_wait <= s.queue_wait(), "cold > queue wait")?;
                prev_end = s.exec_end;
            }
            assert_prop(j.completion == prev_end, "completion != last stage end")?;
        }
        Ok(())
    });
}

#[test]
fn prop_trace_arrivals_sorted_and_in_range() {
    check("trace_arrivals", 50, |rng| {
        let dur = 5 + rng.below(100);
        let t = Trace::wits(dur, rng.next_u64());
        let mut r = Pcg::new(rng.next_u64());
        let arr = t.arrivals(&mut r);
        assert_prop(arr.windows(2).all(|w| w[0] <= w[1]), "unsorted arrivals")?;
        assert_prop(
            arr.iter().all(|&a| a < (dur as u64) * 1_000_000),
            "arrival outside trace",
        )
    });
}

#[test]
fn prop_energy_monotone_in_time() {
    use fifer::config::ClusterConfig;
    use fifer::energy::NodeEnergy;
    check("energy_monotone", 100, |rng| {
        let cfg = ClusterConfig::prototype();
        let mut n = NodeEnergy::new();
        let mut t = 0u64;
        let mut last = 0.0f64;
        for _ in 0..50 {
            t += (rng.f64() * 30e6) as u64;
            let busy = rng.f64() * 16.0;
            let alloc = busy + rng.f64() * (16.0 - busy);
            n.update(t, busy, alloc, &cfg);
            assert_prop(n.energy_wh() >= last - 1e-12, "energy decreased")?;
            last = n.energy_wh();
        }
        Ok(())
    });
}
