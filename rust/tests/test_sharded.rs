//! Sharded-coordinator integration pins (docs/DESIGN.md §Sharding):
//!
//! * `shards = 1` is **byte-identical** to the unsharded engine for
//!   every registered policy — recorder, summary JSON, and obs timeline.
//! * For a fixed shard count a run is a pure function of the seed, and
//!   scenario sweeps with sharded cells stay byte-identical across
//!   `--threads`.
//! * Conservation and store invariants hold per shard and cluster-wide
//!   while the rebalancer actively migrates capacity, and capacity
//!   hosting containers can never migrate.

use fifer::config::{Policy, SystemConfig};
use fifer::coordinator::sharded::RebalancerConfig;
use fifer::coordinator::state::StateStore;
use fifer::model::Catalog;
use fifer::obs::ObsConfig;
use fifer::scenario::{results_json, run_scenario, ScenarioSpec};
use fifer::sim::sharded::{run_sharded_collecting_full, run_sharded_summarized};
use fifer::sim::{run_summarized_full, SimParams};
use fifer::trace::Trace;
use fifer::util::secs;

fn params(policy: Policy, seed: u64, lambda: f64, dur: usize) -> SimParams {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = seed;
    SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(lambda, dur),
        drain_s: 30.0,
    }
}

/// An aggressive rebalancer that fires on any imbalance, every epoch —
/// worst case for the invariants the default hysteresis protects.
fn eager() -> RebalancerConfig {
    RebalancerConfig {
        pressure_ratio: 1.0,
        min_gap: 0.0,
        hysteresis_ticks: 1,
        cooldown_ticks: 0,
    }
}

#[test]
fn one_shard_matches_unsharded_for_every_policy_and_seed() {
    for policy in Policy::ALL {
        for seed in [7u64, 42] {
            let (rec, sum, report) =
                run_summarized_full(params(policy, seed, 10.0, 60), secs(30.0), Some(ObsConfig::default()), false);
            let (run, ssum) = run_sharded_summarized(
                params(policy, seed, 10.0, 60),
                1,
                secs(30.0),
                Some(ObsConfig::default()),
                false,
            )
            .unwrap();
            let tag = format!("{}/seed {seed}", policy.name());
            assert_eq!(run.migrations, 0, "{tag}: one shard can never migrate");
            assert_eq!(run.recorder.jobs, rec.jobs, "{tag}: job records diverged");
            assert_eq!(
                run.recorder.containers, rec.containers,
                "{tag}: container records diverged"
            );
            assert_eq!(
                run.recorder.energy_series, rec.energy_series,
                "{tag}: energy series diverged"
            );
            assert_eq!(
                ssum.to_json().to_string(),
                sum.to_json().to_string(),
                "{tag}: summary JSON diverged"
            );
            assert_eq!(
                run.report.unwrap().timeline_json().to_string(),
                report.unwrap().timeline_json().to_string(),
                "{tag}: obs timeline diverged"
            );
        }
    }
}

#[test]
fn invariants_hold_per_shard_and_cluster_wide_under_rebalancing() {
    // check_every > 0 makes the runner verify conservation + store
    // invariants per shard at every epoch, plus cluster-wide capacity
    // conservation — any violation is an Err here
    let run = run_sharded_collecting_full(params(Policy::Fifer, 7, 60.0, 120), 4, 50, None, false, eager())
        .expect("invariants violated under active rebalancing");
    assert!(
        run.migrations >= 1,
        "eager rebalancer never fired — the invariant check exercised nothing"
    );
    let total: f64 = run.shard_capacity_cores.iter().sum();
    let cfg = SystemConfig::prototype(Policy::Fifer);
    let expected = (cfg.cluster.nodes * cfg.cluster.cores_per_node) as f64;
    assert!(
        (total - expected).abs() < 1e-6,
        "cluster capacity not conserved: {total} != {expected}"
    );
}

#[test]
fn fixed_shard_count_is_byte_identical_across_runs() {
    let go = || {
        run_sharded_collecting_full(
            params(Policy::Fifer, 42, 40.0, 90),
            2,
            0,
            Some(ObsConfig::default()),
            false,
            eager(),
        )
        .unwrap()
    };
    let a = go();
    let b = go();
    assert_eq!(a.recorder.jobs, b.recorder.jobs);
    assert_eq!(a.recorder.containers, b.recorder.containers);
    assert_eq!(a.recorder.energy_series, b.recorder.energy_series);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.shard_arrivals, b.shard_arrivals);
    assert_eq!(a.shard_capacity_cores, b.shard_capacity_cores);
    assert_eq!(
        a.report.unwrap().timeline_json().to_string(),
        b.report.unwrap().timeline_json().to_string()
    );
}

#[test]
fn sharded_scenario_sweep_is_byte_identical_across_threads() {
    let spec = ScenarioSpec::parse(
        r#"
[scenario]
name = "shard-threads"
duration_s = 60
seeds = [7, 42]
traces = ["poisson"]
mixes = ["Heavy"]
policies = ["Fifer", "Bline"]
shards = [1, 2]
"#,
    )
    .unwrap();
    assert_eq!(spec.cells().len(), 8); // 2 seeds x 2 policies x 2 shard counts
    let serial = results_json(&spec, &run_scenario(&spec, 1).unwrap()).to_string();
    let parallel = results_json(&spec, &run_scenario(&spec, 4).unwrap()).to_string();
    assert_eq!(serial, parallel, "sharded sweep output depends on --threads");
}

#[test]
fn capacity_hosting_containers_never_migrates() {
    // 2 nodes x 4 cores, 1 core per container: four spawns fill node 0
    // (best-fit packing), the fifth lands on node 1
    let mut store = StateStore::new(2, 4, 1.0);
    let mut cids = Vec::new();
    for _ in 0..5 {
        cids.push(store.spawn(0, 4, 0, 0, false).expect("cluster has room"));
    }
    assert!(!store.node_is_empty(0));
    assert!(!store.node_is_empty(1));
    // both nodes host containers: neither may be drained
    assert!(store.drain_node(0).is_err());
    assert!(store.drain_node(1).is_err());
    // emptying node 1 makes it (and only it) drainable
    let on_node1: Vec<u64> = cids
        .iter()
        .copied()
        .filter(|&cid| store.get(cid).unwrap().node == 1)
        .collect();
    assert_eq!(on_node1.len(), 1);
    for cid in on_node1 {
        store.remove(cid);
    }
    assert!(store.drain_node(0).is_err(), "node 0 still hosts containers");
    assert_eq!(store.drain_node(1).unwrap(), 4.0);
    // the tombstone keeps indices dense and invariants intact
    store.check_consistency().unwrap();
    assert_eq!(store.capacity_cores(), 4.0);
}
