//! Integration: the live serving path end to end (real PJRT inference).
//! Requires `make artifacts`; no-ops gracefully without them.
//!
//! The live path is driven by the same `SchedulerPolicy` trait objects
//! as the simulator — batching comes from the policy, so the smoke
//! tests below swap policies (including the post-paper `Kn`/`FiferEq`)
//! purely through config.

use fifer::config::{Policy, RmConfig};
use fifer::server::{serve, ServeParams};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn quick_with_policy(policy: Policy, rate: f64, duration_s: f64) -> ServeParams {
    let mut p = ServeParams::quick(rate, duration_s);
    p.cfg.rm = RmConfig::paper(policy);
    p.executors = 1;
    p
}

#[test]
fn live_serve_completes_jobs_within_slo() {
    if !have_artifacts() {
        return;
    }
    let p = quick_with_policy(Policy::Fifer, 8.0, 4.0);
    let r = serve(p).unwrap();
    assert!(r.jobs > 5, "only {} jobs", r.jobs);
    assert!(r.median_ms > 0.0 && r.median_ms.is_finite());
    assert!(r.batches >= r.jobs / 32, "batch accounting broken");
    // the warm path should comfortably meet the paper's 1000 ms SLO on
    // these small models; allow cold-compile stragglers at the start
    assert!(
        r.slo_violation_pct < 60.0,
        "violations {:.1}%",
        r.slo_violation_pct
    );
}

#[test]
fn live_serve_batching_reduces_model_invocations() {
    if !have_artifacts() {
        return;
    }
    let rb = serve(quick_with_policy(Policy::Fifer, 25.0, 4.0)).unwrap();
    // Bline is the non-batching baseline: batch = 1 at every stage
    let ru = serve(quick_with_policy(Policy::Bline, 25.0, 4.0)).unwrap();
    // with batching, strictly fewer PJRT calls per completed job
    let per_job_b = rb.batches as f64 / rb.jobs.max(1) as f64;
    let per_job_u = ru.batches as f64 / ru.jobs.max(1) as f64;
    assert!(
        per_job_b < per_job_u,
        "batched {per_job_b:.2} vs unbatched {per_job_u:.2} calls/job"
    );
    assert!(rb.avg_batch > ru.avg_batch);
}

#[test]
fn live_serve_runs_every_registered_policy() {
    // `--policy kn` / `--policy fifereq` end-to-end: every registry
    // entry — present and future — must drive the live coordinator
    // without engine edits
    if !have_artifacts() {
        return;
    }
    for policy in Policy::ALL {
        let r = serve(quick_with_policy(policy, 10.0, 2.0)).unwrap();
        assert!(r.jobs > 0, "{}: no jobs served", policy.name());
        assert!(r.batches > 0, "{}: no batches", policy.name());
        // every realized batch holds at least one request
        assert!(r.avg_batch >= 1.0, "{}", policy.name());
    }
}
