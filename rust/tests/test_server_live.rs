//! Integration: the live serving path end to end (real PJRT inference).
//! Requires `make artifacts` plus a real `xla` binding; no-ops
//! gracefully without them. (The driver machinery itself is exercised
//! everywhere via the synthetic backend — see
//! `test_driver_differential.rs`.)
//!
//! The live path is the real-time driver over the same
//! `coordinator::engine` core as the simulator: container executor
//! threads are spawned, batched onto, and retired by the registered
//! `SchedulerPolicy` — the smoke tests below swap policies (including
//! the post-paper `Kn`/`FiferEq`) purely through config.

use fifer::config::{Policy, RmConfig};
use fifer::server::{serve, ServeParams};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn quick_with_policy(policy: Policy, rate: f64, duration_s: f64) -> ServeParams {
    let mut p = ServeParams::quick(rate, duration_s);
    p.cfg.rm = RmConfig::paper(policy);
    // tight control loop so monitor-driven scaling acts inside short runs
    p.cfg.rm.monitor_interval_s = 1.0;
    p.cfg.rm.sample_window_s = 1.0;
    p.executors = 8;
    p
}

#[test]
fn live_serve_completes_jobs_within_slo() {
    if !have_artifacts() {
        return;
    }
    let p = quick_with_policy(Policy::Fifer, 8.0, 4.0);
    let r = serve(p).unwrap();
    assert!(r.summary.jobs > 5, "only {} jobs", r.summary.jobs);
    assert!(r.summary.median_ms > 0.0 && r.summary.median_ms.is_finite());
    assert!(r.batches >= r.summary.jobs / 32, "batch accounting broken");
    // the warm path should comfortably meet the paper's 1000 ms SLO on
    // these small models; allow cold-compile stragglers at the start
    assert!(
        r.summary.slo_violation_pct < 60.0,
        "violations {:.1}%",
        r.summary.slo_violation_pct
    );
}

#[test]
fn live_serve_batching_reduces_model_invocations() {
    if !have_artifacts() {
        return;
    }
    let rb = serve(quick_with_policy(Policy::Fifer, 25.0, 4.0)).unwrap();
    // Bline is the non-batching baseline: batch = 1 at every stage
    let ru = serve(quick_with_policy(Policy::Bline, 25.0, 4.0)).unwrap();
    // with batching, strictly fewer PJRT calls per completed job
    let per_job_b = rb.batches as f64 / rb.summary.jobs.max(1) as f64;
    let per_job_u = ru.batches as f64 / ru.summary.jobs.max(1) as f64;
    assert!(
        per_job_b < per_job_u,
        "batched {per_job_b:.2} vs unbatched {per_job_u:.2} calls/job"
    );
    assert!(rb.avg_batch > ru.avg_batch);
}

#[test]
fn live_serve_runs_every_registered_policy() {
    // `--policy kn` / `--policy fifereq` end-to-end: every registry
    // entry — present and future — must drive the live coordinator
    // (including container scaling) without engine edits
    if !have_artifacts() {
        return;
    }
    for policy in Policy::ALL {
        let r = serve(quick_with_policy(policy, 10.0, 2.0)).unwrap();
        assert!(r.summary.jobs > 0, "{}: no jobs served", policy.name());
        assert!(r.batches > 0, "{}: no batches", policy.name());
        assert!(
            r.summary.total_spawned > 0,
            "{}: no containers spawned",
            policy.name()
        );
        // every realized batch holds at least one request
        assert!(r.avg_batch >= 1.0, "{}", policy.name());
    }
}
