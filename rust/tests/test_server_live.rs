//! Integration: the live serving path end to end (real PJRT inference).
//! Requires `make artifacts`; no-ops gracefully without them.

use fifer::server::{serve, ServeParams};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn live_serve_completes_jobs_within_slo() {
    if !have_artifacts() {
        return;
    }
    let mut p = ServeParams::quick(8.0, 4.0);
    p.executors = 1;
    let r = serve(p).unwrap();
    assert!(r.jobs > 5, "only {} jobs", r.jobs);
    assert!(r.median_ms > 0.0 && r.median_ms.is_finite());
    assert!(r.batches >= r.jobs / 32, "batch accounting broken");
    // the warm path should comfortably meet the paper's 1000 ms SLO on
    // these small models; allow cold-compile stragglers at the start
    assert!(
        r.slo_violation_pct < 60.0,
        "violations {:.1}%",
        r.slo_violation_pct
    );
}

#[test]
fn live_serve_batching_reduces_model_invocations() {
    if !have_artifacts() {
        return;
    }
    let mut batched = ServeParams::quick(25.0, 4.0);
    batched.executors = 1;
    let rb = serve(batched).unwrap();
    let mut unbatched = ServeParams::quick(25.0, 4.0);
    unbatched.executors = 1;
    unbatched.batching = false;
    let ru = serve(unbatched).unwrap();
    // with batching, strictly fewer PJRT calls per completed job
    let per_job_b = rb.batches as f64 / rb.jobs.max(1) as f64;
    let per_job_u = ru.batches as f64 / ru.jobs.max(1) as f64;
    assert!(
        per_job_b < per_job_u,
        "batched {per_job_b:.2} vs unbatched {per_job_u:.2} calls/job"
    );
    assert!(rb.avg_batch > ru.avg_batch);
}
