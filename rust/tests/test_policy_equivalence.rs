//! Fixed-seed equivalence: the trait-based policy implementations must
//! be *byte-identical* to the pre-refactor engine, whose policy logic
//! lived as enum branches inside `sim::Engine::{run, enqueue_stage,
//! on_monitor, on_scan}`.
//!
//! `LegacyOracle` below is a line-for-line transcription of those
//! pre-refactor branches (capability sets hardcoded exactly as the old
//! `Policy::{batching, proactive, lsf}` matches) expressed once through
//! the hook API. Running every paper policy both ways at seeds {7, 42}
//! and demanding identical `Recorder` contents (job records, container
//! events, energy series) pins the refactor: any drift in a per-policy
//! impl — spawn counts, spawn *order* (which changes RNG draws),
//! reclamation order — fails the comparison on the first divergence.

use fifer::config::{Policy, SystemConfig};
use fifer::coordinator::policy::{PolicyView, ScalingPlan, SchedulerPolicy};
use fifer::coordinator::queue::Ordering as QueueOrdering;
use fifer::coordinator::{policy, scaling};
use fifer::model::Catalog;
use fifer::predictor::{classic, nn, Predictor};
use fifer::sim::{run_sim, run_sim_with, SimParams};
use fifer::trace::Trace;

/// The pre-refactor engine's policy branches, transcribed.
struct LegacyOracle {
    policy: Policy,
}

impl LegacyOracle {
    // capability sets exactly as the pre-refactor `config::Policy`
    fn legacy_batching(&self) -> bool {
        matches!(self.policy, Policy::SBatch | Policy::RScale | Policy::Fifer)
    }

    fn legacy_proactive(&self) -> bool {
        matches!(self.policy, Policy::BPred | Policy::Fifer)
    }

    fn legacy_lsf(&self) -> bool {
        matches!(self.policy, Policy::RScale | Policy::BPred | Policy::Fifer)
    }
}

impl SchedulerPolicy for LegacyOracle {
    fn name(&self) -> &'static str {
        "LegacyOracle"
    }

    fn queue_order(&self) -> QueueOrdering {
        if self.legacy_lsf() {
            QueueOrdering::LeastSlackFirst
        } else {
            QueueOrdering::Fifo
        }
    }

    fn batching(&self) -> bool {
        self.legacy_batching()
    }

    fn proactive(&self) -> bool {
        self.legacy_proactive()
    }

    // pre-refactor Engine::new predictor match
    fn make_predictor(&self, cfg: &SystemConfig) -> Option<Box<dyn Predictor>> {
        match self.policy {
            Policy::Fifer => {
                let wp =
                    std::path::Path::new(&cfg.artifacts_dir).join("predictor_weights.json");
                let p: Box<dyn Predictor> = match nn::LstmPredictor::load(&wp) {
                    Ok(l) => Box::new(l),
                    Err(_) => Box::new(classic::Ewma::new(cfg.rm.ewma_alpha)),
                };
                Some(p)
            }
            Policy::BPred => Some(Box::new(classic::Ewma::new(cfg.rm.ewma_alpha))),
            _ => None,
        }
    }

    // pre-refactor `provision_sbatch_pool` (aborted wholesale on the
    // first rejected spawn -> stop_on_full)
    fn on_start(&mut self, view: &PolicyView) -> ScalingPlan {
        if self.policy != Policy::SBatch {
            return ScalingPlan::none();
        }
        let mut spawns = Vec::new();
        for &ms_id in view.stages {
            let pool = scaling::sbatch_pool(
                view.avg_rate_hint * view.share(ms_id),
                view.batch(ms_id),
                view.exec_ms_mean(ms_id),
                view.gamma(),
                view.cfg.rm.sbatch_headroom,
            );
            spawns.push((ms_id, pool));
        }
        ScalingPlan {
            spawns,
            stop_on_full: true,
        }
    }

    // pre-refactor `enqueue_stage` deficit branch (`!policy.batching()`)
    fn on_arrival(&mut self, ms_id: usize, view: &PolicyView) -> usize {
        if self.legacy_batching() {
            return 0;
        }
        let covered = view.warm_free_slots(ms_id) + view.starting_slots(ms_id);
        view.pending(ms_id).saturating_sub(covered)
    }

    // pre-refactor `on_monitor`: Algorithm 1a then 1b, sequentially. The
    // old code spawned reactively *before* reading `live` proactively, so
    // the proactive pass here counts the reactive spawns as live-to-be.
    fn on_monitor(&mut self, view: &PolicyView) -> ScalingPlan {
        let mut spawns: Vec<(usize, usize)> = Vec::new();
        if self.legacy_batching() && self.policy != Policy::SBatch {
            for &ms_id in view.stages {
                let d = scaling::reactive_scale(
                    view.pending(ms_id),
                    view.batch(ms_id),
                    view.s_r_ms(ms_id),
                    view.live(ms_id),
                    view.expected_cold_ms(ms_id),
                );
                if d.spawn > 0 {
                    spawns.push((ms_id, d.spawn));
                }
            }
        }
        if self.legacy_proactive() {
            if let Some(forecast) = view.forecast {
                for &ms_id in view.stages {
                    let planned: usize = spawns
                        .iter()
                        .filter(|&&(m, _)| m == ms_id)
                        .map(|&(_, n)| n)
                        .sum();
                    let rate = forecast * view.share(ms_id);
                    let spawn = scaling::proactive_scale(
                        rate,
                        view.batch(ms_id),
                        view.exec_ms_mean(ms_id),
                        view.gamma(),
                        view.live(ms_id) + planned,
                    );
                    if spawn > 0 {
                        spawns.push((ms_id, spawn));
                    }
                }
            }
        }
        ScalingPlan {
            spawns,
            stop_on_full: false,
        }
    }

    // pre-refactor `on_scan`: idle scale-in for everyone but SBatch
    fn on_scan(&mut self, view: &PolicyView) -> Vec<u64> {
        if self.policy == Policy::SBatch {
            return Vec::new();
        }
        policy::default_idle_reclaim(view)
    }
}

fn params(policy: Policy, seed: u64) -> SimParams {
    let cat = Catalog::paper();
    let mut cfg = SystemConfig::prototype(policy);
    cfg.seed = seed;
    // short enough that idle reclamation actually fires inside the run,
    // exercising on_scan equivalence too
    cfg.rm.idle_timeout_s = 20.0;
    SimParams {
        cfg,
        chains: cat.mix("Heavy").unwrap().chains.clone(),
        trace: Trace::poisson(5.0, 60),
        drain_s: 30.0,
    }
}

#[test]
fn trait_policies_match_legacy_engine_byte_for_byte() {
    for policy in Policy::PAPER {
        for seed in [7u64, 42] {
            let (new_rec, new_sum) = run_sim(params(policy, seed));
            let (old_rec, old_sum) = run_sim_with(
                params(policy, seed),
                Box::new(LegacyOracle { policy }),
            );

            let tag = format!("{} @ seed {}", policy.name(), seed);
            // job records: arrival/completion/stage timelines, in order
            assert_eq!(new_rec.jobs, old_rec.jobs, "{tag}: job records diverge");
            // container events: spawn/retire times, batches, coldness
            assert_eq!(
                new_rec.containers, old_rec.containers,
                "{tag}: container records diverge"
            );
            assert_eq!(
                new_rec.cold_starts, old_rec.cold_starts,
                "{tag}: cold starts diverge"
            );
            // energy series sampled at every scan tick (exact f64 match:
            // both runs draw identical RNG streams)
            assert_eq!(
                new_rec.energy_series, old_rec.energy_series,
                "{tag}: energy series diverge"
            );
            assert!(
                new_rec.energy_wh == old_rec.energy_wh,
                "{tag}: total energy diverges ({} vs {})",
                new_rec.energy_wh,
                old_rec.energy_wh
            );
            assert_eq!(new_rec.horizon, old_rec.horizon, "{tag}: horizon");
            // and the derived summaries agree on the headline numbers
            assert_eq!(new_sum.jobs, old_sum.jobs, "{tag}");
            assert_eq!(new_sum.total_spawned, old_sum.total_spawned, "{tag}");
        }
    }
}

#[test]
fn oracle_capabilities_match_registry() {
    // the transcription's hardcoded capability sets must agree with what
    // the registry now declares — otherwise the oracle tests a strawman
    for policy in Policy::PAPER {
        let oracle = LegacyOracle { policy };
        assert_eq!(oracle.legacy_batching(), policy.batching(), "{}", policy.name());
        assert_eq!(oracle.legacy_proactive(), policy.proactive(), "{}", policy.name());
        assert_eq!(oracle.legacy_lsf(), policy.lsf(), "{}", policy.name());
    }
}
