//! Observability-plane integration: byte-deterministic `--slo-timeline`
//! output from the sim driver, the SLO contract shape, and the
//! dependency-free `/metrics` HTTP responder end to end.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};

use fifer::config::{Policy, SystemConfig};
use fifer::model::Catalog;
use fifer::obs::{MetricsServer, ObsConfig, ObsReport, SharedSnapshot};
use fifer::scenario::{self, ScenarioSpec};
use fifer::sim::{run_summarized_obs, SimParams};
use fifer::trace::Trace;

/// A small pure-generator sweep (no artifact files involved, so the
/// traces are a function of the spec alone): 2 policies x 2 seeds.
const SPEC: &str = r#"
[scenario]
name = "obs-pin"
duration_s = 60
drain_s = 10
seeds = [7, 42]
traces = ["t"]
mixes = ["Heavy"]
policies = ["Bline", "Fifer"]

[trace.t]
expr = "poisson(rate=20)"
"#;

fn sim_report() -> ObsReport {
    let cat = Catalog::paper();
    let (_, _, report) = run_summarized_obs(
        SimParams {
            cfg: SystemConfig::prototype(Policy::Fifer),
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(20.0, 30),
            drain_s: 10.0,
        },
        0,
        Some(ObsConfig::default()),
    );
    report.expect("collector was enabled")
}

#[test]
fn slo_timeline_is_byte_identical_across_runs_and_thread_counts() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let obs = Some(ObsConfig::default());
    let render = |threads| {
        let results = scenario::run_scenario_obs(&spec, threads, obs).unwrap();
        assert!(results.iter().all(|r| r.obs.is_some()));
        scenario::results_obs_json(&spec, &results).to_string()
    };
    let serial = render(1);
    assert_eq!(serial, render(1), "run-to-run divergence");
    assert_eq!(serial, render(4), "thread-count divergence");
    assert!(serial.contains("\"request_success_rate\""));
    assert!(serial.contains("\"e2e_p95_ms\""));
}

#[test]
fn plain_sweep_carries_no_timeline() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let results = scenario::run_scenario(&spec, 2).unwrap();
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.obs.is_none()));
}

#[test]
fn sim_contract_has_all_four_slos() {
    let report = sim_report();
    let evals = report.contract();
    let names: Vec<&str> = evals.iter().map(|e| e.name).collect();
    assert_eq!(
        names,
        vec![
            "request_success_rate",
            "e2e_p95_ms",
            "container_utilization",
            "cold_start_ratio",
        ]
    );
    for e in &evals {
        assert!(e.value.is_finite(), "{}: non-finite value", e.name);
        assert!(e.target.is_finite(), "{}: non-finite target", e.name);
        assert!(e.burn_fast >= 0.0 && e.burn_slow >= 0.0, "{}", e.name);
    }
    // a 20 req/s Poisson run under Fifer completes work and stays
    // overwhelmingly within SLO
    assert!(report.totals.completions > 100);
    assert!(evals[0].value > 0.5, "success rate {}", evals[0].value);
}

// ---------------------------------------------------------------------
// HTTP responder
// ---------------------------------------------------------------------

/// Minimal HTTP/1.1 GET over a raw socket (the responder closes the
/// connection after each response, so read-to-end terminates).
fn get(addr: SocketAddr, target: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect metrics responder");
    write!(s, "GET {target} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let code: u16 = raw
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("status code");
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (code, body)
}

#[test]
fn metrics_endpoints_end_to_end() {
    let shared: SharedSnapshot = Arc::new(Mutex::new(None));
    let srv = MetricsServer::start("127.0.0.1:0", shared.clone()).expect("bind");
    let addr = srv.local_addr();

    // before the first publish every route answers 503
    let (code, body) = get(addr, "/metrics");
    assert_eq!(code, 503, "body {body}");
    assert!(body.contains("no snapshot yet"));

    let report = sim_report();
    *shared.lock().unwrap() = Some(report.clone());

    // each route serves exactly the corresponding render — same bytes
    let (code, body) = get(addr, "/metrics");
    assert_eq!(code, 200);
    assert_eq!(body, report.metrics_json().to_string());

    let (code, body) = get(addr, "/metrics/summary");
    assert_eq!(code, 200);
    assert_eq!(body, report.summary_json().to_string());
    assert!(body.contains("\"slo\""));

    let (code, body) = get(addr, "/metrics/history?minutes=5");
    assert_eq!(code, 200);
    assert_eq!(body, report.history_json(Some(5)).to_string());

    // error paths: unknown route, malformed minutes, non-GET
    let (code, _) = get(addr, "/nope");
    assert_eq!(code, 404);
    let (code, _) = get(addr, "/metrics/history?minutes=abc");
    assert_eq!(code, 400);
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "POST /metrics HTTP/1.1\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");

    srv.stop();
}
