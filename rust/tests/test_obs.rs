//! Observability-plane integration: byte-deterministic `--slo-timeline`
//! and `--trace-out` output from the sim driver, the SLO contract
//! shape, burn-rate alert edge transitions, and the dependency-free
//! `/metrics` + `/traces` HTTP responder end to end.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};

use fifer::config::{Policy, SystemConfig};
use fifer::metrics::JobRecord;
use fifer::model::Catalog;
use fifer::obs::{prom, Collector, MetricsServer, ObsConfig, ObsReport, SharedSnapshot};
use fifer::scenario::{self, ScenarioSpec};
use fifer::sim::{run_summarized_obs, SimParams};
use fifer::trace::Trace;
use fifer::util::json::Json;
use fifer::util::secs;

/// A small pure-generator sweep (no artifact files involved, so the
/// traces are a function of the spec alone): 2 policies x 2 seeds.
const SPEC: &str = r#"
[scenario]
name = "obs-pin"
duration_s = 60
drain_s = 10
seeds = [7, 42]
traces = ["t"]
mixes = ["Heavy"]
policies = ["Bline", "Fifer"]

[trace.t]
expr = "poisson(rate=20)"
"#;

fn sim_report_with(obs: ObsConfig) -> ObsReport {
    let cat = Catalog::paper();
    let (_, _, report) = run_summarized_obs(
        SimParams {
            cfg: SystemConfig::prototype(Policy::Fifer),
            chains: cat.mix("Heavy").unwrap().chains.clone(),
            trace: Trace::poisson(20.0, 30),
            drain_s: 10.0,
        },
        0,
        Some(obs),
    );
    report.expect("collector was enabled")
}

fn sim_report() -> ObsReport {
    sim_report_with(ObsConfig::default())
}

#[test]
fn slo_timeline_is_byte_identical_across_runs_and_thread_counts() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let obs = Some(ObsConfig::default());
    let render = |threads| {
        let results = scenario::run_scenario_obs(&spec, threads, obs).unwrap();
        assert!(results.iter().all(|r| r.obs.is_some()));
        scenario::results_obs_json(&spec, &results).to_string()
    };
    let serial = render(1);
    assert_eq!(serial, render(1), "run-to-run divergence");
    assert_eq!(serial, render(4), "thread-count divergence");
    assert!(serial.contains("\"request_success_rate\""));
    assert!(serial.contains("\"e2e_p95_ms\""));
}

#[test]
fn plain_sweep_carries_no_timeline() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let results = scenario::run_scenario(&spec, 2).unwrap();
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.obs.is_none()));
}

#[test]
fn sim_contract_has_all_four_slos() {
    let report = sim_report();
    let evals = report.contract();
    let names: Vec<&str> = evals.iter().map(|e| e.name).collect();
    assert_eq!(
        names,
        vec![
            "request_success_rate",
            "e2e_p95_ms",
            "container_utilization",
            "cold_start_ratio",
        ]
    );
    for e in &evals {
        assert!(e.value.is_finite(), "{}: non-finite value", e.name);
        assert!(e.target.is_finite(), "{}: non-finite target", e.name);
        assert!(e.burn_fast >= 0.0 && e.burn_slow >= 0.0, "{}", e.name);
    }
    // a 20 req/s Poisson run under Fifer completes work and stays
    // overwhelmingly within SLO
    assert!(report.totals.completions > 100);
    assert!(evals[0].value > 0.5, "success rate {}", evals[0].value);
}

// ---------------------------------------------------------------------
// HTTP responder
// ---------------------------------------------------------------------

/// Minimal HTTP/1.1 GET over a raw socket (the responder closes the
/// connection after each response, so read-to-end terminates).
fn get(addr: SocketAddr, target: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect metrics responder");
    write!(s, "GET {target} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).expect("read response");
    let code: u16 = raw
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("status code");
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (code, body)
}

#[test]
fn metrics_endpoints_end_to_end() {
    let shared: SharedSnapshot = Arc::new(Mutex::new(None));
    let srv = MetricsServer::start("127.0.0.1:0", shared.clone()).expect("bind");
    let addr = srv.local_addr();

    // before the first publish every route answers 503
    let (code, body) = get(addr, "/metrics");
    assert_eq!(code, 503, "body {body}");
    assert!(body.contains("no snapshot yet"));

    let report = sim_report();
    *shared.lock().unwrap() = Some(report.clone());

    // each route serves exactly the corresponding render — same bytes
    let (code, body) = get(addr, "/metrics");
    assert_eq!(code, 200);
    assert_eq!(body, report.metrics_json().to_string());

    let (code, body) = get(addr, "/metrics/summary");
    assert_eq!(code, 200);
    assert_eq!(body, report.summary_json().to_string());
    assert!(body.contains("\"slo\""));

    let (code, body) = get(addr, "/metrics/history?minutes=5");
    assert_eq!(code, 200);
    assert_eq!(body, report.history_json(Some(5)).to_string());

    // error paths: unknown route, malformed minutes, non-GET
    let (code, _) = get(addr, "/nope");
    assert_eq!(code, 404);
    let (code, _) = get(addr, "/metrics/history?minutes=abc");
    assert_eq!(code, 400);
    // zero/empty minutes are rejected, not clamped to one row
    let (code, _) = get(addr, "/metrics/history?minutes=0");
    assert_eq!(code, 400);
    let (code, _) = get(addr, "/metrics/history?minutes=");
    assert_eq!(code, 400);
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "POST /metrics HTTP/1.1\r\n\r\n").unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");

    srv.stop();
}

#[test]
fn prom_and_traces_endpoints_end_to_end() {
    let shared: SharedSnapshot = Arc::new(Mutex::new(None));
    let srv = MetricsServer::start("127.0.0.1:0", shared.clone()).expect("bind");
    let addr = srv.local_addr();

    let report = sim_report_with(ObsConfig {
        trace_sample: 1,
        ..ObsConfig::default()
    });
    assert!(!report.traces.is_empty(), "tracing at 1-in-1 records spans");
    *shared.lock().unwrap() = Some(report.clone());

    // /metrics/prom serves exactly the exposition renderer's bytes
    let (code, body) = get(addr, "/metrics/prom");
    assert_eq!(code, 200);
    assert_eq!(body, prom::render(&report));
    assert!(body.contains("# TYPE fifer_arrivals_total counter"));
    assert!(body.contains("fifer_slo_attained{slo=\"request_success_rate\"}"));

    // /traces serves the Chrome trace document, honoring last=N
    let (code, body) = get(addr, "/traces");
    assert_eq!(code, 200);
    assert_eq!(body, report.trace_json(Some(100)).to_string());
    let (code, body) = get(addr, "/traces?last=1");
    assert_eq!(code, 200);
    assert_eq!(body, report.trace_json(Some(1)).to_string());
    let doc = Json::parse(&body).expect("valid JSON");
    assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());

    // bad last values: zero and junk are 400
    let (code, _) = get(addr, "/traces?last=0");
    assert_eq!(code, 400);
    let (code, _) = get(addr, "/traces?last=nope");
    assert_eq!(code, 400);

    srv.stop();
}

#[test]
fn responder_rejects_oversized_and_stalled_requests() {
    let shared: SharedSnapshot = Arc::new(Mutex::new(None));
    let srv = MetricsServer::start("127.0.0.1:0", shared.clone()).expect("bind");
    let addr = srv.local_addr();

    // a request line that fills the 2 KiB head buffer without a CRLF
    // is answered 431, not parsed (exactly 2048 bytes, so the server
    // closes with nothing left unread and the client sees a clean FIN)
    let mut s = TcpStream::connect(addr).unwrap();
    let long = vec![b'a'; 2048 - "GET /".len()];
    s.write_all(b"GET /").unwrap();
    s.write_all(&long).unwrap();
    s.flush().unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 431"), "{raw}");

    // a client that connects and sends nothing is cut off with 408
    // after the 2 s read timeout instead of stalling the responder
    let mut s = TcpStream::connect(addr).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 408"), "{raw}");

    // the responder survived both and still serves normal requests
    *shared.lock().unwrap() = Some(sim_report());
    let (code, _) = get(addr, "/metrics");
    assert_eq!(code, 200);

    srv.stop();
}

// ---------------------------------------------------------------------
// tracing
// ---------------------------------------------------------------------

#[test]
fn trace_out_is_byte_identical_across_runs_and_thread_counts() {
    let spec = ScenarioSpec::parse(SPEC).unwrap();
    let obs = Some(ObsConfig {
        trace_sample: 4,
        trace_keep: usize::MAX,
        ..ObsConfig::default()
    });
    let render = |threads| {
        let results = scenario::run_scenario_obs(&spec, threads, obs).unwrap();
        scenario::results_trace_json(&spec, &results).to_string()
    };
    let serial = render(1);
    assert_eq!(serial, render(1), "run-to-run divergence");
    assert_eq!(serial, render(4), "thread-count divergence");

    // schema sanity: a loadable trace-event document with the span
    // vocabulary the tentpole promises
    let doc = Json::parse(&serial).expect("valid JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() > 100, "only {} events", events.len());
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        assert!(ph == "X" || ph == "M", "unexpected phase {ph}");
        if ph == "X" {
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("pid").unwrap().as_f64().unwrap() >= 1.0);
            assert!(e.get("tid").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
    for needle in [
        "\"cat\":\"request\"",
        "\"cat\":\"stage\"",
        "\"name\":\"exec\"",
        "\"name\":\"monitor\"",
        "\"container\":",
        "\"node\":",
        "\"batch\":",
        "\"cold\":",
        "\"policy\":\"Fifer\"",
        "\"policy\":\"Bline\"",
    ] {
        assert!(serial.contains(needle), "missing {needle}");
    }
}

#[test]
fn trace_sampling_is_head_based_and_seeded() {
    let full = sim_report_with(ObsConfig {
        trace_sample: 1,
        trace_keep: usize::MAX,
        ..ObsConfig::default()
    });
    let quarter = sim_report_with(ObsConfig {
        trace_sample: 4,
        trace_keep: usize::MAX,
        ..ObsConfig::default()
    });
    let off = sim_report();
    // 1-in-1 keeps every completed request; 1-in-4 a strict subset
    assert_eq!(full.traces.len() as u64, full.totals.completions);
    assert!(quarter.traces.len() < full.traces.len());
    assert!(!quarter.traces.is_empty());
    assert!(off.traces.is_empty(), "trace_sample=0 disables recording");
    // every sampled request carries a full span tree
    for t in &full.traces {
        assert!(!t.stages.is_empty(), "job {} has no stages", t.job_id);
        for s in &t.stages {
            assert!(s.enqueued <= s.exec_start && s.exec_start <= s.exec_end);
            assert!(s.batch >= 1);
        }
        assert!(t.completion >= t.arrival);
    }
    // the sampled id set is a pure function of the seed
    let again = sim_report_with(ObsConfig {
        trace_sample: 4,
        trace_keep: usize::MAX,
        ..ObsConfig::default()
    });
    let ids = |r: &ObsReport| r.traces.iter().map(|t| t.job_id).collect::<Vec<_>>();
    assert_eq!(ids(&quarter), ids(&again));
}

#[test]
fn summary_carries_decision_latency_block() {
    // deterministic runs render zeros...
    let s = sim_report().summary_json().to_string();
    assert!(s.contains("\"decision_latency_us\":"), "{s}");
    assert!(s.contains("\"samples\":0"));
    // ...and probed samples land in the histogram percentiles
    let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Test");
    for ns in [10_000u64, 150_000, 2_000_000] {
        c.on_decision_latency(ns);
    }
    let r = c.report(0);
    assert_eq!(r.decision.count, 3);
    assert!((r.decision.max_us - 2000.0).abs() < 1e-9);
    let s = r.summary_json().to_string();
    assert!(s.contains("\"samples\":3"), "{s}");
}

// ---------------------------------------------------------------------
// burn-rate alert edges + ring wraparound (satellite coverage)
// ---------------------------------------------------------------------

/// Feed `jobs` completions into `c` inside the one-minute bucket at
/// `minute`, all succeeding (`ok`) or all violating.
fn feed_minute(c: &mut Collector, minute: u64, jobs: u64, ok: bool) {
    let t = secs(minute as f64 * 60.0 + 1.0);
    for k in 0..jobs {
        let rec = JobRecord {
            chain: 0,
            arrival: t,
            completion: t,
            stages: Vec::new(),
        };
        c.on_job_complete(t, minute * 10_000 + k, &rec, ok);
    }
}

fn success_eval(c: &Collector, minute: u64) -> fifer::obs::SloEval {
    let evals = c.report(secs(minute as f64 * 60.0 + 30.0)).contract();
    assert_eq!(evals[0].name, "request_success_rate");
    evals[0].clone()
}

#[test]
fn burn_rate_alert_edge_transitions() {
    // default windows: fast = 5 min, slow = 60 min, one-minute buckets
    let mut c = Collector::new(ObsConfig::default(), 1000.0, 0, "Test");

    // phase A: a long healthy history, then a 5-minute total outage —
    // the fast window burns but the slow window still holds budget, so
    // no page (brief spikes must not alert)
    for m in 0..60 {
        feed_minute(&mut c, m, 100, true);
    }
    for m in 60..65 {
        feed_minute(&mut c, m, 100, false);
    }
    let e = success_eval(&c, 64);
    assert!(e.burn_fast >= 1.0, "fast window must burn: {e:?}");
    assert!(e.burn_slow < 1.0, "slow window must still hold: {e:?}");
    assert!(!e.alerting(), "fast-only breach must not page: {e:?}");

    // phase B: the outage persists until the slow window breaches too —
    // now both burns cross 1 and the objective pages
    for m in 65..125 {
        feed_minute(&mut c, m, 100, false);
    }
    let e = success_eval(&c, 124);
    assert!(e.burn_fast >= 1.0 && e.burn_slow >= 1.0, "{e:?}");
    assert!(e.alerting(), "sustained breach must page: {e:?}");

    // phase C: recovery hysteresis — 5 healthy minutes clear the fast
    // window and the page drops even though the slow window is still
    // deep in violation
    for m in 125..130 {
        feed_minute(&mut c, m, 100, true);
    }
    let e = success_eval(&c, 129);
    assert!(e.burn_fast < 1.0, "recovered fast window: {e:?}");
    assert!(e.burn_slow >= 1.0, "slow window still burned: {e:?}");
    assert!(!e.alerting(), "recovery must clear the page: {e:?}");
}

#[test]
fn ring_wraparound_keeps_oldest_row_identity() {
    let cfg = ObsConfig {
        bucket_s: 1,
        retention_buckets: 4,
        ..ObsConfig::default()
    };
    let mut c = Collector::new(cfg, 1000.0, 0, "Test");
    // second s gets s+1 arrivals, so every row is self-identifying
    for s in 0..12u64 {
        for _ in 0..=s {
            c.on_arrival(secs(s as f64));
        }
    }
    let r = c.report(secs(11.0));
    assert_eq!(r.rows.len(), 4);
    assert_eq!(r.dropped_buckets, 8, "evictions are counted");
    for (i, row) in r.rows.iter().enumerate() {
        let s = 8 + i as u64;
        assert_eq!(row.start, secs(s as f64), "row {i} start");
        assert_eq!(row.arrivals, s + 1, "row {i} is the right row");
    }
    // totals survive wraparound even though rows were dropped
    assert_eq!(r.totals.arrivals, (1..=12).sum::<u64>());
}
