//! Scenario-layer guarantees:
//!
//! 1. TOML scenario files round-trip into validated specs, and every
//!    class of bad input fails with an error at parse time.
//! 2. The sharded parallel sweep is byte-identical to the serial one at
//!    fixed seeds (JSON and CSV).
//! 3. The built-in `prototype-grid` / `macro-grid` scenarios reproduce
//!    `experiments::run_policy` cells exactly — the paper's evaluation
//!    grid is a special case of the scenario subsystem.
//! 4. Trace operators/generators evaluate deterministically through the
//!    scenario pipeline.

use fifer::config::Policy;
use fifer::experiments::{self, TraceKind};
use fifer::metrics::Summary;
use fifer::scenario::{self, ScenarioSpec};

const SMALL: &str = r#"
# four-cell sweep on the prototype cluster
[scenario]
name = "small"
duration_s = 30
drain_s = 30
seeds = [7, 11]
traces = ["poisson"]
mixes = ["Heavy"]
policies = ["Bline", "Fifer"]

[cluster]
preset = "prototype"

[rm]
idle_timeout_s = 60
"#;

#[test]
fn toml_round_trip() {
    let spec = ScenarioSpec::parse(SMALL).unwrap();
    assert_eq!(spec.name, "small");
    assert_eq!(spec.duration_s, 30);
    assert_eq!(spec.drain_s, 30.0);
    assert_eq!(spec.seeds, vec![7, 11]);
    assert_eq!(spec.traces, vec!["poisson"]);
    assert_eq!(spec.mixes, vec!["Heavy"]);
    assert_eq!(spec.policies, vec![Policy::Bline, Policy::Fifer]);
    assert_eq!(spec.cluster.nodes, 5);
    // defaults
    assert_eq!(spec.warmup_frac, 0.5);
    assert_eq!(spec.warmup_cap_s, 700.0);
    assert_eq!(spec.artifacts_dir, "artifacts");
    // matrix order: trace-major, seed-minor, contiguous indices
    let cells = spec.cells();
    assert_eq!(cells.len(), 4);
    assert_eq!(
        cells.iter().map(|c| (c.policy, c.seed)).collect::<Vec<_>>(),
        vec![
            (Policy::Bline, 7),
            (Policy::Bline, 11),
            (Policy::Fifer, 7),
            (Policy::Fifer, 11),
        ]
    );
    for (i, c) in cells.iter().enumerate() {
        assert_eq!(c.index, i);
    }
}

#[test]
fn policy_groups_expand() {
    let all = ScenarioSpec::parse(
        "[scenario]\nduration_s = 10\ntraces = [\"poisson\"]\npolicies = [\"all\"]",
    )
    .unwrap();
    assert_eq!(all.policies, Policy::ALL.to_vec());
    let paper = ScenarioSpec::parse(
        "[scenario]\nduration_s = 10\ntraces = [\"poisson\"]\npolicies = [\"paper\"]",
    )
    .unwrap();
    assert_eq!(paper.policies, Policy::PAPER.to_vec());
    // defaults: policies -> all, mixes -> Heavy, seeds -> [42]
    let d = ScenarioSpec::parse("[scenario]\ntraces = [\"wits\"]").unwrap();
    assert_eq!(d.policies, Policy::ALL.to_vec());
    assert_eq!(d.mixes, vec!["Heavy"]);
    assert_eq!(d.seeds, vec![42]);
    assert_eq!(d.duration_s, 600);
}

#[test]
fn bad_inputs_error_at_parse_time() {
    // complete documents, each broken in one way
    let standalone: &[(&str, &str)] = &[
        ("no scenario section", "duration_s = 10"),
        ("no traces", "[scenario]\nduration_s = 10"),
        ("empty traces", "[scenario]\ntraces = []"),
        ("unknown trace name", "[scenario]\ntraces = [\"mystery\"]"),
        ("root-level key", "x = 1\n[scenario]\ntraces = [\"wits\"]"),
    ];
    // one broken tail appended to an otherwise-valid [scenario] head
    let tails: &[(&str, &str)] = &[
        ("unknown policy", "policies = [\"zline\"]"),
        ("unknown mix", "mixes = [\"Spicy\"]"),
        ("unknown scenario key", "polices = [\"Fifer\"]"),
        ("unknown section", "[traces.x]\nexpr = \"wits()\""),
        ("bad expression syntax", "[trace.t]\nexpr = \"overlay(wits,\""),
        ("unknown function", "[trace.t]\nexpr = \"frobnicate()\""),
        ("unknown expr reference", "[trace.t]\nexpr = \"scale(ghost, by=2)\""),
        ("wrong expr arity", "[trace.t]\nexpr = \"overlay(wits)\""),
        ("missing required expr param", "[trace.t]\nexpr = \"scale(wits)\""),
        ("typo'd expr param", "[trace.t]\nexpr = \"noise(wits, sgima=0.1)\""),
        ("trace section extra key", "[trace.t]\nexpr = \"wits()\"\nrate = 5"),
        ("trace section missing expr", "[trace.t]\nname = \"t\""),
        ("bad trace name", "[trace.a,b]\nexpr = \"wits()\""),
        ("unknown rm key", "[rm]\nidle_timeout = 60"),
        ("unknown cluster key", "[cluster]\nnods = 3"),
        ("negative seed", "seeds = [-1]"),
        ("fractional seed", "seeds = [1.5]"),
        ("seeds not numbers", "seeds = [\"a\"]"),
        ("zero duration", "duration_s = 0"),
        ("warmup_frac out of range", "warmup_frac = 1.5"),
        ("bad rm override", "[rm]\nslack_policy = \"zigzag\""),
        ("bad cluster preset", "[cluster]\npreset = \"mega\""),
    ];
    let check = |what: &str, text: &str| {
        assert!(
            ScenarioSpec::parse(text).is_err(),
            "{what}: expected a parse error\n{text}"
        );
    };
    for &(what, text) in standalone {
        check(what, text);
    }
    for &(what, tail) in tails {
        check(what, &format!("[scenario]\ntraces = [\"wits\"]\n{tail}"));
    }
}

#[test]
fn definition_cycles_are_detected() {
    let spec = ScenarioSpec::parse(
        r#"
[scenario]
duration_s = 10
traces = ["a"]

[trace.a]
expr = "scale(b, by=2)"

[trace.b]
expr = "scale(a, by=2)"
"#,
    )
    .unwrap();
    let err = spec.build_traces().unwrap_err().to_string();
    assert!(err.contains("itself"), "unexpected error: {err}");
}

#[test]
fn composed_traces_build_deterministically() {
    let text = r#"
[scenario]
duration_s = 120
traces = ["base", "crowd"]

[trace.base]
expr = "noise(ramp(wits(seed=5), from=0.5, to=1.0), sigma=0.1, seed=9)"

[trace.crowd]
expr = "overlay(base, flashcrowd(base=0, amp=300, start=60, width=20))"
"#;
    let spec = ScenarioSpec::parse(text).unwrap();
    let a = spec.build_traces().unwrap();
    let b = spec.build_traces().unwrap();
    assert_eq!(a["crowd"].rate_per_s, b["crowd"].rate_per_s);
    assert_eq!(a["crowd"].duration_s(), 120);
    // crowd is exactly base + the 300 req/s step inside [60, 80)
    for t in 0..120 {
        let step = if (60..80).contains(&t) { 300.0 } else { 0.0 };
        assert_eq!(a["crowd"].rate_per_s[t], a["base"].rate_per_s[t] + step, "t={t}");
    }
}

#[test]
fn parallel_sweep_is_byte_identical_to_serial() {
    let spec = ScenarioSpec::parse(SMALL).unwrap();
    let serial = scenario::run_scenario(&spec, 1).unwrap();
    let sharded = scenario::run_scenario(&spec, 3).unwrap();
    assert_eq!(serial.len(), 4);
    assert_eq!(
        scenario::results_json(&spec, &serial).to_string(),
        scenario::results_json(&spec, &sharded).to_string()
    );
    assert_eq!(
        scenario::results_csv(&serial),
        scenario::results_csv(&sharded)
    );
    // oversubscribed thread count is clamped, still identical
    let over = scenario::run_scenario(&spec, 64).unwrap();
    assert_eq!(
        scenario::results_json(&spec, &serial).to_string(),
        scenario::results_json(&spec, &over).to_string()
    );
}

#[test]
fn csv_shape_matches_results() {
    let spec = ScenarioSpec::parse(SMALL).unwrap();
    let results = scenario::run_scenario(&spec, 2).unwrap();
    let csv = scenario::results_csv(&results);
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 1 + results.len());
    let ncols = 4 + Summary::CSV_FIELDS.len();
    for line in &lines {
        assert_eq!(line.split(',').count(), ncols, "{line}");
    }
    assert!(lines[0].starts_with("trace,mix,policy,seed,jobs,"));
}

/// The §6.1 grid is a special case: a `prototype-grid` cell is
/// byte-identical to `experiments::run_policy` with the same knobs.
#[test]
fn builtin_prototype_grid_matches_experiment_driver() {
    let mut spec = ScenarioSpec::parse(scenario::builtin("prototype-grid").unwrap()).unwrap();
    // shrink the grid to one cheap cell; the shared knobs stay as shipped
    spec.duration_s = 30;
    spec.seeds = vec![7];
    spec.policies = vec![Policy::Fifer];
    spec.mixes = vec!["Heavy".to_string()];
    let results = scenario::run_scenario(&spec, 2).unwrap();
    assert_eq!(results.len(), 1);
    let driver = experiments::run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 30, true, 7);
    assert_eq!(
        results[0].summary.to_json().to_string(),
        driver.summary.to_json().to_string(),
        "scenario cell must equal the run_prototype driver cell"
    );
}

/// Same for the §6.2 macro grid (simulation cluster, real trace).
#[test]
fn builtin_macro_grid_matches_experiment_driver() {
    let mut spec = ScenarioSpec::parse(scenario::builtin("macro-grid").unwrap()).unwrap();
    spec.duration_s = 90;
    spec.seeds = vec![42];
    spec.policies = vec![Policy::Bline];
    spec.mixes = vec!["Heavy".to_string()];
    spec.traces = vec!["wits".to_string()];
    let results = scenario::run_scenario(&spec, 1).unwrap();
    assert_eq!(results.len(), 1);
    let driver = experiments::run_policy(Policy::Bline, "Heavy", TraceKind::Wits, 90, false, 42);
    assert_eq!(
        results[0].summary.to_json().to_string(),
        driver.summary.to_json().to_string(),
        "scenario cell must equal the run_macro driver cell"
    );
}

/// Warm-up follows each trace's *actual* horizon: a cell whose
/// expression resizes the trace must equal the driver run at the
/// resized duration, not at the nominal `duration_s`.
#[test]
fn warmup_follows_actual_trace_horizon() {
    let spec = ScenarioSpec::parse(
        r#"
[scenario]
duration_s = 30
seeds = [7]
traces = ["longer"]
mixes = ["Heavy"]
policies = ["Fifer"]

[trace.longer]
expr = "resize(poisson(rate=50), to=60)"
"#,
    )
    .unwrap();
    let results = scenario::run_scenario(&spec, 1).unwrap();
    // same workload as the Poisson driver at duration 60 (resize of a
    // constant-rate series is the same series), so summaries match iff
    // the warm-up cutoff scales with the real 60 s horizon
    let driver = experiments::run_policy(Policy::Fifer, "Heavy", TraceKind::Poisson, 60, true, 7);
    assert_eq!(
        results[0].summary.to_json().to_string(),
        driver.summary.to_json().to_string()
    );
}

#[test]
fn all_builtin_scenarios_parse_and_validate() {
    for (name, text, _) in scenario::BUILTINS {
        let spec = ScenarioSpec::parse(text)
            .unwrap_or_else(|e| panic!("builtin {name} failed to parse: {e:#}"));
        assert!(!spec.cells().is_empty(), "{name}: empty matrix");
    }
    // the composed demo also builds its traces (without running the sims)
    let demo = ScenarioSpec::parse(scenario::builtin("flashcrowd").unwrap()).unwrap();
    let traces = demo.build_traces().unwrap();
    assert_eq!(traces.len(), 2);
    assert!(traces.contains_key("crowd") && traces.contains_key("azure"));
    assert_eq!(demo.cells().len(), 12); // 2 traces x 1 mix x 3 policies x 2 seeds
    assert!(scenario::builtin("no-such-scenario").is_none());
}
